"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes the same rows as
machine-readable JSON (``BENCH_partitionpim.json``, uploaded as a CI
artifact) so the perf trajectory is diffable across commits.
``us_per_call`` is real CPU wall time where the benchmark executes
something (the simulator throughput rows); cycle/bit/area rows are
cycle-accurate simulator measurements (``derived`` column) with the build
time as the timing column.

Every JSON row is stamped with its table name (``suite``), ``pim_mode``,
and ``mesh`` shape so ``benchmarks/check.py`` can key rows stably on
(suite, name, pim_mode) across PRs; a top-level ``_meta`` block records
the jax version, git commit, and device topology of the run.  Rows may
additionally carry gateable fields — ``tok_s`` (absolute decode
throughput), ``ratio`` (within-run speedup, machine-independent), and
``bit_exact`` — which the CI regression gate compares against
``benchmarks/baseline.json`` (see check.py for the refresh procedure).

Paper anchors:
  fig6a_latency   — §5.1: 32-bit multiplication latency per model
  fig6b_control   — §5.2: control-message bits (607/79/36 vs 30)
  fig6c_area      — §5.3.2: algorithmic area (memristor columns)
  energy          — §5.4: total gate count (serial vs parallel)
  bounds          — §2.3/3.3/4.3: combinatorial lower bounds
  sim_throughput  — crossbar-simulator throughput (real wall time)
  dot_accumulate  — beyond-paper carry-save accumulator (before/after)
  pim_lm_gemm     — the paper's technique applied to the assigned archs

``--suite serving`` runs the continuous-batching decode-throughput
benchmark instead (tokens/sec at batch 1/4/16 over a synthetic Poisson
request trace; batch 1 doubles as the sequential-request-handling
baseline); ``--suite serving-paged`` A/Bs the block-paged KV pool against
the contiguous one on a long-tail trace (bit-identical tokens, peak pool
bytes strictly below the ``max_batch * max_len`` reservation) and serves
a sliding-window config end-to-end; ``--suite tp`` measures the
tensor-parallel ``quant_tp`` decode path against single-rank "quant" at
mesh model={1,2,4,8} on the forced 8-device CPU topology (per-rank tile
shapes, tok/s, speedup ratio, and a quant-tolerance output check);
``--suite prefix`` replays a shared-system-prompt trace with the trie
prefix cache on and off, per PIM mode {xla, quant, quant_tp}: warm
(trie-hit) admits must beat cold mean TTFT by the gated 2x floor, stay
bit-identical to the no-prefix-cache paged pool, and the blocks-shared
reuse ratio records how much of the prompt stream the index
deduplicates; ``--suite prefill-chunked`` replays a bursty
long-prompt-plus-shorts trace with chunked+packed prefill on and off per
PIM mode: chunking must cut the p99 inter-token gap by the gated 2x
floor (a monolithic long prefill stalls every decoding slot; a 64-token
chunk bounds the stall) while generations stay bit-identical to whole
prefill; ``--suite replica`` measures the multi-replica router on
the fleet clock (replica={1,2,4} throughput scaling over 8-device
slices, a prefix-affinity vs round-robin dispatch hit-rate A/B on a
multi-tenant trace, and a mid-trace replica-kill drill that must finish
with zero lost requests and tokens bit-identical to a single-scheduler
oracle); ``--suite spec-decode`` A/Bs self-speculative decoding (quant
drafts, ``draft_k=4``, expensive-mode batched verify) against plain
decode per verify mode {xla, quant_tp, pim_sim}: tokens must stay
bit-identical in every mode (greedy acceptance is exact for any
draft/verify pairing) and the pim_sim verify must clear the gated 1.3x
tok/s floor — the crossbar simulator's per-gate overhead dominates its
row math, so one k-wide verify step costs about one single-row step;
``--suite autotune`` runs the partition autotuner's race per
(shape, pim_mode) grid point — the tuned pick must never lose to the
hardcoded default (``picked_vs_default`` gated at floor 1.0), the tuned
GEMM must stay bit-exact, the tuning-table JSON roundtrip must preserve
picks, and the two new multiplier backends must keep beating the NOR
serial baseline's cycle count; ``--suite all`` runs everything.  All
rows land in the same JSON artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Tuple, Union

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

#: (name, us_per_call, derived[, extras]) — extras is an optional dict of
#: row stamps / gateable fields (pim_mode, mesh, tok_s, ratio, bit_exact;
#: tol — a per-row gate tolerance for rows noisier than check.py's 20%
#: default; floor — an absolute minimum replacing the relative gate for
#: rows whose smoke-scale wall time is heavy-tailed on small CI boxes:
#: the tok_s floors (tok_s/4 at baseline-mint time) still catch the
#: order-of-magnitude failure modes — a decode step that recompiles per
#: token, an accidentally serialized shard_map — while the deterministic
#: cycle-count tables and the within-run ratio rows carry the
#: finer-grained signal).
Row = Union[Tuple[str, float, str], Tuple[str, float, str, Dict]]


def _timed(fn):
    t0 = time.time()
    out = fn()
    return (time.time() - t0) * 1e6, out


def fig6a_latency() -> List[Row]:
    from repro.pim.mult_serial import build_serial_multiplier
    from repro.pim.multpim import build_multpim

    rows: List[Row] = []
    us, serial = _timed(lambda: build_serial_multiplier(32).program.stats())
    rows.append(("fig6a/serial_cycles", us, str(serial.cycles)))
    for model in ("unlimited", "standard", "minimal"):
        us, st = _timed(lambda m=model: build_multpim(32, model=m)
                        .program.stats())
        rows.append((f"fig6a/{model}_cycles", us, str(st.cycles)))
        rows.append((f"fig6a/{model}_speedup_vs_serial", 0.0,
                     f"{serial.cycles / st.cycles:.2f}x (paper: 11/9.2/8.6x)"))
    return rows


def fig6b_control() -> List[Row]:
    from repro.core import PartitionConfig, message_bits
    from repro.pim.mult_serial import build_serial_multiplier
    from repro.pim.multpim import build_multpim

    cfg = PartitionConfig(1024, 32)
    rows: List[Row] = []
    for model, paper in (("baseline", 30), ("unlimited", 607),
                         ("standard", 79), ("minimal", 36)):
        bits = message_bits(model, cfg)
        assert bits == paper, (model, bits, paper)
        rows.append((f"fig6b/{model}_message_bits", 0.0,
                     f"{bits} (paper: {paper})"))
    serial_total = build_serial_multiplier(32).program.stats().total_control_bits
    rows.append(("fig6b/serial_total_bits", 0.0, str(serial_total)))
    for model in ("unlimited", "standard", "minimal"):
        t = build_multpim(32, model=model).program.stats().total_control_bits
        rows.append((f"fig6b/{model}_total_bits", 0.0,
                     f"{t} ({t / serial_total:.2f}x of serial total)"))
    return rows


def fig6c_area() -> List[Row]:
    from repro.pim.mult_serial import build_serial_multiplier
    from repro.pim.multpim import build_multpim

    serial = build_serial_multiplier(32).program.stats().area_columns
    rows = [("fig6c/serial_area_columns", 0.0, str(serial))]
    for model in ("unlimited", "standard", "minimal"):
        a = build_multpim(32, model=model).program.stats().area_columns
        rows.append((f"fig6c/{model}_area_columns", 0.0,
                     f"{a} ({a / serial:.2f}x serial; paper ~1.4x)"))
    return rows


def energy() -> List[Row]:
    from repro.pim.mult_serial import build_serial_multiplier
    from repro.pim.multpim import build_multpim

    s = build_serial_multiplier(32).program.stats()
    rows = [("energy/serial_gates", 0.0, str(s.energy_gates))]
    for model in ("unlimited", "standard", "minimal"):
        p = build_multpim(32, model=model).program.stats()
        rows.append((f"energy/{model}_gates", 0.0,
                     f"{p.energy_gates} ({p.energy_gates / s.energy_gates:.2f}x"
                     f" serial; paper 2.1x)"))
    return rows


def bounds() -> List[Row]:
    from repro.core import PartitionConfig
    from repro.core.bounds import (minimal_lower_bound, standard_lower_bound,
                                   unlimited_lower_bound)

    cfg = PartitionConfig(1024, 32)
    return [
        ("bounds/unlimited_lb_bits", 0.0,
         f"{unlimited_lower_bound(cfg)} (paper: 443+; implemented 607)"),
        ("bounds/standard_lb_bits", 0.0,
         f"{standard_lower_bound(cfg)} (paper: 46; implemented 79)"),
        ("bounds/minimal_lb_bits", 0.0,
         f"{minimal_lower_bound(cfg)} (paper: 25; implemented 36)"),
    ]


def sim_throughput() -> List[Row]:
    """Real wall-clock throughput of the crossbar simulator (jnp backend)."""
    import jax
    import numpy as np

    from repro.pim import executor as ex
    from repro.pim.multpim import build_multpim

    pm = build_multpim(32, model="minimal")
    mc = pm.program.to_microcode()
    rows_per, crossbars = 1024, 8
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1 << 32, size=(crossbars, rows_per), dtype=np.uint64)
    b = rng.integers(0, 1 << 32, size=(crossbars, rows_per), dtype=np.uint64)
    state = ex.blank_state(crossbars, 1024, rows_per)
    state = ex.write_numbers(state, pm.a_cols, a)
    state = ex.write_numbers(state, pm.b_cols, b)
    mc_dev = jax.numpy.asarray(mc)
    out = ex.execute(jax.numpy.array(state), mc_dev)  # compile + warm
    out.block_until_ready()
    t0 = time.time()
    reps = 3
    for _ in range(reps):
        out = ex.execute(jax.numpy.array(state), mc_dev)
        out.block_until_ready()
    dt = (time.time() - t0) / reps
    mults = crossbars * rows_per
    gate_evals = mc.shape[0] * mults
    return [
        ("sim/exec_32b_mult_8x1024rows", dt * 1e6,
         f"{mults / dt:.0f} mults/s"),
        ("sim/gate_throughput", dt * 1e6, f"{gate_evals / dt:.3g} gate-evals/s"),
    ]


def dot_accumulate() -> List[Row]:
    """Beyond-paper: carry-save vs ripple accumulation in the PIM dot."""
    from repro.pim.matmul import build_dot

    rows: List[Row] = []
    for acc in ("ripple", "carry_save"):
        st = build_dot(8, 8, model="minimal", accumulate=acc).program.stats()
        rows.append((f"dot8x8b/{acc}_cycles", 0.0, str(st.cycles)))
    r = build_dot(8, 8, model="minimal", accumulate="ripple").program.stats()
    c = build_dot(8, 8, model="minimal", accumulate="carry_save").program.stats()
    rows.append(("dot8x8b/carry_save_speedup", 0.0,
                 f"{r.cycles / c.cycles:.2f}x"))
    return rows


def engine_compile_cache() -> List[Row]:
    """Compile-once/execute-many: cold build vs engine cache hit."""
    from repro.pim import engine

    engine.clear_cache()
    us_cold, art = _timed(lambda: engine.compile_dot(8, 8, model="minimal"))
    us_hit, art2 = _timed(lambda: engine.compile_dot(8, 8, model="minimal"))
    assert art is art2, "cache hit must return the same artifact"
    return [
        ("engine/compile_dot_cold", us_cold,
         f"{art.microcode.shape[0]} microcode rows"),
        ("engine/compile_dot_hit", us_hit,
         f"{us_cold / max(us_hit, 0.1):.0f}x faster than cold build"),
    ]


def pim_lm_gemm() -> List[Row]:
    """PIM cost model over the assigned archs' core GEMM (one FFN layer)."""
    import repro.configs as configs
    from repro.pim.cost_model import gemm_cost

    rows: List[Row] = []
    for name in ("qwen1.5-0.5b", "gemma-7b", "arctic-480b", "xlstm-1.3b"):
        cfg = configs.get(name)
        ff = cfg.moe_d_ff if cfg.n_experts else cfg.d_ff
        ff = ff or int(cfg.xlstm_proj_factor * cfg.d_model)
        g_min = gemm_cost(4096, cfg.d_model, ff, n_bits=8, model="minimal")
        g_base = gemm_cost(4096, cfg.d_model, ff, n_bits=8, model="baseline")
        rows.append((f"pim_gemm/{name}", 0.0,
                     f"minimal {g_min.time_s * 1e3:.2f}ms vs serial-PIM "
                     f"{g_base.time_s * 1e3:.2f}ms "
                     f"({g_base.time_s / g_min.time_s:.1f}x); control "
                     f"{g_min.control_bits / 8e3:.0f}KB/GEMM"))
    # 32-bit fixed point: the multiply dominates and the paper's full
    # partition speedup carries through end-to-end
    g32m = gemm_cost(1024, 512, 1024, n_bits=32, model="minimal")
    g32b = gemm_cost(1024, 512, 1024, n_bits=32, model="baseline")
    rows.append(("pim_gemm/32bit_fixed_point", 0.0,
                 f"minimal {g32m.time_s * 1e3:.2f}ms vs serial-PIM "
                 f"{g32b.time_s * 1e3:.2f}ms "
                 f"({g32b.time_s / g32m.time_s:.1f}x)"))
    return rows


def serving_throughput() -> List[Row]:
    """Continuous-batching decode throughput on a synthetic Poisson trace.

    One scheduler per batch size, warmed up (prefill bucket + decode step
    compiled) before the measured trace so tokens/sec reflects steady
    state.  ``batch 1`` is sequential request handling — one request
    occupies the engine end-to-end — so the batch>=1 ratios are the
    continuous-batching win.
    """
    import jax

    import repro.configs as configs
    from repro.models import model_lib as M
    from repro.serving import (Scheduler, ServingConfig, ServingMetrics,
                               synthetic_requests)

    cfg = configs.get("qwen1.5-0.5b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    gen = 8
    rows: List[Row] = []
    tps = {}
    for batch in (1, 4, 16):
        # deep enough trace that the fill/drain ramps are amortized and the
        # window measures full-slot steady state, even at batch 16
        n_req = max(12, 4 * batch)
        sched = Scheduler(params, cfg,
                          ServingConfig(max_batch=batch, prompt_bucket=16))
        warm = synthetic_requests(max(2, batch), vocab_size=cfg.vocab_size,
                                  prompt_lens=[8], max_new_tokens=2, seed=99,
                                  start_time=sched.clock())
        for r in warm:
            sched.submit_request(r)
        sched.run()
        sched.metrics = ServingMetrics()  # timed window excludes compiles
        reqs = synthetic_requests(n_req, vocab_size=cfg.vocab_size,
                                  prompt_lens=[5, 8, 12, 16],
                                  max_new_tokens=gen, rate=200.0, seed=0,
                                  start_time=sched.clock())
        for r in reqs:
            sched.submit_request(r)
        sched.run()
        assert sched.decode_traces == 1, "steady-state decode recompiled"
        s = sched.metrics.summary()
        tps[batch] = s["tokens_per_s"]
        rows.append((f"serving/continuous_batch{batch}_tok_s",
                     s["mean_tpot_s"] * 1e6,
                     f"{s['tokens_per_s']:.1f} tok/s "
                     f"(TTFT {s['mean_ttft_s'] * 1e3:.0f}ms, "
                     f"{s['n_finished']}/{n_req} reqs)",
                     {"tok_s": round(s["tokens_per_s"], 2),
                      "floor": round(s["tokens_per_s"] / 4, 1)}))
    for batch in (4, 16):
        rows.append((f"serving/continuous_vs_sequential_batch{batch}", 0.0,
                     f"{tps[batch] / tps[1]:.2f}x aggregate tok/s vs "
                     f"one-request-at-a-time",
                     {"ratio": round(tps[batch] / tps[1], 3),
                      # smoke-scale ratio noise reaches ~1.0 on a 2-core
                      # box, and a fully-broken batcher also lands at ~1.0
                      # (sequential IS max_batch=1 of the same scheduler),
                      # so the floor can only police "far below the
                      # oracle"; the benchmark's own decode_traces==1
                      # assertion and tests/test_serving.py carry the
                      # sharp regression signal
                      "floor": 0.8}))
    return rows


def serving_paged() -> List[Row]:
    """Paged vs contiguous KV pool on a long-tail prompt trace.

    Same Poisson trace through both pool layouts: tokens must be
    bit-identical (the layout is a memory optimization, never a semantic
    one), decode stays at one trace, and the paged pool's *peak* KV bytes
    — blocks actually reserved — must land strictly below the contiguous
    pool's static ``max_batch * max_len`` reservation, because the
    long-tail prompts don't all need worst-case capacity at once.  A
    third row serves a sliding-window variant end-to-end (ring over the
    block list), which the contiguous pool cannot do at all.
    """
    import jax
    import numpy as np

    import repro.configs as configs
    from repro.models import model_lib as M
    from repro.serving import (Scheduler, ServingConfig, ServingMetrics,
                               synthetic_requests)

    cfg = configs.get("qwen1.5-0.5b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch, n_req = 4, 16
    # long-tail: mostly short prompts, a few near pool capacity
    trace = dict(vocab_size=cfg.vocab_size, prompt_lens=[4, 6, 8, 40],
                 max_new_tokens=8, rate=200.0, seed=7)

    def warm(sched):
        """Compile prefill buckets + decode outside the timed window (same
        steady-state convention as serving_throughput)."""
        for r in synthetic_requests(batch, vocab_size=cfg.vocab_size,
                                    prompt_lens=[4, 40], max_new_tokens=2,
                                    seed=99, start_time=sched.clock()):
            sched.submit_request(r)
        sched.run()
        sched.metrics = ServingMetrics()

    rows: List[Row] = []
    outs, peaks, tps = {}, {}, {}
    for paged in (False, True):
        name = "paged" if paged else "contiguous"
        sched = Scheduler(params, cfg,
                          ServingConfig(max_batch=batch, prompt_bucket=8,
                                        paged=paged, block_size=8))
        warm(sched)
        reqs = synthetic_requests(n_req, start_time=sched.clock(), **trace)
        for r in reqs:
            sched.submit_request(r)
        res = sched.run()
        outs[paged] = [res[r.rid] for r in reqs]  # rids differ across runs
        assert sched.decode_traces == 1, f"{name} decode recompiled"
        s = sched.metrics.summary()
        peaks[paged], tps[paged] = s["peak_kv_bytes"], s["tokens_per_s"]
        rows.append((f"serving_paged/{name}_tok_s",
                     s["mean_tpot_s"] * 1e6,
                     f"{s['tokens_per_s']:.1f} tok/s, peak KV "
                     f"{s['peak_kv_bytes'] / 1024:.0f}KiB",
                     {"tok_s": round(s["tokens_per_s"], 2),
                      "floor": round(s["tokens_per_s"] / 4, 1)}))
    same = all(np.array_equal(a, b)
               for a, b in zip(outs[False], outs[True]))
    assert same, "paged pool changed generated tokens"
    assert peaks[True] < peaks[False], \
        "paged peak KV must undercut the contiguous reservation"
    rows.append(("serving_paged/peak_kv_bytes_vs_contiguous", 0.0,
                 f"{peaks[True] / peaks[False]:.2f}x of the "
                 f"max_batch*max_len reservation ({peaks[True]:.0f} vs "
                 f"{peaks[False]:.0f} bytes), tokens bit-identical",
                 {"bit_exact": bool(same)}))

    wcfg = cfg.scaled(sliding_window=16)
    wparams = M.init_params(wcfg, jax.random.PRNGKey(0))
    sched = Scheduler(wparams, wcfg,
                      ServingConfig(max_batch=batch, prompt_bucket=8,
                                    block_size=8))
    warm(sched)
    for r in synthetic_requests(n_req, start_time=sched.clock(), **trace):
        sched.submit_request(r)
    sched.run()
    s = sched.metrics.summary()
    rows.append(("serving_paged/sliding_window_tok_s",
                 s["mean_tpot_s"] * 1e6,
                 f"{s['tokens_per_s']:.1f} tok/s (window 16 as block ring; "
                 f"peak KV {s['peak_kv_bytes'] / 1024:.0f}KiB, "
                 f"{sched.decode_traces} decode compiles)",
                 {"tok_s": round(s["tokens_per_s"], 2),
                  "floor": round(s["tokens_per_s"] / 4, 1)}))
    return rows


def serving_prefix() -> List[Row]:
    """Prefix caching on a shared-system-prompt trace, per PIM mode.

    Every request carries one long shared system prompt plus a short
    divergent tail.  Per mode {xla, quant, quant_tp} the same trace runs
    twice through the paged pool — prefix cache off (cold) and on (warm,
    with the trie pre-seeded and the tail-resume prefill pre-compiled by
    a warm-up pass, mirroring the steady-state convention of the other
    serving suites) — and three rows land per mode:

    - ``warm_ttft_speedup``: cold mean TTFT / warm mean TTFT, gated at
      the acceptance floor of 2.0 — trie hits prefill only the divergent
      tail, so most of the prompt's prefill compute (and its queueing
      shadow on later arrivals) disappears;
    - ``tokens_bit_exact``: warm generations must match the
      no-prefix-cache paged pool token for token (sharing blocks is a
      memory optimization, never a semantic one);
    - ``blocks_shared``: fraction of prompt tokens served straight from
      the index (deterministic for this trace, floor 0.9), plus the peak
      shared-block count.

    quant_tp runs under the 8-device "model" mesh (same idiom as the
    serving tests); decode stays at one trace in every configuration.
    """
    import contextlib

    import jax
    import numpy as np

    import repro.configs as configs
    from repro.dist import context as dctx
    from repro.launch.mesh import make_mesh
    from repro.models import model_lib as M
    from repro.serving import (Scheduler, ServingConfig, ServingMetrics,
                               synthetic_requests)

    # heavy enough that prefill compute (not dispatch) dominates TTFT, the
    # shared prefix long enough that the cold run's quadratic attention
    # over it dwarfs the warm path's linear concat-and-attend over the same
    # prefix (at short prefixes the two nearly cancel on CPU), and
    # d_model/d_ff divide the 8-rank mesh for the quant_tp tiles
    base = configs.get("qwen1.5-0.5b").smoke().scaled(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=1024, vocab_size=512, pad_vocab_multiple=8, loss_chunk=64,
        max_seq_len=544)
    # one admission wave (n_req == batch): every measured TTFT is pure
    # prefill-side latency, not decode-wait from an earlier wave that the
    # cache cannot help with — the ratio then measures the skipped prefill
    shared, tails, gen, batch, n_req = 512, [8, 12], 4, 4, 4
    bs = 16
    trace = dict(vocab_size=base.vocab_size, prompt_lens=tails,
                 max_new_tokens=gen, seed=13, shared_prefix_len=shared)

    def run(sched):
        # warm-up: two shared-prefix requests — the first compiles the
        # cold prompt bucket (and, with the index on, seeds the trie),
        # the second compiles the tail-resume shapes — so the measured
        # window holds no compiles and every measured admit can hit
        for r in synthetic_requests(2, rate=0.0, start_time=sched.clock(),
                                    **trace):
            sched.submit_request(r)
        sched.run()
        sched.metrics = ServingMetrics()
        reqs = synthetic_requests(n_req, rate=0.0,
                                  start_time=sched.clock(), **trace)
        for r in reqs:
            sched.submit_request(r)
        res = sched.run()
        assert sched.decode_traces == 1, "prefix suite decode recompiled"
        return [res[r.rid] for r in reqs], sched.metrics.summary()

    rows: List[Row] = []
    for mode in ("xla", "quant", "quant_tp"):
        cfg = base if mode == "xla" else base.scaled(pim_mode=mode)
        ctx = (dctx.use_mesh(make_mesh((8,), ("model",)))
               if mode == "quant_tp" else contextlib.nullcontext())
        with ctx:
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            outs, summaries = {}, {}
            for prefix_on in (False, True):
                sched = Scheduler(params, cfg,
                                  ServingConfig(max_batch=batch,
                                                prompt_bucket=bs,
                                                paged=True, block_size=bs,
                                                prefix_cache=prefix_on))
                outs[prefix_on], summaries[prefix_on] = run(sched)
        cold, warm = summaries[False], summaries[True]
        same = all(np.array_equal(a, b)
                   for a, b in zip(outs[False], outs[True]))
        assert same, f"prefix cache changed generated tokens under {mode}"
        speedup = cold["mean_ttft_s"] / warm["mean_ttft_s"]
        reused = warm["prefix_tokens_reused"]
        total_prompt = sum(shared + t for t in
                           (tails * n_req)[:n_req])
        rows.append((f"prefix/{mode}_warm_ttft_speedup",
                     warm["mean_ttft_s"] * 1e6,
                     f"warm TTFT {warm['mean_ttft_s'] * 1e3:.0f}ms vs cold "
                     f"{cold['mean_ttft_s'] * 1e3:.0f}ms = {speedup:.2f}x "
                     f"(hit rate {warm['prefix_hit_rate'] * 100:.0f}%; "
                     f"acceptance floor 2x)",
                     {"pim_mode": mode,
                      "mesh": "model=8" if mode == "quant_tp" else "1",
                      "ratio": round(speedup, 3), "floor": 2.0}))
        rows.append((f"prefix/{mode}_tokens_bit_exact", 0.0,
                     f"{n_req} shared-prefix requests bit-identical to the "
                     f"no-prefix-cache paged pool",
                     {"pim_mode": mode,
                      "mesh": "model=8" if mode == "quant_tp" else "1",
                      "bit_exact": bool(same)}))
        rows.append((f"prefix/{mode}_blocks_shared", 0.0,
                     f"{reused:.0f}/{total_prompt} prompt tokens served "
                     f"from the index (peak {warm['peak_blocks_shared']:.0f}"
                     f" shared blocks, {warm['cow_copies']:.0f} COW copies)",
                     {"pim_mode": mode,
                      "mesh": "model=8" if mode == "quant_tp" else "1",
                      "ratio": round(reused / total_prompt, 3),
                      "floor": 0.9}))
    return rows


def serving_chunked() -> List[Row]:
    """Chunked + packed prefill vs monolithic prefill, per PIM mode.

    A bursty trace — a dozen short prompts with staggered generation
    budgets plus one very long prompt dropped mid-queue — runs twice per
    mode {xla, quant, quant_tp} through the paged pool: whole-prompt
    prefill (a slot admitting the long prompt stalls every decoding slot
    for one monolithic prefill) and chunked+packed
    (``prefill_chunk=64, step_token_budget=64, packed_prefill=True`` — no
    step runs more than one chunk's worth of prefill).  Both runs are
    warmed first (compiles pinned outside the measured window; metrics
    reset) and decode must hold at exactly one trace.  Rows per mode:

    - ``p99_tpot_improvement``: unchunked p99 inter-token gap / chunked
      p99, gated at the acceptance floor 2.0 (the issue's "chunked p99
      TPOT <= 0.5x unchunked") — the long prefill is the tail gap, and
      chunking bounds it by one 64-token chunk;
    - ``tokens_bit_exact``: chunked+packed generations must match the
      whole-prefill run token for token (scheduling is a latency
      optimization, never a semantic one);

    plus one descriptive ``packed_prefill_calls`` row (xla run's chunk /
    pack counters; no gate).
    """
    import contextlib

    import jax
    import numpy as np

    import repro.configs as configs
    from repro.dist import context as dctx
    from repro.launch.mesh import make_mesh
    from repro.models import model_lib as M
    from repro.serving import Scheduler, ServingConfig, ServingMetrics
    from repro.serving.queue import make_request

    # same heavy-enough smoke scaling as the prefix suite: prefill compute
    # (not dispatch) dominates the stall, and d_model/d_ff divide the
    # 8-rank mesh for the quant_tp tiles
    base = configs.get("qwen1.5-0.5b").smoke().scaled(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=1024, vocab_size=512, pad_vocab_multiple=8, loss_chunk=64,
        max_seq_len=672)
    # the long prompt is sized so its monolithic prefill (quadratic in
    # plen) dwarfs the per-step fixed costs both runs share (the decode
    # step itself sits inside every measured gap); the chunked run's
    # worst gap grows only linearly (one 64-token chunk over the prefix)
    bs, chunk, batch = 16, 64, 4
    long_plen, long_at = 640, 6

    def mk_trace(seed):
        rng = np.random.default_rng(seed)
        reqs = []
        # staggered budgets de-synchronize slot completion, so the long
        # admit lands while other slots are mid-decode — the stall the
        # unchunked run must pay and the chunked run must bound
        for i in range(12):
            plen = (8, 12, 16, 12)[i % 4]
            reqs.append(make_request(
                rng.integers(0, base.vocab_size, size=plen).astype(np.int32),
                (6, 8, 10, 12)[i % 4], arrival_time=0.0))
        reqs.insert(long_at, make_request(
            rng.integers(0, base.vocab_size,
                         size=long_plen).astype(np.int32),
            8, arrival_time=0.0))
        return reqs

    def run(sched):
        # warm-up replay compiles every shape this trace touches (prompt
        # buckets, each chunk-resume (prefix, tail) pair, packed lengths,
        # decode) so the measured gaps hold no compiles
        for r in mk_trace(7):
            sched.submit_request(r)
        sched.run()
        sched.metrics = ServingMetrics()
        reqs = mk_trace(7)
        for r in reqs:
            sched.submit_request(r)
        res = sched.run()
        assert sched.decode_traces == 1, "chunked suite decode recompiled"
        return [res[r.rid] for r in reqs], sched.metrics.summary()

    rows: List[Row] = []
    counters = None
    for mode in ("xla", "quant", "quant_tp"):
        cfg = base if mode == "xla" else base.scaled(pim_mode=mode)
        ctx = (dctx.use_mesh(make_mesh((8,), ("model",)))
               if mode == "quant_tp" else contextlib.nullcontext())
        with ctx:
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            outs, summaries = {}, {}
            for chunked_on in (False, True):
                scfg = (ServingConfig(max_batch=batch, prompt_bucket=bs,
                                      paged=True, block_size=bs,
                                      prefill_chunk=chunk,
                                      step_token_budget=chunk,
                                      packed_prefill=True)
                        if chunked_on else
                        ServingConfig(max_batch=batch, prompt_bucket=bs,
                                      paged=True, block_size=bs))
                sched = Scheduler(params, cfg, scfg)
                outs[chunked_on], summaries[chunked_on] = run(sched)
        mono, chk = summaries[False], summaries[True]
        same = all(np.array_equal(a, b)
                   for a, b in zip(outs[False], outs[True]))
        assert same, f"chunked prefill changed generated tokens under {mode}"
        ratio = mono["p99_tpot_s"] / chk["p99_tpot_s"]
        rows.append((f"chunked/{mode}_p99_tpot_improvement",
                     chk["p99_tpot_s"] * 1e6,
                     f"chunked p99 TPOT {chk['p99_tpot_s'] * 1e3:.0f}ms vs "
                     f"monolithic {mono['p99_tpot_s'] * 1e3:.0f}ms = "
                     f"{ratio:.2f}x ({chk['prefill_chunks']} chunks; "
                     f"acceptance floor 2x)",
                     {"pim_mode": mode,
                      "mesh": "model=8" if mode == "quant_tp" else "1",
                      "ratio": round(ratio, 3), "floor": 2.0}))
        rows.append((f"chunked/{mode}_tokens_bit_exact", 0.0,
                     f"13 bursty requests bit-identical to whole-prompt "
                     f"prefill",
                     {"pim_mode": mode,
                      "mesh": "model=8" if mode == "quant_tp" else "1",
                      "bit_exact": bool(same)}))
        if mode == "xla":
            counters = chk
    rows.append(("chunked/packed_prefill_calls", 0.0,
                 f"{counters['packed_prefills']} packed prefill call(s), "
                 f"{counters['prefill_chunks']} chunk prefills over the "
                 f"xla run (descriptive; no gate)"))
    return rows


def serving_replica() -> List[Row]:
    """Multi-replica router: scaling, dispatch A/B, and the kill drill.

    Replicas are independent hosts in a data-parallel fleet; this
    process steps them sequentially, so throughput is measured on the
    router's ``FleetClock`` — each replica's step is wall-timed in its
    own clock segment and fleet time advances **once per round by the
    slowest segment**, the wall-clock law of independent hosts (the
    serial dispatch loop is the cheap shared controller).  Three
    scenario groups land as rows:

    - ``scaling_replica{1,2,4}_tok_s`` + ``scaling_4x_vs_1``: the same
      closed 32-request trace through 1/2/4 replicas over the 8-device
      topology (warmed per replica so compiles stay out of the window);
      the replica=4 / replica=1 ratio gates at the 2.5x acceptance
      floor.  On this forced-CPU topology the ratio lands *super*-linear
      (~5-7x): rounds shrink ~4x with the fleet, and the replica=1
      baseline additionally pays 8-way replicated dispatch for its
      whole-mesh engine while 2-device replicas pay only 2-way — real
      fleets see the sub-linear side of 4x, so the floor polices the
      scaling direction, not the exact multiple.
    - ``affinity_hit_rate`` vs ``round_robin_hit_rate``: a 3-tenant
      shared-system-prompt trace over 4 prefix-cached replicas.  Round
      robin smears every tenant's prefix across all four tries (each
      replica pays its own cold miss per tenant); ``prefix_affinity``
      pins each tenant to one replica, so only the first request per
      tenant misses — aggregate ``prefix_hit_rate`` gates at 0.7 (the
      deterministic values are ~0.875 vs ~0.5).
    - ``kill_mid_trace_zero_lost``: replica 0 is killed mid-trace by an
      injected ``FailurePlan``; its in-flight requests drain back to
      the global queue and restart elsewhere.  The full trace must
      complete with zero lost/duplicated requests and per-request
      tokens **bit-identical** to a single-scheduler oracle run (greedy
      decode is deterministic given the prompt) — gated as a
      ``bit_exact`` boolean.
    """
    import jax
    import numpy as np

    import repro.configs as configs
    from repro.models import model_lib as M
    from repro.serving import (FailurePlan, Router, RouterConfig, Scheduler,
                               ServingConfig, ServingMetrics,
                               synthetic_requests)

    cfg = configs.get("qwen1.5-0.5b").smoke().scaled(max_seq_len=64)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    devices = jax.devices()
    scfg = ServingConfig(max_batch=4, prompt_bucket=8, paged=True,
                         block_size=8)
    n_req, gen = 32, 8
    trace = dict(vocab_size=cfg.vocab_size, prompt_lens=[6, 10, 14],
                 max_new_tokens=gen, rate=0.0, seed=3)

    def fleet_run(n_replicas, *, policy="least_loaded", scfg=scfg,
                  reqs=None, plan=None, warm=True):
        router = Router(params, cfg, scfg,
                        RouterConfig(n_replicas=n_replicas, policy=policy),
                        devices=devices, failure_plan=plan)
        if warm:
            # compile every prompt bucket + decode on EVERY replica
            # outside the timed window: least-loaded dispatch over idle
            # replicas cycles i%n, and 3 prompt lengths with n in {1,2,4}
            # are coprime, so 3n warm requests cover the full
            # (replica, bucket) product — a bucket first compiled
            # mid-window would land in that round's max and poison the
            # fleet-clock scaling ratio
            for r in synthetic_requests(3 * n_replicas,
                                        vocab_size=cfg.vocab_size,
                                        prompt_lens=[6, 10, 14],
                                        max_new_tokens=2, seed=99,
                                        start_time=router.clock()):
                router.submit_request(r)
            router.run()
            router.results.clear()
            for rep in router.replicas:
                rep.sched.metrics = ServingMetrics()
        if reqs is None:
            reqs = synthetic_requests(n_req, start_time=router.clock(),
                                      **trace)
        for r in reqs:
            router.submit_request(r)
        res = router.run()
        return router, reqs, res

    rows: List[Row] = []
    tps: Dict[int, float] = {}
    for n in (1, 2, 4):
        router, reqs, res = fleet_run(n)
        assert len(res) == n_req, f"replica={n} lost requests"
        s = router.metrics().summary()
        tps[n] = s["tokens_per_s"]
        per = "/".join(f"{v:.0f}" for _, v in
                       sorted(s["per_replica_tok_s"].items()))
        rows.append((f"replica/scaling_replica{n}_tok_s", 0.0,
                     f"{s['tokens_per_s']:.1f} fleet tok/s over {n} "
                     f"replica(s) of {8 // n} devices (per-replica {per})",
                     {"mesh": f"replicas={n}",
                      "tok_s": round(s["tokens_per_s"], 2),
                      "floor": round(s["tokens_per_s"] / 4, 1)}))
    ratio = tps[4] / tps[1]
    rows.append(("replica/scaling_4x_vs_1", 0.0,
                 f"{ratio:.2f}x fleet tok/s at replica=4 vs replica=1 "
                 f"(acceptance floor 2.5x; fleet clock: a round costs its "
                 f"slowest replica)",
                 {"mesh": "replicas=4", "ratio": round(ratio, 3),
                  "floor": 2.5}))

    # --- dispatch A/B: per-tenant system prompts over prefix-cached
    # replicas.  3 tenants on 4 replicas breaks the i%4 / i%3 aliasing, so
    # round robin genuinely smears each tenant across all replicas.
    scfg_px = ServingConfig(max_batch=2, prompt_bucket=8, paged=True,
                            block_size=16, prefix_cache=True)
    tenant_trace = dict(vocab_size=cfg.vocab_size, prompt_lens=[8, 12],
                        max_new_tokens=4, seed=5, shared_prefix_len=32,
                        n_tenants=3)
    hit = {}
    for pol in ("round_robin", "prefix_affinity"):
        router, _, res = fleet_run(
            4, policy=pol, scfg=scfg_px, warm=False,
            reqs=synthetic_requests(24, start_time=0.0, **tenant_trace))
        assert len(res) == 24, f"{pol} lost requests"
        hit[pol] = router.metrics().summary()["prefix_hit_rate"]
    assert hit["prefix_affinity"] > hit["round_robin"], \
        "prefix_affinity must beat round_robin on the multi-tenant trace"
    rows.append(("replica/round_robin_hit_rate", 0.0,
                 f"{hit['round_robin'] * 100:.0f}% aggregate prefix hit "
                 f"rate (each tenant cold-misses once per replica)",
                 {"mesh": "replicas=4"}))
    rows.append(("replica/affinity_hit_rate", 0.0,
                 f"{hit['prefix_affinity'] * 100:.0f}% aggregate prefix "
                 f"hit rate vs round robin "
                 f"{hit['round_robin'] * 100:.0f}% (3 tenants pinned to "
                 f"one trie each; floor 0.7)",
                 {"mesh": "replicas=4",
                  "ratio": round(hit["prefix_affinity"], 3), "floor": 0.7}))

    # --- kill drill: bit-exact vs a single-scheduler oracle
    oracle = Scheduler(params, cfg, scfg)
    oreqs = synthetic_requests(n_req, start_time=oracle.clock(), **trace)
    for r in oreqs:
        oracle.submit_request(r)
    orun = oracle.run()
    kreqs = synthetic_requests(n_req, start_time=0.0, **trace)
    router, _, res = fleet_run(
        2, reqs=kreqs, warm=False,
        plan=FailurePlan(kill_replica=0, at_step=6))
    zero_lost = (len(res) == n_req
                 and set(res) == {r.rid for r in kreqs})
    exact = zero_lost and all(
        np.array_equal(res[k.rid], orun[o.rid])
        for k, o in zip(kreqs, oreqs))
    s = router.metrics().summary()
    migrated = s["rebalanced_requests"]
    assert migrated > 0, "the kill must actually catch in-flight requests"
    rows.append(("replica/kill_mid_trace_zero_lost", 0.0,
                 f"replica 0 killed at step 6: {n_req}/{n_req} completed, "
                 f"{migrated} drained+requeued, "
                 f"{s['replica_restarts']} respawn, tokens bit-identical "
                 f"to the single-scheduler oracle",
                 {"mesh": "replicas=2",
                  "bit_exact": bool(zero_lost and exact)}))
    return rows


def spec_decode() -> List[Row]:
    """Self-speculative decode vs plain decode, per verify mode.

    A decode-heavy trace (short prompts, long generation budgets) runs
    twice per verify mode — plain decode and speculative
    (``draft_mode="quant"``, ``draft_k=4``) — through the paged pool.
    Both runs are warmed first (prompt bucket, the ``(B, 1)`` plain and
    draft steps, and the ``(B, k)`` verify step all compile outside the
    measured window; metrics reset), then the measured trace must hold
    decode at **one** trace per jit (``decode_traces == 1`` and
    ``draft_traces == 1`` — acceptance-length churn never recompiles).
    Rows per mode:

    - ``tokens_bit_exact`` (gated): the speculative run's generations
      must match plain decode token for token.  Greedy acceptance makes
      this hold for *any* draft/verify pairing — the xla row pairs a
      float verify with an integer draft precisely so acceptance is
      imperfect and the exactness claim is non-trivial;
    - ``speculative_vs_plain`` (ratio): measured tok/s speedup.  Only
      the ``pim_sim`` row carries the acceptance-criterion floor 1.3 —
      the simulator's per-gate interpreter overhead dominates its
      vectorized row math, so verifying ``k`` rows costs about one
      single-row step and ~``k`` tokens ride one expensive step plus
      ``k - 1`` cheap quant drafts (the same latency-hiding batching
      PartitionPIM's partitions buy in hardware).  The xla and quant_tp
      rows are descriptive (floor 0.05 = "ran at all"): their per-step
      cost already scales with the verified width, so the k - 1 extra
      draft steps make speculation a net loss at smoke scale on CPU —
      the rows document that speculation is a *pim_sim* (amortizable
      verify) optimization, not a universal one;

    plus one descriptive ``mean_accept_len`` row (pim_sim run's
    acceptance histogram; quant drafts against an integer verify mode
    agree on nearly every logit, so it sits near ``draft_k``).
    """
    import contextlib

    import jax
    import numpy as np

    import repro.configs as configs
    from repro.dist import context as dctx
    from repro.launch.mesh import make_mesh
    from repro.models import model_lib as M
    from repro.serving import (Scheduler, ServingConfig, ServingMetrics,
                               synthetic_requests)

    # xla/quant_tp: heavy enough that step compute dominates dispatch and
    # d_model/d_ff divide the 8-rank mesh (same scaling as the chunked
    # suite); pim_sim: the bit-accurate crossbar interpreter needs the
    # tiny mode-suite dims to decode whole traces in seconds
    heavy = configs.get("qwen1.5-0.5b").smoke().scaled(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=1024, vocab_size=512, pad_vocab_multiple=8, loss_chunk=64,
        max_seq_len=64)
    tiny = configs.get("qwen1.5-0.5b").smoke().scaled(
        n_layers=1, pattern=("ad",), d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=64, pad_vocab_multiple=8,
        loss_chunk=8, max_seq_len=48)
    draft_mode, k, batch, bs = "quant", 4, 4, 8
    rows: List[Row] = []
    accept = None
    for mode, floor in (("xla", 0.05), ("quant_tp", 0.05),
                        ("pim_sim", 1.3)):
        base = tiny if mode == "pim_sim" else heavy
        cfg = base.scaled(pim_mode=mode)
        gen = 12 if mode == "pim_sim" else 16
        n_req = 8
        trace = dict(vocab_size=cfg.vocab_size, prompt_lens=[4, 6, 8],
                     max_new_tokens=gen, rate=0.0, seed=11)
        ctx = (dctx.use_mesh(make_mesh((8,), ("model",)))
               if mode == "quant_tp" else contextlib.nullcontext())
        with ctx:
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            outs, tps, summaries = {}, {}, {}
            for spec_on in (False, True):
                scfg = ServingConfig(max_batch=batch, prompt_bucket=bs,
                                     paged=True, block_size=bs,
                                     speculative=spec_on,
                                     draft_mode=draft_mode, draft_k=k)
                sched = Scheduler(params, cfg, scfg)
                for r in synthetic_requests(batch, start_time=sched.clock(),
                                            **dict(trace, max_new_tokens=2,
                                                   seed=99)):
                    sched.submit_request(r)
                sched.run()
                sched.metrics = ServingMetrics()
                reqs = synthetic_requests(n_req, start_time=sched.clock(),
                                          **trace)
                for r in reqs:
                    sched.submit_request(r)
                res = sched.run()
                assert sched.decode_traces == 1, \
                    f"{mode} spec={spec_on} decode recompiled"
                if spec_on:
                    assert sched.draft_traces == 1, \
                        f"{mode} draft step recompiled"
                outs[spec_on] = [res[r.rid] for r in reqs]
                summaries[spec_on] = sched.metrics.summary()
                tps[spec_on] = summaries[spec_on]["tokens_per_s"]
        same = all(np.array_equal(a, b)
                   for a, b in zip(outs[False], outs[True]))
        assert same, f"speculative decode changed tokens under {mode}"
        mesh_s = "model=8" if mode == "quant_tp" else "1"
        ratio = tps[True] / tps[False]
        s = summaries[True]
        rows.append((f"spec/{mode}_tokens_bit_exact", 0.0,
                     f"{n_req} requests bit-identical to plain {mode} "
                     f"decode (draft {draft_mode}, k={k}; accept "
                     f"{s['accepted_tokens']}/{s['verified_tokens']})",
                     {"pim_mode": mode, "mesh": mesh_s,
                      "bit_exact": bool(same)}))
        rows.append((f"spec/{mode}_speculative_vs_plain",
                     0.0,
                     f"{tps[True]:.1f} vs {tps[False]:.1f} tok/s = "
                     f"{ratio:.2f}x (draft {draft_mode}, k={k}, mean "
                     f"accept len {s['mean_accept_len']:.2f}"
                     + ("; acceptance floor 1.3x)" if mode == "pim_sim"
                        else "; descriptive)"),
                     {"pim_mode": mode, "mesh": mesh_s,
                      "ratio": round(ratio, 3), "floor": floor}))
        if mode == "pim_sim":
            accept = s
    hist = ", ".join(f"{n}: {c}" for n, c in
                     sorted(accept["accept_len_hist"].items()))
    rows.append(("spec/mean_accept_len", 0.0,
                 f"{accept['mean_accept_len']:.2f} of k={k} on the pim_sim "
                 f"run ({accept['accepted_per_step']:.2f} tok/verify step; "
                 f"hist {{{hist}}}; descriptive, no gate)"))
    return rows


def tp_quant_decode() -> List[Row]:
    """Tensor-parallel quant_tp decode vs single-rank quant, model={1,2,4,8}.

    One shared parameter set decodes greedily through the same jitted
    ``decode_step`` under each mesh; model=1 is the single-rank "quant"
    baseline, model>1 runs "quant_tp" (per-rank int8 Pallas tiles over the
    "model" axis, weights device_put onto their ``param_pspecs`` shards).
    Rows record per-rank tile shapes, tok/s per mesh, the model=8 speedup
    ratio (the within-run, machine-independent gate metric), and whether
    the model=8 per-token logits stay inside the quant-path tolerance of
    the single-rank output (``bit_exact``: the int accumulation is
    identical by construction; only float fusion ulps may differ).
    """
    import contextlib

    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.configs as configs
    from repro.dist import context as dctx
    from repro.dist import partitioning as dpart
    from repro.kernels.quant_matmul.tp import tile_summary
    from repro.launch.mesh import make_mesh
    from repro.models import model_lib as M

    # big enough that the per-rank tile shrink dominates step overhead;
    # every sharded dim divides 8
    base = configs.get("qwen1.5-0.5b").smoke().scaled(
        n_layers=2, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
        d_ff=2048, vocab_size=512, pad_vocab_multiple=8, max_seq_len=24,
        loss_chunk=64)
    B, plen, steps = 4, 8, 10
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, base.vocab_size, (B, plen)),
                         jnp.int32)
    params0 = M.init_params(base, jax.random.PRNGKey(0))

    rows: List[Row] = []
    tps: Dict[int, float] = {}
    logits_last: Dict[int, np.ndarray] = {}
    for r in (1, 2, 4, 8):
        mode = "quant" if r == 1 else "quant_tp"
        cfg = base.scaled(pim_mode=mode)
        ctx = (contextlib.nullcontext() if r == 1
               else dctx.use_mesh(make_mesh((r,), ("model",))))
        with ctx:
            mesh = dctx.current_mesh()
            params = params0
            if mesh is not None:
                shardings = dpart.tree_shardings(
                    dpart.param_pspecs(params0, mesh), mesh)
                params = jax.device_put(params0, shardings)
            prefill = jax.jit(lambda p, b, c=cfg: M.prefill(p, b, c))
            logits, caches = prefill(params, {"tokens": prompt})
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            dstep = jax.jit(
                lambda p, t, pos, c, cf=cfg: M.decode_step(p, t, pos, c, cf))
            warm = dstep(params, tok, jnp.int32(plen), caches)
            jax.block_until_ready(warm)
            # best-of-3 windows: the 2-core CI box's thread scheduling adds
            # heavy-tailed noise, and the minimum is the honest estimate of
            # the step cost (each window replays the same greedy stream)
            dt = float("inf")
            for _ in range(3):
                tok_i, c_i, lg = tok, caches, logits
                t0 = time.time()
                for i in range(steps):
                    tok_i, lg, c_i = dstep(params, tok_i,
                                           jnp.int32(plen + i), c_i)
                jax.block_until_ready(tok_i)
                dt = min(dt, time.time() - t0)
        tok_s = B * steps / dt
        tps[r] = tok_s
        logits_last[r] = np.asarray(lg)
        rows.append((f"tp/decode_model{r}_tok_s", dt / steps * 1e6,
                     f"{tok_s:.1f} tok/s (batch {B}, {base.n_layers} "
                     f"layers, d_model {base.d_model})",
                     {"pim_mode": mode, "mesh": f"model={r}",
                      "tok_s": round(tok_s, 2),
                      "floor": round(tok_s / 4, 1)}))
        if r > 1:
            rows.append((f"tp/tiles_model{r}", 0.0,
                         "; ".join(tile_summary(base, r)),
                         {"pim_mode": mode, "mesh": f"model={r}"}))
    ratio = tps[8] / tps[1]
    rows.append(("tp/speedup_model8_vs_quant", 0.0,
                 f"{ratio:.2f}x decode tok/s vs single-rank quant "
                 f"(gate floor 1.5x)",
                 {"pim_mode": "quant_tp", "mesh": "model=8",
                  "ratio": round(ratio, 3), "floor": 1.5}))
    scale = float(np.abs(logits_last[1]).max())
    err = float(np.abs(logits_last[8] - logits_last[1]).max())
    within = err <= 1e-4 * max(scale, 1.0)
    rows.append(("tp/model8_logits_within_quant_tolerance", 0.0,
                 f"max |Δlogit| {err:.2e} vs scale {scale:.2e} "
                 f"(identical int accumulation; float-fusion ulps only)",
                 {"pim_mode": "quant_tp", "mesh": "model=8",
                  "bit_exact": bool(within)}))
    return rows


def autotune_suite() -> List[Row]:
    """Partition autotuner: tuned pick vs hardcoded default per grid point.

    For every (shape, pim_mode) grid point the tuner races the top
    cost-model candidates (partition model x crossbar geometry x chunking
    x state backend) *plus the engine's hardcoded default* in timed
    trials; the pick is the argmin of that race, so
    ``picked_vs_default >= 1.0`` holds by construction — the gate floor
    1.0 therefore polices the tuner's contract ("never slower than not
    tuning"), and any dip below it means the default stopped being in the
    race.  ``pim_mode="raw"`` races the direct-call state backends;
    ``"pim_sim"`` is the jax.pure_callback context, where only the
    jax-free numpy interpreter may run.  Further rows gate the tuned
    path's bit-exactness against the default configuration, the
    tuning-table JSON save/reload roundtrip (format in check.py's
    header), and the cycle counts of the two new multiplier backends vs
    the NOR serial baseline (deterministic simulator measurements).
    """
    import numpy as np

    from repro.pim import autotune, engine

    engine.clear_cache()
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    # K=24 fits one chunk at every geometry; K=96 chunks 3x at 1024
    # columns but fits one program at 2048+ — the geometry trade-off the
    # tuner exists to call
    grid = [((4, 24, 32), "raw"), ((4, 96, 64), "raw"),
            ((4, 96, 64), "pim_sim")]
    for (m, k_dim, o), mode in grid:
        plan = autotune.autotune(k_dim, 8, (m, o), mode, trials=2)
        rows.append((f"autotune/k{k_dim}_{mode}_picked_vs_default", 0.0,
                     f"picked model={plan.model} n_cols={plan.n_cols} "
                     f"chunk={plan.chunk} backend={plan.backend}: "
                     f"{plan.trial_us:.0f}us vs default "
                     f"{plan.default_us:.0f}us = {plan.vs_default:.2f}x "
                     f"(>= 1.0 by construction)",
                     {"pim_mode": mode,
                      "ratio": round(plan.vs_default, 3),
                      "floor": 1.0, "tol": 0.0}))
    # tuned path must compute the identical integer GEMM
    m, k_dim, o = 4, 96, 64
    plan = autotune.autotune(k_dim, 8, (m, o), "raw", trials=0)
    x = rng.integers(0, 256, size=(m, k_dim), dtype=np.uint64)
    w = rng.integers(0, 256, size=(o, k_dim), dtype=np.uint64)
    same = bool(np.array_equal(engine.matmul_int(x, w, 8),
                               engine.matmul_int(x, w, 8, plan=plan)))
    rows.append(("autotune/tuned_bit_exact_vs_default", 0.0,
                 f"tuned ({plan.model}, n_cols={plan.n_cols}, "
                 f"chunk={plan.chunk}) == default minimal/1024 GEMM "
                 f"on {m}x{k_dim}x{o}",
                 {"bit_exact": same}))
    # table persistence: picks survive save -> clear -> load
    import tempfile

    path = os.path.join(tempfile.mkdtemp(), "tuning_table.json")
    before = {p.key: (p.model, p.n_cols, p.chunk, p.backend)
              for k, p in [(None, autotune.autotune(k_dim, 8, (m, o), md,
                                                    trials=0))
                           for md in ("raw", "pim_sim")]}
    n_saved = autotune.save_table(path)
    engine.clear_cache()
    n_loaded = autotune.load_table(path)
    autotune.enable(True)
    survived = all(
        (p := autotune.lookup(k_dim, 8, shape=(m, o), pim_mode=md))
        is not None and (p.model, p.n_cols, p.chunk, p.backend)
        == before[p.key] and p.source == "table"
        for md in ("raw", "pim_sim"))
    info = engine.cache_info()
    rows.append(("autotune/table_roundtrip", 0.0,
                 f"{n_saved} plan(s) saved, {n_loaded} reloaded, picks "
                 f"identical after clear_cache ({info.tune_hits} hits / "
                 f"{info.tune_misses} misses / {info.tune_trials} trials)",
                 {"bit_exact": bool(survived)}))
    # the two new multiplier backends vs the NOR serial baseline
    base = engine.build_multiplier("serial", 32).program.stats().cycles
    for name in ("serial_fast", "compressor42"):
        c = engine.build_multiplier(name, 32).program.stats().cycles
        rows.append((f"autotune/mult_{name}_32b_cycles", 0.0,
                     f"{c} cycles vs NOR serial {base} "
                     f"({base / c:.2f}x; deterministic)",
                     {"ratio": round(base / c, 3), "floor": 1.1}))
    return rows


TABLES = [fig6a_latency, fig6b_control, fig6c_area, energy, bounds,
          sim_throughput, dot_accumulate, engine_compile_cache, pim_lm_gemm]

SUITES = {
    "core": TABLES,
    "serving": [serving_throughput],
    "serving-paged": [serving_paged],
    "prefix": [serving_prefix],
    "prefill-chunked": [serving_chunked],
    "replica": [serving_replica],
    "spec-decode": [spec_decode],
    "tp": [tp_quant_decode],
    "autotune": [autotune_suite],
    "all": TABLES + [serving_throughput, serving_paged, serving_prefix,
                     serving_chunked, serving_replica, spec_decode,
                     tp_quant_decode, autotune_suite],
}


def _meta() -> Dict:
    """Artifact-level provenance: enough to interpret a baseline later."""
    import subprocess

    import jax

    here = os.path.dirname(os.path.abspath(__file__))
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, cwd=here).stdout.strip() or "unknown"
        # numbers minted from an uncommitted tree must not masquerade as
        # the clean HEAD revision
        if commit != "unknown" and subprocess.run(
                ["git", "status", "--porcelain"], capture_output=True,
                text=True, cwd=here).stdout.strip():
            commit += "-dirty"
    except Exception:
        commit = "unknown"
    return {"jax": jax.__version__, "commit": commit,
            "devices": jax.device_count(),
            "platform": jax.default_backend()}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-out", default="",
                    help="machine-readable results path (e.g. "
                         "BENCH_partitionpim.json, as CI passes); empty "
                         "keeps local runs side-effect-free")
    ap.add_argument("--suite", choices=sorted(SUITES), default="core",
                    help="core: paper tables; serving: continuous-batching "
                         "decode throughput; serving-paged: paged-vs-"
                         "contiguous KV pool A/B + sliding-window serving; "
                         "prefix: trie prefix-cache warm-vs-cold TTFT per "
                         "PIM mode; prefill-chunked: chunked+packed prefill "
                         "p99-TPOT A/B per PIM mode; "
                         "replica: multi-replica router scaling/"
                         "affinity/kill-drill; spec-decode: self-"
                         "speculative vs plain decode A/B per verify "
                         "mode; tp: tensor-parallel quant_tp "
                         "vs single-rank quant; all: everything")
    args = ap.parse_args(argv)

    if args.suite in ("tp", "prefix", "prefill-chunked", "replica",
                      "spec-decode", "all"):
        # these tables shard/slice an 8-device topology: force it before
        # anything initializes jax (no-op if already forced)
        from repro.xla_flags import ensure_host_device_count

        ensure_host_device_count(8)

    results = {}
    print("name,us_per_call,derived")
    for table in SUITES[args.suite]:
        for row in table():
            name, us, derived = row[0], row[1], row[2]
            extras = dict(row[3]) if len(row) > 3 else {}
            extras.setdefault("pim_mode", "xla")
            extras.setdefault("mesh", "1")
            extras["suite"] = table.__name__
            print(f"{name},{us:.1f},{derived}")
            results[name] = {"us_per_call": round(us, 1),
                             "derived": derived, **extras}
    results["_meta"] = _meta()
    if args.json_out:
        tmp = args.json_out + ".tmp"
        with open(tmp, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, args.json_out)
        print(f"# wrote {len(results) - 1} entries to {args.json_out}")


if __name__ == "__main__":
    main()
