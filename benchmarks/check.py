"""Benchmark-regression gate: a fresh BENCH artifact vs the committed baseline.

CI runs ``benchmarks/run.py --suite all --json-out BENCH_partitionpim.json``
and then ``python benchmarks/check.py BENCH_partitionpim.json``; a
throughput regression past a row's band (>20% by default; noisy rows
carry explicit ``tol``/``floor`` overrides, see below) or a
bit-exactness flip fails the build.

Rows are keyed on (suite, name, pim_mode) — run.py stamps every row with
its table name and pim mode, so the keys stay stable across PRs even as
suites are reordered or re-grouped.  Gated fields per row:

* ``tok_s``  — absolute decode throughput.  Fails when
  ``fresh < (1 - tolerance) * baseline`` (default tolerance 0.20: the
  ">20% regression" contract).  Absolute tok/s is machine-dependent —
  after a hardware move, refresh the baseline (below) rather than chase
  phantom regressions, or loosen via ``--tolerance`` / ``BENCH_TOLERANCE``.
* ``ratio``  — a within-run speedup (e.g. quant_tp model=8 over
  single-rank quant).  Machine-independent, gated with the same
  tolerance; this is the robust signal when hardware shifts.
* ``bit_exact`` — a baseline ``true`` may never flip to ``false``
  (tokens/logits diverging from their reference path is a correctness
  regression regardless of speed).

A row may carry its own ``tol`` (set by run.py where a benchmark's
measured run-to-run noise exceeds the 20% default — e.g. the smoke-scale
serving rows, whose wall time is scheduler-overhead-dominated); the
*baseline* row's ``tol`` wins over the global tolerance, so loosening a
gate is a reviewed baseline change, never a runtime flag.  A row may
instead carry an absolute ``floor`` — the gate then checks
``fresh >= floor`` and skips the relative comparison: the right contract
for metrics whose run-to-run spread exceeds any sane relative band but
which must clear a hard requirement (the quant_tp model=8 speedup row
floors at 1.5x, the acceptance bar, rather than chasing the
scheduler-noise-inflated ratio of whichever run minted the baseline;
the prefix-cache warm-vs-cold TTFT rows floor at 2.0x — the acceptance
bar for trie-hit admits skipping the shared prompt's prefill — and their
blocks-shared reuse ratios floor at 0.9, which is deterministic for the
suite's fixed trace so any dip means the index stopped matching; their
``bit_exact`` flags gate warm generations staying token-identical to the
no-prefix-cache paged pool;
the smoke-scale serving/tp/replica tok_s rows floor at a quarter of
their minted value — wide enough for a 2-core box's heavy-tailed
scheduler noise, tight enough to catch a decode step that recompiles per
token; the continuous-vs-sequential serving ratios floor at 0.8, because
their smoke-scale noise reaches ~1.0 and a fully-broken batcher also
lands at ~1.0 — the benchmark's internal ``decode_traces == 1``
assertion and the serving test suite carry the sharp signal for that
failure mode.
The multi-replica router rows gate the fleet contracts:
``replica/scaling_4x_vs_1`` floors at the 2.5x acceptance bar — fleet
tok/s on the router's FleetClock must scale with replicas (the measured
value is super-linear on the forced-CPU topology, see run.py, so the
floor polices direction, not the multiple); ``replica/affinity_hit_rate``
floors at 0.7 — deterministic ~0.875 for the fixed 3-tenant trace, so a
dip means prefix_affinity stopped pinning tenants to tries; and
``replica/kill_mid_trace_zero_lost`` is a ``bit_exact`` boolean — a
mid-trace replica kill must complete the whole trace with zero
lost/duplicated requests and tokens identical to the single-scheduler
oracle, so any flip is a drain/requeue correctness regression, never
noise).

The chunked-prefill rows gate the decode-interleaving contract:
``chunked/*_p99_tpot_improvement`` floors at 2.0 — the ratio of the
monolithic run's p99 inter-token gap over the chunked+packed run's, per
PIM mode, on the suite's fixed bursty trace (one very long prompt
stalls every decoding slot for a whole prefill unless chunking bounds
the stall to one 64-token chunk; the issue's acceptance bar is chunked
p99 TPOT <= 0.5x unchunked, i.e. ratio >= 2, and the measured values
sit at 2.7-5.7x); ``chunked/*_tokens_bit_exact`` booleans gate chunked
+packed generations staying token-identical to whole-prompt prefill
(scheduling is a latency optimization, never a semantic one — any flip
is a chunk-resume or segment-mask correctness regression); the
``chunked/packed_prefill_calls`` row is descriptive (chunk/pack
counters), not gated.

The spec-decode suite rows gate the self-speculative decoding contract:
``spec/*_tokens_bit_exact`` booleans gate speculative generations
staying token-identical to plain decode per verify mode {xla, quant_tp,
pim_sim} — greedy acceptance commits exactly the verify mode's own
greedy chain, so any flip is an acceptance/rollback correctness
regression (the xla row pairs a float verify with an integer quant
draft precisely so acceptance is imperfect and the exactness claim is
non-trivial); ``spec/pim_sim_speculative_vs_plain`` floors at the 1.3x
acceptance bar — verifying ``draft_k`` rows through the crossbar
simulator costs about one single-row step (per-gate interpreter
overhead dominates), so speculative tok/s must beat plain pim_sim
decode — while the xla/quant_tp ratio rows floor at 0.05 ("ran at
all"): their per-step cost scales with the verified width, so
speculation is documented as a net loss there, not gated as a win; the
``spec/mean_accept_len`` row is descriptive (acceptance histogram), not
gated.

The autotune suite rows gate the partition autotuner's contract:
``autotune/*_picked_vs_default`` floors at 1.0 — the tuner's pick is the
argmin of a timed race that always contains the engine's hardcoded
default, so a value below 1.0 means the default fell out of the race,
not noise; ``autotune/tuned_bit_exact_vs_default`` and
``autotune/table_roundtrip`` are ``bit_exact`` booleans (tuned plans
change speed, never results; persisted picks must survive
save -> clear_cache -> load); the ``autotune/mult_*_32b_cycles`` ratios
are deterministic simulator cycle counts of the new multiplier backends
vs the NOR serial baseline.

**Tuning-table JSON format** (``pim.autotune.save_table`` /
``load_table``; written by ``serve.py --autotune-table PATH``)::

    {"version": 1,
     "entries": {
       "gemm:k<K>b<bits>m<model>x<Mbucket>o<O>@<pim_mode>": {
         "key": ..., "kind": "gemm" | "linear",
         "model":  partition model or linear lowering picked,
         "n_cols": crossbar geometry, "chunk": dot terms per program,
         "backend": execution backend ("" for non-executable ranks),
         "predicted_us": cost-model device latency,
         "trial_us": measured pick, "default_us": measured default,
         "source": "cost_model" | "trial" | "table"}, ...}}

Keys bucket the batch rows M to the next power of two (decode batch
churn must not thrash the table); ``linear:`` keys race the quant vs
quant_tp int8 lowerings.  Loading stamps every entry
``source="table"``, so the ``[autotune]`` hit counters show warmup
reusing picks instead of re-searching.  To refresh a persisted table
after an engine or cost-model change, delete the file (or pass
``force=True`` to ``pim.autotune.autotune``) and re-run
``serve.py --autotune --autotune-table PATH`` — trials re-race on the
current code and the file is rewritten on exit; bumping
``pim.autotune.TABLE_VERSION`` invalidates stale files loudly
(``load_table`` raises on mismatch).

Besides the stdout lines, every run renders the gated rows as a
markdown pass/fail table: appended to ``$GITHUB_STEP_SUMMARY`` when set
(the CI run page then shows which gate tripped without downloading the
``BENCH_partitionpim`` artifact), printed to stdout otherwise.

A row present in the baseline but missing from the fresh artifact fails:
renaming or deleting a benchmark must refresh the baseline deliberately,
never silently drop coverage.  Fresh-only rows (new benchmarks) pass with
a note.  Timing columns (``us_per_call``) and ``derived`` strings are
diagnostics, not gates.

Refreshing the committed baseline (after an intentional perf change, a
row rename, or a hardware move):

    PYTHONPATH=src python benchmarks/run.py --suite all \\
        --json-out benchmarks/baseline.json

— or run CI's artifact command and copy it over with
``python benchmarks/check.py BENCH_partitionpim.json --update`` — then
commit ``benchmarks/baseline.json`` with a line in the PR body saying why
the numbers moved.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import Dict, List, Tuple

DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "baseline.json")


def _rows(doc: Dict) -> Dict[str, Dict]:
    return {k: v for k, v in doc.items() if not k.startswith("_")}


def compare(fresh: Dict, baseline: Dict, tolerance: float
            ) -> Tuple[List[str], List[str], List[Dict]]:
    """Returns (failures, notes, records).

    ``records`` is one dict per gated check — ``{"name", "pim_mode",
    "gate", "baseline", "fresh", "status", "detail"}`` with ``status``
    in {"pass", "FAIL"} — the structured form behind both the stdout
    lines and the ``$GITHUB_STEP_SUMMARY`` table
    (:func:`write_step_summary`).  Ungated (descriptive) rows don't
    produce records.
    """
    failures: List[str] = []
    notes: List[str] = []
    records: List[Dict] = []
    f_rows, b_rows = _rows(fresh), _rows(baseline)

    def rec(status, name, pim_mode, gate, bv, fv, detail=""):
        records.append({"name": name, "pim_mode": pim_mode, "gate": gate,
                        "baseline": bv, "fresh": fv, "status": status,
                        "detail": detail})

    for name, b in sorted(b_rows.items()):
        key = (b.get("suite", ""), name, b.get("pim_mode", ""))
        f = f_rows.get(name)
        if f is None:
            failures.append(f"missing row {key}: present in baseline but "
                            f"not in the fresh artifact (renames must "
                            f"refresh the baseline)")
            rec("FAIL", name, key[2], "presence", "present", "missing",
                "renames must refresh the baseline")
            continue
        if (f.get("suite", ""), f.get("pim_mode", "")) != (key[0], key[2]):
            failures.append(
                f"row {name!r} changed identity: baseline "
                f"(suite={key[0]}, pim_mode={key[2]}) vs fresh "
                f"(suite={f.get('suite', '')}, "
                f"pim_mode={f.get('pim_mode', '')})")
            rec("FAIL", name, key[2], "identity",
                f"{key[0]}/{key[2]}",
                f"{f.get('suite', '')}/{f.get('pim_mode', '')}",
                "row changed (suite, pim_mode) identity")
            continue
        tol = float(b.get("tol", tolerance))
        floor = b.get("floor")
        for field in ("tok_s", "ratio"):
            bv, fv = b.get(field), f.get(field)
            if bv is None:
                continue
            gate = (f"{field} floor {float(floor):.3g}" if floor is not None
                    else f"{field} tol -{tol:.0%}")
            if fv is None:
                failures.append(f"{key}: baseline has {field}={bv} but the "
                                f"fresh row dropped the field")
                rec("FAIL", name, key[2], gate, bv, None,
                    "fresh row dropped the field")
            elif floor is not None:
                if fv < float(floor):
                    failures.append(
                        f"{key}: {field} {fv:.3f} fell below the absolute "
                        f"floor {float(floor):.3f} (baseline {bv:.3f})")
                    rec("FAIL", name, key[2], gate, bv, fv,
                        "below the absolute floor")
                else:
                    if fv < bv:
                        notes.append(f"{key}: {field} {bv:.3f} -> {fv:.3f} "
                                     f"(above floor {float(floor):.3f})")
                    rec("pass", name, key[2], gate, bv, fv)
            elif fv < (1.0 - tol) * bv:
                failures.append(
                    f"{key}: {field} regressed {bv:.3f} -> {fv:.3f} "
                    f"({fv / bv - 1.0:+.1%}, tolerance -{tol:.0%})")
                rec("FAIL", name, key[2], gate, bv, fv,
                    f"regressed {fv / bv - 1.0:+.1%}")
            else:
                if fv < bv:
                    notes.append(f"{key}: {field} {bv:.3f} -> {fv:.3f} "
                                 f"(within tolerance)")
                rec("pass", name, key[2], gate, bv, fv)
        if b.get("bit_exact") is True:
            if f.get("bit_exact") is not True:
                failures.append(f"{key}: bit_exact flipped "
                                f"{b.get('bit_exact')} -> "
                                f"{f.get('bit_exact')}")
                rec("FAIL", name, key[2], "bit_exact", True,
                    f.get("bit_exact"), "correctness regression")
            else:
                rec("pass", name, key[2], "bit_exact", True, True)
    for name in sorted(set(f_rows) - set(b_rows)):
        notes.append(f"new row {name!r} (not in baseline; refresh to gate "
                     f"it)")
    return failures, notes, records


def write_step_summary(records: List[Dict], fresh: Dict, baseline: Dict,
                       n_failures: int, out=None) -> None:
    """Render the gated-row table as GitHub-flavored markdown.

    Appends to ``$GITHUB_STEP_SUMMARY`` when set (the CI run page shows
    it without downloading the ``BENCH_partitionpim`` artifact), else
    prints to ``out``/stdout so local runs see the same table.
    """
    def fmt(v):
        if isinstance(v, bool) or v is None:
            return str(v)
        if isinstance(v, float):
            return f"{v:.3f}"
        return str(v)

    lines = ["## Benchmark gate: "
             + (f"FAIL ({n_failures} regression(s))" if n_failures
                else "pass"),
             "",
             f"baseline commit "
             f"`{baseline.get('_meta', {}).get('commit')}` vs fresh "
             f"`{fresh.get('_meta', {}).get('commit')}` — "
             f"{len(records)} gated check(s)",
             "",
             "| status | row | pim_mode | gate | baseline | fresh | |",
             "|---|---|---|---|---|---|---|"]
    # failures first so the run page leads with what broke
    for r in sorted(records, key=lambda r: r["status"] != "FAIL"):
        mark = "❌" if r["status"] == "FAIL" else "✅"
        lines.append(f"| {mark} | `{r['name']}` | {r['pim_mode']} | "
                     f"{r['gate']} | {fmt(r['baseline'])} | "
                     f"{fmt(r['fresh'])} | {r['detail']} |")
    text = "\n".join(lines) + "\n"
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if path:
        with open(path, "a") as fh:
            fh.write(text)
    else:
        print(text, file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail on benchmark regressions vs the committed "
                    "baseline (see module docstring)")
    ap.add_argument("fresh", help="fresh artifact from benchmarks/run.py "
                                  "(e.g. BENCH_partitionpim.json)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("BENCH_TOLERANCE", "0.20")),
                    help="allowed fractional throughput drop "
                         "(default 0.20; env BENCH_TOLERANCE)")
    ap.add_argument("--update", action="store_true",
                    help="copy the fresh artifact over the baseline "
                         "instead of gating (then commit it)")
    args = ap.parse_args(argv)

    if args.update:
        shutil.copyfile(args.fresh, args.baseline)
        print(f"baseline updated from {args.fresh} -> {args.baseline}; "
              f"commit it")
        return 0

    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures, notes, records = compare(fresh, baseline, args.tolerance)
    write_step_summary(records, fresh, baseline, len(failures))
    for n in notes:
        print(f"note: {n}")
    if failures:
        print(f"\nFAIL: {len(failures)} benchmark regression(s) vs "
              f"{os.path.basename(args.baseline)} "
              f"(baseline commit {baseline.get('_meta', {}).get('commit')}, "
              f"fresh commit {fresh.get('_meta', {}).get('commit')}):")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    n_gated = sum(1 for r in _rows(baseline).values()
                  if any(k in r for k in ("tok_s", "ratio", "bit_exact")))
    print(f"OK: {len(_rows(fresh))} rows checked against "
          f"{len(_rows(baseline))} baseline rows ({n_gated} gated), "
          f"tolerance {args.tolerance:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
