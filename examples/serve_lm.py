"""Serving example: batched prefill + greedy decode on a small config.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch jamba-v0.1-52b]
(any decoder-only architecture, sliding-window included — those page their
KV into block rings automatically; enc-dec/vision serving is a ROADMAP
follow-on.  --preset tiny keeps it CPU-sized; add --paged via
launch/serve.py for the block-paged pool on full-attention archs.)
"""
import argparse
import sys

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="jamba-v0.1-52b")
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    sys.argv = ["serve", "--arch", args.arch, "--preset", "tiny",
                "--batch", "4", "--prompt-len", "48",
                "--gen", str(args.gen)]
    serve_mod.main()


if __name__ == "__main__":
    main()
