"""Quickstart: the paper's contribution in one minute.

Builds the three partition designs, runs a bit-exact 32-bit multiplication
on the simulated crossbar (1024 rows at once), and prints the Figure-6
numbers — latency, control bits, area — next to the paper's claims.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import PartitionConfig, message_bits
from repro.pim import engine
from repro.pim import executor as ex
from repro.pim.mult_serial import build_serial_multiplier
from repro.pim.multpim import build_multpim

cfg = PartitionConfig(n=1024, k=32)
print("== PartitionPIM quickstart ==")
print(f"crossbar: {cfg.n} bitlines, {cfg.k} partitions "
      f"({cfg.m} bitlines each)\n")

# -- control messages (paper §2.3/§3.3/§4.3) -------------------------------
for model in ("baseline", "unlimited", "standard", "minimal"):
    print(f"{model:10s} control message: {message_bits(model, cfg):4d} bits")

# -- build the multipliers ---------------------------------------------------
serial = build_serial_multiplier(32)
minimal = build_multpim(32, model="minimal")
s_st, m_st = serial.program.stats(), minimal.program.stats()
print(f"\n32-bit multiply latency: serial {s_st.cycles} cycles, "
      f"minimal-partitions {m_st.cycles} cycles "
      f"-> {s_st.cycles / m_st.cycles:.1f}x speedup (paper: ~9x)")

# -- every cycle's control message round-trips through the real codec --------
minimal.program.check_messages(sample_every=50)
print("control codec: every sampled message encodes/decodes correctly")

# -- run it: 1024 rows multiply concurrently --------------------------------
# (execution goes through the repro.pim.engine backend registry; swap
# backend="pallas" for the TPU kernel path)
rows = 1024
rng = np.random.default_rng(0)
a = rng.integers(0, 1 << 32, size=(1, rows), dtype=np.uint64)
b = rng.integers(0, 1 << 32, size=(1, rows), dtype=np.uint64)
state = ex.blank_state(1, cfg.n, rows)
state = ex.write_numbers(state, minimal.a_cols, a)
state = ex.write_numbers(state, minimal.b_cols, b)
state = engine.execute_state(state, minimal.program.to_microcode(),
                             backend="scan")
got = ex.read_numbers(state, minimal.result_cols, rows)
ok = np.array_equal(got.astype(object), a.astype(object) * b.astype(object))
print(f"simulated crossbar multiplied {rows} row-pairs bit-exactly: {ok}")
