"""PIM GEMM demo: integer matrix multiply executed gate-by-gate on the
simulated memristive crossbars (carry-save accumulation), through the
compile-once/execute-many ``repro.pim.engine`` API — plus the same matmul
through the Pallas TPU kernel path and through a neural layer under the
engine's mode selection.

Run:  PYTHONPATH=src python examples/pim_matmul_demo.py
"""
import numpy as np
import jax.numpy as jnp

from repro.pim import engine
from repro.pim.matmul import pim_matmul_int
from repro.kernels.quant_matmul import quant_linear
from repro.pim import executor as ex

rng = np.random.default_rng(0)

# -- 1) bit-exact integer GEMM on the crossbars ------------------------------
M, K, O = 4, 6, 3
x = rng.integers(0, 256, size=(M, K), dtype=np.uint64)
w = rng.integers(0, 256, size=(O, K), dtype=np.uint64)
y = pim_matmul_int(x, w, n_bits=8, model="minimal", rows_per_crossbar=32)
print("pim_matmul_int exact:",
      np.array_equal(y.astype(object), x.astype(object) @ w.T.astype(object)))
# the wrapper compiled through the engine cache: same shape -> same artifact
print("compile cache:", engine.cache_info())

# -- 2) the same artifact through the Pallas kernel (interpret mode on CPU) --
dot = engine.compile_dot(K, 8, model="minimal")   # cache hit, no rebuild
st = dot.program.stats()
print(f"dot program: {st.cycles} cycles, {st.logic_gates} gates, "
      f"{st.control_bits_per_message} control bits/cycle")
y_pallas = engine.execute(dot, x, w, backend="pallas", rows_per_crossbar=32)
print("pallas kernel matmul exact:", np.array_equal(
    y_pallas.astype(object), x.astype(object) @ w.T.astype(object)))

# the raw state path is still available for custom drivers:
rows = 32
state = ex.blank_state(1, dot.n_cols, rows)
for i in range(K):
    state = ex.write_numbers(state, dot.x_cols[i],
                             np.tile(x[:1, i], (1, rows)))
    state = ex.write_numbers(state, dot.w_cols[i],
                             np.tile(w[:1, i], (1, rows)))
out = engine.execute_state(jnp.array(state), dot.microcode, backend="pallas")
acc = ex.read_numbers(out, dot.acc_cols, rows)
want = int(sum(int(a) * int(b) for a, b in zip(x[0], w[0])))
print("pallas kernel dot exact:", bool((acc == want).all()))

# -- 3) a neural linear layer in PIM fixed point (int8 Pallas matmul) --------
xf = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
wf = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
yq = quant_linear(xf, wf, backend="pallas")
rel = float(np.abs(np.asarray(yq) - np.asarray(xf) @ np.asarray(wf)).max()
            / np.abs(np.asarray(xf) @ np.asarray(wf)).max())
print(f"quantized PIM-style linear rel-err: {rel:.3%} (int8 fixed point)")

# -- 4) the same layer through models.layers.linear under mode selection -----
from repro.models.layers import linear  # noqa: E402

with engine.mode("quant"):
    yq2 = linear(xf, wf)
print("engine.mode('quant') matches direct kernel call:",
      bool(np.allclose(np.asarray(yq2), np.asarray(yq))))
