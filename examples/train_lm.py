"""End-to-end driver: train a small LM for a few hundred steps on CPU with
checkpointing, then reload and serve a few tokens.  Demonstrates the full
substrate: data pipeline -> jit'd train step -> AdamW -> checkpoints ->
resume -> decode.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(defaults are sized to finish in a few minutes on one CPU core)
"""
import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    args = ap.parse_args()

    sys.argv = [
        "train", "--arch", args.arch, "--preset", "tiny", "--layers", "4",
        "--steps", str(args.steps), "--batch", "8", "--seq", "128",
        "--lr", "1e-3", "--ckpt-dir", "/tmp/repro_train_demo",
        "--ckpt-every", "100", "--log-every", "25",
        "--metrics-out", "/tmp/repro_train_demo_metrics.jsonl",
    ]
    losses = train_mod.main()
    assert min(losses) < losses[0], "training should reduce loss"
    drop = losses[0] - min(losses)
    print(f"\nloss dropped by {drop:.3f} "
          f"({losses[0]:.3f} -> {min(losses):.3f}) over {args.steps} steps")

    # serve from the trained weights' config (fresh decode demo)
    sys.argv = ["serve", "--arch", args.arch, "--preset", "tiny",
                "--layers", "4", "--batch", "2", "--prompt-len", "32",
                "--gen", "8"]
    from repro.launch import serve as serve_mod

    serve_mod.main()


if __name__ == "__main__":
    main()
