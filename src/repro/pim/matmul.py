"""PIM GEMM: lowering integer matrix multiplication onto crossbar rows.

Throughput-oriented mapping (single-row arithmetic, §1 of the paper): each
*simulator row* computes one output element ``y[m, o] = sum_i x[m, i] * w[o, i]``
— the (m, o) grid is flattened across rows and crossbars, so the whole GEMM
runs at ``rows x crossbars`` way parallelism while the per-row program is a
sequence of ``K`` multiply-accumulate steps:

    for i in range(K):
        copy x_i, w_i  ->  multiplier input columns    (parallel copies)
        MultPIM multiply (partitioned, model-specific)
        ripple-add the 2N-bit product into the accumulator

The multiply is the partition-accelerated part (the paper's case study);
copies and the accumulate ride along.  This module is the *synthesis* side
only: it lowers the arithmetic into a validated :class:`Program` through the
shared :class:`~repro.core.program.ProgramBuilder` API.  Compilation
caching, backend selection and execution live in ``repro.pim.engine`` —
call :func:`repro.pim.engine.compile_dot` (or the thin
:func:`pim_matmul_int` wrapper kept here for compatibility, which now
compiles once per shape through the engine cache) rather than rebuilding
programs per call.  The *analytical* scaling of the same mapping to full LM
layers lives in ``pim/cost_model.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.operation import GateOp, PartitionConfig
from repro.core.program import Program, ProgramBuilder
from repro.pim.multpim import Layout, build_multpim

__all__ = ["PimDot", "build_dot", "max_dot_terms", "pim_matmul_int"]


def _dot_layout(n_terms: int, n_bits: int, k: int):
    """(acc_width, n_acc, need): the intra columns a dot of ``n_terms``
    needs beyond the multiplier layout — THE budget formula, shared by
    :func:`build_dot` (allocation) and :func:`max_dot_terms` (chunking)."""
    acc_width = 2 * n_bits + max(1, (n_terms - 1).bit_length())
    n_acc = (acc_width + k - 1) // k  # intra columns per accumulator plane
    # planes: ACCS/ACCC (current sum/carry) + NACCS/NACCC (next) + result,
    # plus the operand column pairs and the 14-column serial scratch strip
    need = 2 * n_terms + 5 * n_acc + 14
    return acc_width, n_acc, need


def max_dot_terms(n_bits: int = 8, n_cols: int = 1024) -> int:
    """Largest ``n_terms`` whose dot program fits one row's column budget.

    Uses :func:`build_dot`'s own layout arithmetic without building
    anything; the engine uses it to split long inner dimensions into
    chunked GEMMs whose partials are summed exactly on the host.
    """
    k = n_bits
    base = Layout.make(k)["width"]
    m = n_cols // k
    best = 0
    for t in range(1, m):
        if base + _dot_layout(t, n_bits, k)[2] <= m:
            best = t
        else:
            break
    return best


@dataclasses.dataclass
class PimDot:
    program: Program
    n_bits: int
    n_terms: int
    x_cols: Tuple[Tuple[int, ...], ...]  # x_cols[i] = columns of term i of x
    w_cols: Tuple[Tuple[int, ...], ...]
    acc_cols: Tuple[int, ...]            # accumulator (2N + log2(K) bits)


def _ripple_add(b: ProgramBuilder, x_cols, y_cols, out_cols, tmp, width_x,
                width_y, model: str, cfg: PartitionConfig):
    """out = x + y (serial single-gate FA chain; legal in every model).

    ``tmp``: >= 14 scratch columns in ONE partition — tmp[0:7] FA internals
    (re-initialized per position), tmp[7]/tmp[8] alternating carry columns (a
    carry must survive into the next position's adder, so it cannot share the
    re-init strip), tmp[9] constant-one scratch, tmp[10:14] operand
    localization slots.

    *No Split-Input* (paper §3.1, fn. 3) applies to serial gates too: under
    standard/minimal, a NOR's two inputs must share a partition, so operands
    are first copied (double-NOT) into the scratch partition.  The unlimited
    model permits split inputs and skips the copies.
    """
    split_ok = model in ("unlimited", "baseline")
    part = cfg.partition

    def localize(val, slot_a, slot_b):
        """Copy ``val`` into the scratch partition (2 NOTs); returns column."""
        b.init_range(slot_a, slot_a)
        b.gate("NOT", (val,), slot_a)
        b.init_range(slot_b, slot_b)
        b.gate("NOT", (slot_a,), slot_b)
        return slot_b

    carry: Optional[int] = None
    for p, out in enumerate(out_cols):
        x = x_cols[p] if p < width_x else None
        y = y_cols[p] if p < width_y else None
        if not split_ok:
            home = part(tmp[0])
            if x is not None and part(x) != home:
                x = localize(x, tmp[10], tmp[11])
            if y is not None and part(y) != home:
                y = localize(y, tmp[12], tmp[13])
        terms = [t for t in (x, y, carry) if t is not None]
        cout = tmp[7] if p % 2 == 0 else tmp[8]
        b.init_range(out, out)
        if len(terms) == 0:
            b.init_range(tmp[9], tmp[9])
            b.gate("NOT", (tmp[9],), out)  # NOT(1) = 0
            carry = None
            continue
        b.init_range(tmp[0], tmp[6])
        if len(terms) == 1:
            b.gate("NOT", (terms[0],), tmp[0])
            b.gate("NOT", (tmp[0],), out)
            carry = None
            continue
        b.init_range(cout, cout)
        if len(terms) == 2:
            t0, t1 = terms
            b.gate("NOR", (t0, t1), tmp[0])
            b.gate("NOR", (t0, tmp[0]), tmp[1])
            b.gate("NOR", (t1, tmp[0]), tmp[2])
            b.gate("NOR", (tmp[1], tmp[2]), tmp[3])  # XNOR
            b.gate("NOT", (tmp[3],), tmp[4])         # XOR (local copy)
            b.gate("NOT", (tmp[3],), out)            # XOR -> output column
            b.gate("NOR", (tmp[0], tmp[4]), cout)    # AND = NOR(NOR, XOR)
        else:
            t0, t1, t2 = terms
            b.gate("NOR", (t0, t1), tmp[0])
            b.gate("NOR", (t0, tmp[0]), tmp[1])
            b.gate("NOR", (t1, tmp[0]), tmp[2])
            b.gate("NOR", (tmp[1], tmp[2]), tmp[3])  # XNOR(t0,t1)
            b.gate("NOR", (tmp[3], t2), tmp[4])
            b.gate("NOR", (tmp[3], tmp[4]), tmp[5])
            b.gate("NOR", (t2, tmp[4]), tmp[6])
            b.gate("NOR", (tmp[5], tmp[6]), out)     # sum
            b.gate("NOR", (tmp[0], tmp[4]), cout)    # majority
        carry = cout


def build_dot(n_terms: int, n_bits: int = 8, n_cols: int = 1024,
              model: str = "minimal", accumulate: str = "carry_save") -> PimDot:
    """Dot product of ``n_terms`` pairs of N-bit ints in a single row.

    ``accumulate="carry_save"`` (default, beyond-paper optimization): each
    product is folded into a redundant (sum, carry) accumulator with one 3:2
    compression — a handful of *parallel* partition operations per term —
    and a single ripple carry-propagate at the very end.  ``"ripple"`` is
    the naive serial accumulate (kept for the §Perf before/after).
    """
    N = n_bits
    core = build_multpim(N, n_cols, model=model)
    cfg = core.program.cfg
    k = cfg.k
    L = core.layout
    m = cfg.m
    col = cfg.col

    base = L["width"]
    acc_width, n_acc, need = _dot_layout(n_terms, N, k)
    if base + need > m:
        raise ValueError(
            f"layout overflow: {base + need} > {m} intra columns "
            f"(reduce n_terms or n_bits)")
    X = [base + 2 * i for i in range(n_terms)]
    W = [base + 2 * i + 1 for i in range(n_terms)]
    ACCS = base + 2 * n_terms
    ACCC = ACCS + n_acc
    NACCS = ACCC + n_acc
    NACCC = NACCS + n_acc
    RES = NACCC + n_acc
    TMP = RES + n_acc                  # serial scratch strip (14 columns)

    b = ProgramBuilder(cfg, model, name=f"pim-dot-{n_terms}x{N}b")
    prog = b.program

    def plane(intra0):
        # bit p -> (partition p % k, intra intra0 + p // k)
        return tuple(col(p % k, intra0 + p // k) for p in range(acc_width))

    mult_ops = core.program.ops
    prod_cols = core.result_cols
    prod_intra = (L["R"], L["R2"])  # product bit p: (partition p%k, group p//k)
    U, PP, NZ = L["U"], L["PP"], 3  # multiplier scratch reused between runs

    cur_s, cur_c = ACCS, ACCC
    nxt_s, nxt_c = NACCS, NACCC

    def copy_in(i):
        """Copy term operands into the multiplier input columns (parallel)."""
        b.init_periodic(PP, PP, 0, k - 1, label="cp-init")
        b.par([GateOp("NOT", (col(j, X[i]),), col(j, PP)) for j in range(k)],
              "cp-x1")
        b.init_periodic(Layout.IA, Layout.IB, 0, k - 1, label="cp-init2")
        b.par([GateOp("NOT", (col(j, PP),), col(j, Layout.IA))
               for j in range(k)], "cp-x2")
        b.init_periodic(PP, PP, 0, k - 1)
        b.par([GateOp("NOT", (col(j, W[i]),), col(j, PP)) for j in range(k)],
              "cp-w1")
        b.par([GateOp("NOT", (col(j, PP),), col(j, Layout.IB))
               for j in range(k)], "cp-w2")

    def group_positions(g):
        return [j for j in range(k) if g * k + j < acc_width]

    def csa_term():
        """(nxt_s, nxt_c) = 3:2 compress (cur_s, product, cur_c)."""
        b.init_periodic(nxt_s, nxt_c + n_acc - 1, 0, k - 1, label="csa-init")
        # position 0 has no carry-in producer: set nxt_c plane bit 0 to 0
        b.gate("NOT", (col(0, NZ),), col(0, nxt_c), "c0-zero")
        for g in range(n_acc):
            js = group_positions(g)
            b.init_periodic(PP, U + 6, 0, k - 1, label="csa-u-init")
            s_i, c_i = cur_s + g, cur_c + g
            so, co = nxt_s + g, nxt_c + g
            if g < 2:
                y_i = prod_intra[g]
                # u1..u7 of the NOR full adder, parallel across the group
                pg = lambda gate, ins, out: b.par(
                    [GateOp(gate, tuple(col(j, ii) for ii in ins), col(j, out))
                     for j in js])
                pg("NOR", (s_i, y_i), U + 0)
                pg("NOR", (s_i, U + 0), U + 1)
                pg("NOR", (y_i, U + 0), U + 2)
                pg("NOR", (U + 1, U + 2), U + 3)
                pg("NOR", (U + 3, c_i), U + 4)
                pg("NOR", (U + 3, U + 4), U + 5)
                pg("NOR", (c_i, U + 4), U + 6)
                pg("NOR", (U + 5, U + 6), so)          # sum stays in place
                cout_src = (U + 0, U + 4)
            else:
                # no product bits here: half-add (cur_s, cur_c)
                pg = lambda gate, ins, out: b.par(
                    [GateOp(gate, tuple(col(j, ii) for ii in ins), col(j, out))
                     for j in js])
                pg("NOR", (s_i, c_i), U + 0)
                pg("NOR", (s_i, U + 0), U + 1)
                pg("NOR", (c_i, U + 0), U + 2)
                pg("NOR", (U + 1, U + 2), U + 3)       # XNOR
                pg("NOT", (U + 3,), so)                # XOR
                # cout = AND = NOR(NOR(s,c), XOR(s,c)), emitted directly by
                # the cross-partition carry gates below
                cout_src = (U + 0, so)

            # carries go one position left: partition j -> j+1 (even/odd),
            # group boundary j=k-1 -> partition 0 of the next plane
            def cgate(j):
                tgt_p, tgt_i = (j + 1, co) if j + 1 < k else (0, nxt_c + g + 1)
                if g * k + j + 1 >= acc_width:
                    return None
                if len(cout_src) == 2:
                    return GateOp("NOR", (col(j, cout_src[0]),
                                          col(j, cout_src[1])),
                                  col(tgt_p, tgt_i))
                return GateOp("NOT", (col(j, cout_src[0]),), col(tgt_p, tgt_i))

            even = [cgate(j) for j in js if j % 2 == 0 and j + 1 < k]
            odd = [cgate(j) for j in js if j % 2 == 1 and j + 1 < k]
            even = [g_ for g_ in even if g_ is not None]
            odd = [g_ for g_ in odd if g_ is not None]
            if even:
                b.par(even, "csa-cout-even")
            if odd:
                b.par(odd, "csa-cout-odd")
            top = cgate(k - 1)
            if top is not None and k - 1 in js:
                b.par([top], "csa-cout-wrap")

    first = True
    for i in range(n_terms):
        copy_in(i)
        prog.ops.extend(mult_ops)  # the partition-accelerated multiply
        tmp = [col(0, TMP + t) for t in range(14)]
        if accumulate == "ripple":
            cur = plane(cur_s)
            nxt = plane(nxt_s)
            if first:
                for p in range(acc_width):
                    b.init_range(nxt[p], nxt[p])
                    b.init_range(tmp[0], tmp[0])
                    if p < 2 * N:
                        b.gate("NOT", (prod_cols[p],), tmp[0])
                        b.gate("NOT", (tmp[0],), nxt[p])
                    else:
                        b.gate("NOT", (tmp[0],), nxt[p])
                first = False
            else:
                _ripple_add(b, prod_cols, cur, nxt, tmp, 2 * N, acc_width,
                            model, cfg)
            cur_s, nxt_s = nxt_s, cur_s
            continue
        if first:
            # acc := product; carries := 0 (parallel copies per plane)
            b.init_periodic(cur_s, cur_c + n_acc - 1, 0, k - 1,
                            label="acc0-init")
            for g in range(n_acc):
                js = group_positions(g)
                b.init_periodic(PP, PP, 0, k - 1)
                if g < 2:
                    b.par([GateOp("NOT", (col(j, prod_intra[g]),), col(j, PP))
                           for j in js])
                    b.par([GateOp("NOT", (col(j, PP),), col(j, cur_s + g))
                           for j in js])
                else:
                    b.par([GateOp("NOT", (col(j, NZ),), col(j, cur_s + g))
                           for j in js])
                b.par([GateOp("NOT", (col(j, NZ),), col(j, cur_c + g))
                       for j in js])
            first = False
        else:
            csa_term()
            cur_s, nxt_s = nxt_s, cur_s
            cur_c, nxt_c = nxt_c, cur_c

    # final resolution: result = acc_s + acc_c (single ripple pass)
    if accumulate == "carry_save":
        tmp = [col(0, TMP + t) for t in range(14)]
        _ripple_add(b, plane(cur_s), plane(cur_c), plane(RES), tmp,
                    acc_width, acc_width, model, cfg)
        out_cols = plane(RES)
    else:
        out_cols = plane(cur_s)

    prog.name = f"pim-dot-{n_terms}x{N}b-{model}-{accumulate}"
    return PimDot(
        program=prog,
        n_bits=N,
        n_terms=n_terms,
        x_cols=tuple(tuple(col(j, X[i]) for j in range(N))
                     for i in range(n_terms)),
        w_cols=tuple(tuple(col(j, W[i]) for j in range(N))
                     for i in range(n_terms)),
        acc_cols=out_cols,
    )


def pim_matmul_int(x: np.ndarray, w: np.ndarray, n_bits: int = 8,
                   model: str = "minimal", rows_per_crossbar: int = 256,
                   backend: str = "scan") -> np.ndarray:
    """Bit-exact integer GEMM on the simulated crossbars.

    x: (M, K) uint, w: (O, K) uint -> (M, O) uint64.  Each (m, o) output is
    one simulator row; rows are packed 32/word and split across crossbars.

    Compatibility wrapper over ``repro.pim.engine.matmul_int``: the gate
    program is compiled through the engine cache (once per
    ``(K, n_bits, model)``) and executed on the selected backend.
    """
    from repro.pim import engine

    return engine.matmul_int(x, w, n_bits, model=model,
                             rows_per_crossbar=rows_per_crossbar,
                             backend=backend)
