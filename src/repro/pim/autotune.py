"""Cost-model-driven partition autotuner: the engine's configuration planner.

The paper's central trade-off — partition count vs. peripheral/control
overhead — means the fastest crossbar configuration depends on the
workload shape.  This module turns ``pim/cost_model.py`` from descriptive
seed code into the engine's decision maker: for each compile key
``(n_terms, n_bits, model, shape, pim_mode)`` it

1. enumerates candidate configurations — partition model
   (``minimal``/``standard``/``unlimited``), crossbar geometry
   (:class:`~repro.core.operation.PartitionConfig` widened via
   ``scaled(n=...)``: a wider row fits more dot terms per chunk but pays
   more control bits per message), the implied inner-dimension chunking
   (``matmul.max_dot_terms``), the execution backend (scan / pallas /
   numpy; the quant-vs-quant_tp split rule races through
   :func:`autotune_linear`), and the multiplier algorithm (every
   ``kind="mult"`` registry entry — the NOR serial baseline plus
   ``serial_fast`` and ``compressor42`` — priced in the same race even
   though only partitioned models lower to executable dot programs);
2. scores every candidate with ``cost_model.gemm_cost`` /
   ``cost_model.mult_cost`` (predicted device latency);
3. breaks ties among the top predicted candidates with short timed trials
   on clipped operands — the hardcoded default configuration is ALWAYS in
   the trial set, so the pick can never be slower than the default on the
   machine that tuned it (``picked_vs_default >= 1.0`` by construction,
   the ``--suite autotune`` gate);
4. caches the winner: in the in-process table (hit on the next
   :func:`lookup`), attached to the ``CompiledPim`` artifact
   (``artifact.plan``), and — via :func:`save_table` /
   :func:`load_table` — in a JSON file so serving warmup
   (``serve.py --autotune-table``) reloads picks instead of re-searching.

Every tuned configuration computes the same exact integer GEMM (the
quant / quant_tp / pim_sim bit-exactness contract), so plans change
speed, never results.  ``engine.clear_cache()`` clears the table and its
counters; ``engine.cache_info()`` exposes them (``tune_hits`` /
``tune_misses`` / ``tune_trials``).
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "TunedPlan",
    "TuneInfo",
    "autotune",
    "autotune_linear",
    "lookup",
    "default_plan",
    "enable",
    "enabled",
    "clear",
    "save_table",
    "load_table",
    "table_info",
    "summary",
    "plan_for_params",
]

# executable dot-program partition models (build_dot lowers these)
PARTITIONED_MODELS = ("minimal", "standard", "unlimited")
# crossbar geometries raced (cfg.scaled(n=...)); wider rows fit more terms
GEOMETRIES = (1024, 2048, 4096)
# state backends raced outside a host callback; inside jax.pure_callback
# ("pim_sim") only the jax-free numpy interpreter may run.  "unrolled" is
# excluded: its XLA compile time grows with program length, so a trial
# would measure compilation, not steady state.
STATE_BACKENDS = ("scan", "pallas", "numpy")
CALLBACK_BACKENDS = ("numpy",)


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    """One tuned configuration pick (see the table JSON format in
    ``benchmarks/check.py``'s header)."""

    key: str
    kind: str               # "gemm" | "linear"
    model: str              # partition model (gemm) / lowering mode (linear)
    n_cols: int
    chunk: int              # dot terms per program (0: n/a)
    backend: str            # execution backend / lowering name
    predicted_us: float     # cost-model device latency
    trial_us: float = 0.0   # measured trial wall time (0: untried)
    default_us: float = 0.0  # the default config's time in the same race
    source: str = "cost_model"  # "cost_model" | "trial" | "table"

    @property
    def vs_default(self) -> float:
        """default_time / picked_time (>= 1.0 when trials ran)."""
        if self.trial_us > 0 and self.default_us > 0:
            return self.default_us / self.trial_us
        return 1.0

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict) -> "TunedPlan":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


@dataclasses.dataclass(frozen=True)
class TuneInfo:
    hits: int
    misses: int
    trials: int
    size: int
    enabled: bool


_table: Dict[str, TunedPlan] = {}
_lock = threading.Lock()
_hits = 0
_misses = 0
_trials = 0
_enabled = False


def enable(on: bool = True) -> None:
    """Turn ambient plan lookup on/off (``matmul_int(tune_ctx=...)``)."""
    global _enabled
    _enabled = on


def enabled() -> bool:
    return _enabled


def clear() -> None:
    """Drop every pick and zero the counters (leaves ``enabled`` alone)."""
    global _hits, _misses, _trials
    with _lock:
        _table.clear()
        _hits = _misses = _trials = 0


def table_info() -> TuneInfo:
    with _lock:
        return TuneInfo(hits=_hits, misses=_misses, trials=_trials,
                        size=len(_table), enabled=_enabled)


def _bucket_m(m: int) -> int:
    """Batch rows bucket to the next power of two: decode batch sizes churn
    as requests come and go, and re-tuning per transient M would thrash."""
    return 1 << max(0, int(m - 1).bit_length())


def tune_key(n_terms: int, n_bits: int, model: str, shape: Tuple[int, int],
             pim_mode: str) -> str:
    m, o = shape
    return f"gemm:k{n_terms}b{n_bits}m{model}x{_bucket_m(m)}o{o}@{pim_mode}"


def _allowed_backends(pim_mode: str,
                      backends: Optional[Sequence[str]]) -> Tuple[str, ...]:
    if backends is not None:
        return tuple(backends)
    return CALLBACK_BACKENDS if pim_mode == "pim_sim" else STATE_BACKENDS


def default_plan(n_terms: int, n_bits: int, shape: Tuple[int, int],
                 pim_mode: str = "raw", model: str = "minimal") -> TunedPlan:
    """The hardcoded configuration tuned calls are raced against: the
    engine's defaults (minimal model, 1024-column crossbar, max chunking,
    scan — or the callback-safe numpy interpreter under pim_sim)."""
    from repro.pim.cost_model import gemm_cost
    from repro.pim.matmul import max_dot_terms

    chunk = min(n_terms, max_dot_terms(n_bits, 1024))
    backend = "numpy" if pim_mode == "pim_sim" else "scan"
    cost = gemm_cost(shape[0], n_terms, shape[1], n_bits, model,
                     n_cols=1024, chunk=chunk)
    return TunedPlan(key=tune_key(n_terms, n_bits, model, shape, pim_mode),
                     kind="gemm", model=model, n_cols=1024, chunk=chunk,
                     backend=backend, predicted_us=cost.time_s * 1e6)


def candidates(n_terms: int, n_bits: int, shape: Tuple[int, int],
               pim_mode: str = "raw",
               backends: Optional[Sequence[str]] = None
               ) -> List[TunedPlan]:
    """Every raced configuration, cost-model-scored, fastest predicted
    first.  Serial multiplier algorithms (``kind="mult"`` registry entries)
    are priced with ``chunk=0``/no backend — they rank in the race but
    cannot lower to a dot program, so :func:`autotune` never picks them for
    execution (on these shapes the partitioned models win the prediction
    anyway, reproducing the paper's ~9x)."""
    from repro.pim import engine
    from repro.pim.cost_model import gemm_cost
    from repro.pim.matmul import max_dot_terms

    m, o = shape
    out: List[TunedPlan] = []
    key_of = lambda model: tune_key(n_terms, n_bits, model, shape, pim_mode)
    for model in PARTITIONED_MODELS:
        for n_cols in GEOMETRIES:
            chunk = min(n_terms, max_dot_terms(n_bits, n_cols))
            if chunk <= 0:
                continue
            cost = gemm_cost(m, n_terms, o, n_bits, model,
                             n_cols=n_cols, chunk=chunk)
            for backend in _allowed_backends(pim_mode, backends):
                out.append(TunedPlan(
                    key=key_of(model), kind="gemm", model=model,
                    n_cols=n_cols, chunk=chunk, backend=backend,
                    predicted_us=cost.time_s * 1e6))
    for name in engine.backends():
        if engine.backend_kind(name) != "mult" or name == "serial":
            continue
        cost = gemm_cost(m, n_terms, o, n_bits, name, n_cols=1024)
        out.append(TunedPlan(key=key_of(name), kind="gemm", model=name,
                             n_cols=1024, chunk=0, backend="",
                             predicted_us=cost.time_s * 1e6))
    # the NOR serial baseline, for the race report
    cost = gemm_cost(m, n_terms, o, n_bits, "baseline", n_cols=1024)
    out.append(TunedPlan(key=key_of("baseline"), kind="gemm",
                         model="baseline", n_cols=1024, chunk=0, backend="",
                         predicted_us=cost.time_s * 1e6))
    out.sort(key=lambda p: p.predicted_us)
    return out


def _trial_time(plan: TunedPlan, n_terms: int, n_bits: int,
                shape: Tuple[int, int], trials: int,
                rng: np.random.Generator) -> float:
    """Median-of-``trials`` wall microseconds for one tuned GEMM call.

    Operands are clipped (M<=8, O<=64 rows) so warmup stays cheap; the
    full inner dimension is kept — chunking is what the race is about.
    Runs through ``matmul_int(plan=...)``, so the winning artifact lands
    in the compile cache and its session pool primed for serving.
    """
    from repro.pim import engine

    m = min(shape[0], 8)
    o = min(shape[1], 64)
    hi = np.uint64(1) << np.uint64(n_bits)
    x = rng.integers(0, hi, size=(m, n_terms), dtype=np.uint64)
    w = rng.integers(0, hi, size=(o, n_terms), dtype=np.uint64)
    engine.matmul_int(x, w, n_bits, plan=plan)  # warm: compile + upload
    best = []
    for _ in range(max(1, trials)):
        t0 = time.perf_counter()
        engine.matmul_int(x, w, n_bits, plan=plan)
        best.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(best))


def autotune(n_terms: int, n_bits: int, shape: Tuple[int, int],
             pim_mode: str = "raw", *, model: str = "minimal",
             trials: int = 1, top_k: int = 3,
             backends: Optional[Sequence[str]] = None,
             force: bool = False) -> TunedPlan:
    """Search (or fetch) the fastest configuration for one compile key.

    Cost-model scores every candidate; the ``top_k`` predicted-fastest
    executable candidates plus the hardcoded default then race in timed
    trials (set ``trials=0`` for a pure cost-model pick).  The winner is
    cached in the table, attached to its ``CompiledPim`` artifact, and
    returned.
    """
    global _hits, _misses, _trials
    key = tune_key(n_terms, n_bits, model, shape, pim_mode)
    with _lock:
        plan = _table.get(key)
        if plan is not None and not force:
            _hits += 1
            _attach(plan, n_terms, n_bits)
            return plan
        _misses += 1

    cands = candidates(n_terms, n_bits, shape, pim_mode, backends)
    execable = [p for p in cands if p.chunk > 0]
    default = default_plan(n_terms, n_bits, shape, pim_mode, model)
    picked = execable[0] if execable else default
    if trials > 0 and execable:
        race = execable[:top_k]
        if not any(p.model == default.model and p.n_cols == default.n_cols
                   and p.chunk == default.chunk
                   and p.backend == default.backend for p in race):
            race.append(default)
        rng = np.random.default_rng(0)
        timed: List[Tuple[float, TunedPlan]] = []
        for p in race:
            t = _trial_time(p, n_terms, n_bits, shape, trials, rng)
            timed.append((t, p))
            with _lock:
                _trials += 1
        t_default = next(t for t, p in timed
                         if (p.model, p.n_cols, p.chunk, p.backend) ==
                         (default.model, default.n_cols, default.chunk,
                          default.backend))
        t_best, best = min(timed, key=lambda tp: tp[0])
        picked = dataclasses.replace(best, key=key, trial_us=t_best,
                                     default_us=t_default, source="trial")
    else:
        picked = dataclasses.replace(picked, key=key, source="cost_model")

    with _lock:
        _table[key] = picked
    _attach(picked, n_terms, n_bits)
    return picked


def _attach(plan: TunedPlan, n_terms: int, n_bits: int) -> None:
    """Pin the pick on its ``CompiledPim`` artifact (cache hits carry it)."""
    if plan.kind != "gemm" or plan.chunk <= 0:
        return
    from repro.pim import engine

    art = engine.compile_matmul(min(plan.chunk, n_terms), n_bits,
                                model=plan.model, n_cols=plan.n_cols)
    if art.plan is not plan:
        object.__setattr__(art, "plan", plan)


def lookup(n_terms: int, n_bits: int, *, shape: Tuple[int, int],
           pim_mode: str, model: str = "minimal") -> Optional[TunedPlan]:
    """Table-only fetch for the hot path (``matmul_int(tune_ctx=...)``):
    returns the cached pick or None — a miss never triggers a search."""
    global _hits, _misses
    if not _enabled:
        return None
    key = tune_key(n_terms, n_bits, model, shape, pim_mode)
    with _lock:
        plan = _table.get(key)
        if plan is None:
            _misses += 1
        else:
            _hits += 1
        return plan


# ==========================================================================
# the quant vs quant_tp split rule
# ==========================================================================

def autotune_linear(tokens: int, d_in: int, d_out: int, *,
                    trials: int = 2, force: bool = False) -> TunedPlan:
    """Race the int8 linear lowerings — single-rank ``quant`` vs the
    shard_mapped ``quant_tp`` tile — for one (tokens, d_in, d_out) shape.

    Bit-identical integer accumulation is the PR 5 contract, so the pick
    is purely a speed decision: quant_tp only pays off once the mesh's
    "model" axis is wide enough to beat its dispatch overhead.  Requires
    an active mesh for quant_tp to differ from quant; runs eagerly jitted.
    """
    global _hits, _misses, _trials
    key = f"linear:t{_bucket_m(tokens)}d{d_in}o{d_out}"
    with _lock:
        plan = _table.get(key)
        if plan is not None and not force:
            _hits += 1
            return plan
        _misses += 1

    import jax
    import jax.numpy as jnp

    from repro.models import layers

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((tokens, d_in)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32)
    timed: List[Tuple[float, str]] = []
    for mode_name in ("quant", "quant_tp"):
        fn = jax.jit(lambda x, w, m=mode_name: layers.linear(x, w, mode=m))
        try:
            fn(x, w).block_until_ready()  # warm: trace + compile
        except Exception:
            continue  # no mesh / backend unavailable: not a candidate
        times = []
        for _ in range(max(1, trials)):
            t0 = time.perf_counter()
            fn(x, w).block_until_ready()
            times.append((time.perf_counter() - t0) * 1e6)
        timed.append((float(np.median(times)), mode_name))
        with _lock:
            _trials += 1
    if not timed:
        raise RuntimeError("no linear lowering could run (quant nor quant_tp)")
    t_best, best = min(timed)
    t_default = next((t for t, nm in timed if nm == "quant"), t_best)
    plan = TunedPlan(key=key, kind="linear", model=best, n_cols=0, chunk=0,
                     backend=best, predicted_us=0.0, trial_us=t_best,
                     default_us=t_default, source="trial")
    with _lock:
        _table[key] = plan
    return plan


# ==========================================================================
# persistence + warmup helpers
# ==========================================================================

TABLE_VERSION = 1


def save_table(path: str) -> int:
    """Write every pick to ``path`` (JSON; format in benchmarks/check.py).
    Returns the number of entries written."""
    with _lock:
        entries = {k: p.to_json() for k, p in sorted(_table.items())}
    doc = {"version": TABLE_VERSION, "entries": entries}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return len(entries)


def load_table(path: str, *, merge: bool = True) -> int:
    """Load picks from ``path``; returns the number of entries loaded.

    Loaded plans are stamped ``source="table"`` — the hit counters then
    show serving warmup reusing picks instead of re-searching.  With
    ``merge=False`` the current table is replaced.
    """
    with open(path) as f:
        doc = json.load(f)
    if doc.get("version") != TABLE_VERSION:
        raise ValueError(f"tuning table {path!r} has version "
                         f"{doc.get('version')!r}, expected {TABLE_VERSION}")
    loaded = {k: dataclasses.replace(TunedPlan.from_json(v), source="table")
              for k, v in doc.get("entries", {}).items()}
    with _lock:
        if not merge:
            _table.clear()
        _table.update(loaded)
    return len(loaded)


def summary() -> str:
    """One-line state for launcher echoes (``serve.py``'s ``[autotune]``)."""
    info = table_info()
    with _lock:
        picks = [p for p in _table.values() if p.kind == "gemm"]
    pick = ""
    if picks:
        p = max(picks, key=lambda p: p.chunk)
        pick = (f"; e.g. {p.key}: model={p.model} n_cols={p.n_cols} "
                f"chunk={p.chunk} backend={p.backend} "
                f"({p.vs_default:.2f}x vs default)")
    return (f"{'on' if info.enabled else 'off'}, {info.size} plan(s), "
            f"{info.hits} hits / {info.misses} misses, "
            f"{info.trials} trials{pick}")


def plan_for_params(params, max_batch: int, *, bits: int = 7,
                    pim_mode: str = "pim_sim", trials: int = 1) -> int:
    """Tune every distinct linear shape in a model's parameter tree.

    Walks the pytree for the trailing ``(K, O)`` dims of 2-D leaves and of
    3-D layer-stacked leaves ``(n_layers, K, O)`` — the weight shapes
    ``sim_linear`` hands the engine.  Each distinct shape is planned at
    the serving batch bucket.  ``sim_linear`` quantizes to ``bits`` and
    multiplies at ``bits+1`` (offset-shifted unsigned), hence the
    ``n_bits`` below.  Returns the number of shapes planned (table hits
    count, so a reloaded table makes this free).
    """
    import jax

    shapes = set()
    for leaf in jax.tree_util.tree_leaves(params):
        shp = getattr(leaf, "shape", None)
        if shp is not None and len(shp) in (2, 3):
            shapes.add((int(shp[-2]), int(shp[-1])))
    for k_dim, o in sorted(shapes):
        autotune(k_dim, bits + 1, (max_batch, o), pim_mode, trials=trials)
    return len(shapes)
