"""PIM algorithms on the PartitionPIM core: executor, arithmetic, cost model."""
from repro.pim import executor
from repro.pim.mult_serial import SerialMultiplier, build_serial_multiplier
from repro.pim.multpim import PartitionedMultiplier, build_multpim
from repro.pim.matmul import PimDot, build_dot, pim_matmul_int
from repro.pim.cost_model import GemmCost, PimDeviceParams, gemm_cost, mult_cost

__all__ = [
    "executor",
    "SerialMultiplier",
    "build_serial_multiplier",
    "PartitionedMultiplier",
    "build_multpim",
    "PimDot",
    "build_dot",
    "pim_matmul_int",
    "GemmCost",
    "PimDeviceParams",
    "gemm_cost",
    "mult_cost",
]
