"""PIM algorithms on the PartitionPIM core: executor, arithmetic, engine,
cost model.

``repro.pim.engine`` is the execution surface: compile-once/execute-many
artifacts, the backend registry, and the ``mode(...)`` selection that
``models.layers.linear`` honours.  The other modules are the synthesis
(program construction) and simulation layers underneath it.
"""
from repro.pim import engine, executor
from repro.pim.mult_serial import SerialMultiplier, build_serial_multiplier
from repro.pim.multpim import PartitionedMultiplier, build_multpim
from repro.pim.matmul import PimDot, build_dot, pim_matmul_int
from repro.pim.cost_model import GemmCost, PimDeviceParams, gemm_cost, mult_cost

__all__ = [
    "engine",
    "executor",
    "SerialMultiplier",
    "build_serial_multiplier",
    "PartitionedMultiplier",
    "build_multpim",
    "PimDot",
    "build_dot",
    "pim_matmul_int",
    "GemmCost",
    "PimDeviceParams",
    "gemm_cost",
    "mult_cost",
]
