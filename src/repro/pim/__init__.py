"""PIM algorithms on the PartitionPIM core: executor, arithmetic, engine,
cost model, autotuner.

``repro.pim.engine`` is the execution surface: compile-once/execute-many
artifacts, the backend registry, and the ``mode(...)`` selection that
``models.layers.linear`` honours.  ``repro.pim.autotune`` is the planner
on top of it — cost-model-driven configuration search with timed-trial
tie-breaks and a persistable tuning table.  The other modules are the
synthesis (program construction) and simulation layers underneath.
"""
from repro.pim import autotune, engine, executor
from repro.pim.autotune import TunedPlan
from repro.pim.mult_serial import SerialMultiplier, build_serial_multiplier
from repro.pim.mult_serial_fast import build_fast_serial_multiplier
from repro.pim.compressor42 import build_compressor42_multiplier
from repro.pim.multpim import PartitionedMultiplier, build_multpim
from repro.pim.matmul import PimDot, build_dot, pim_matmul_int
from repro.pim.cost_model import GemmCost, PimDeviceParams, gemm_cost, mult_cost

__all__ = [
    "autotune",
    "engine",
    "executor",
    "TunedPlan",
    "SerialMultiplier",
    "build_serial_multiplier",
    "build_fast_serial_multiplier",
    "build_compressor42_multiplier",
    "PartitionedMultiplier",
    "build_multpim",
    "PimDot",
    "build_dot",
    "pim_matmul_int",
    "GemmCost",
    "PimDeviceParams",
    "gemm_cost",
    "mult_cost",
]
