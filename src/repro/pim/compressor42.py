"""Serial multiplier reducing two partial-product rows per pass (4:2).

IMPLY/MAGIC-style serial multipliers reduce one partial-product row per
iteration (3:2 carry-save).  The serial 4:2-compressor design
(arXiv 2407.09980) instead consumes TWO multiplier bits per pass: at each
product position the compressor folds (s, c, ppA, ppB) plus a
chained carry-in into one sum bit, one saved carry, and a carry-out —
and because the chain carry-out comes from the FIRST of the two stacked
adders it is independent of the carry-in, so positions chain without a
ripple dependency:

    stage 1:  FA(ppA, ppB, s)   -> t,   cout  (the position chain)
    stage 2:  FA(t,   c,   cin) -> sum, carry (saved for the next pass)

Both stages use the 7-gate NAND/OR/AND full adder from
``mult_serial_fast``; stages degrade to half adders / copies wherever an
operand is known zero at build time.  Halving the pass count amortizes
the accumulator bookkeeping: ~35% fewer cycles than the NOR serial
baseline at 32 bits.  Bit-exact N x N -> 2N for any N >= 2 (odd widths
run one final single-row 3:2 pass).

Layout invariants (why the carry routing below is safe):

* a pass over bits (i, i+1) touches positions [i, i+n+1] and writes saved
  carries only at positions >= i+2 — positions i, i+1 finalize during the
  pass (their residual carry rides the chain), so nothing is ever dropped
  when the next pass's window starts at i+2;
* the accumulator is double-buffered by pass parity; every live (s, c)
  entry is rewritten each pass it stays in-window, so reads always hit
  the immediately-previous parity plane.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.operation import PartitionConfig
from repro.core.program import ProgramBuilder
from repro.pim.mult_serial import SerialMultiplier
from repro.pim.mult_serial_fast import fast_full_adder, fast_half_adder

__all__ = ["build_compressor42_multiplier"]


def _reduce(b: ProgramBuilder, terms: List[int], t: List[int], sum_out: int,
            cout_out: Optional[int]):
    """Fold 1-3 live terms into sum_out (+ optional carry)."""
    if len(terms) == 3:
        fast_full_adder(b, terms[0], terms[1], terms[2], t, sum_out, cout_out)
    elif len(terms) == 2:
        fast_half_adder(b, terms[0], terms[1], t[:2], sum_out, cout_out)
    else:
        b.gate("AND", (terms[0], terms[0]), sum_out)  # 1-gate copy


def build_compressor42_multiplier(n_bits: int = 32, n_cols: int = 1024,
                                  k: int = 32) -> SerialMultiplier:
    """N-bit x N-bit -> 2N-bit product, two multiplier bits per pass."""
    n = n_bits
    if n < 2:
        raise ValueError("compressor42 multiplier needs n_bits >= 2")
    cfg = PartitionConfig(n_cols, k)
    b = ProgramBuilder(cfg, "baseline")

    # -- column layout -------------------------------------------------------
    A = list(range(0, n))
    B = list(range(n, 2 * n))
    # workspace strip [PPA, PPB, TS, T1..T5, T6..T10]: one-range inits
    PPA = 2 * n
    PPB = 2 * n + 1
    TS = 2 * n + 2              # stage-1 sum
    T1 = list(range(2 * n + 3, 2 * n + 8))   # stage-1 temps
    T2 = list(range(2 * n + 8, 2 * n + 13))  # stage-2 temps
    STRIP_HI = T2[-1]
    CC = [2 * n + 13, 2 * n + 14]  # chain carry, alternating by position
    base = 2 * n + 15
    S = [list(range(base, base + 2 * n)),
         list(range(base + 2 * n, base + 4 * n))]
    C = [list(range(base + 4 * n, base + 6 * n)),
         list(range(base + 6 * n, base + 8 * n))]
    assert C[1][-1] < n_cols, "layout exceeds crossbar width"

    # symbolic accumulator: position -> column (None = known zero)
    s_col: Dict[int, Optional[int]] = {}
    c_col: Dict[int, Optional[int]] = {}

    groups: List[Tuple[int, ...]] = [(i, i + 1) for i in range(0, n - 1, 2)]
    if n % 2:
        groups.append((n - 1,))

    for t_idx, bits in enumerate(groups):
        i = bits[0]
        w = (t_idx + 1) % 2  # write parity; reads hit parity t_idx % 2
        lo, hi = i, min(i + n + len(bits) - 1, 2 * n - 1)
        b.init_range(S[w][lo], S[w][hi], "init-sw")
        clo, chi = i + 2, min(i + n, 2 * n - 1)
        if clo <= chi:
            b.init_range(C[w][clo], C[w][chi], "init-cw")
        new_s: Dict[int, Optional[int]] = {}
        new_c: Dict[int, Optional[int]] = {}
        chain: Optional[int] = None  # carry column riding to pos+1
        for pos in range(lo, hi + 1):
            jA = pos - bits[0]
            jB = pos - bits[1] if len(bits) > 1 else -1
            has_ppA = 0 <= jA < n
            has_ppB = 0 <= jB < n
            s = s_col.get(pos)
            c = c_col.get(pos)
            cin = chain
            total = sum(x is not None for x in (s, c, cin)) + has_ppA + has_ppB
            if total == 0:
                new_s[pos] = None
                chain = None
                continue
            b.init_range(PPA, STRIP_HI)
            ppA = ppB = None
            if has_ppA:
                b.gate("AND", (A[jA], B[bits[0]]), PPA, "ppA")
                ppA = PPA
            if has_ppB:
                b.gate("AND", (A[jB], B[bits[1]]), PPB, "ppB")
                ppB = PPB
            sum_out = S[w][pos]
            cc = CC[pos % 2]  # never the column holding cin = CC[(pos-1)%2]
            if total <= 3:
                # one 3:2 stage; the carry rides the chain so it can never
                # land below the next pass's carry window.
                terms = [x for x in (ppA, ppB, s, c, cin) if x is not None]
                cout = None
                if len(terms) >= 2:
                    b.init_range(cc, cc)
                    cout = cc
                _reduce(b, terms, T1, sum_out, cout)
                chain = cout
            else:
                # full 4:2 compressor: stage 1 on (ppA, ppB, s) chains its
                # cout; stage 2 folds (t, c, cin) and saves its carry.
                g1 = [x for x in (ppA, ppB, s) if x is not None]
                b.init_range(cc, cc)
                _reduce(b, g1, T1, TS, cc)
                g2 = [x for x in (TS, c, cin) if x is not None]
                carry_out = C[w][pos + 1] if pos + 1 <= 2 * n - 1 else None
                _reduce(b, g2, T2, sum_out, carry_out)
                if carry_out is not None:
                    new_c[pos + 1] = carry_out
                chain = cc
            new_s[pos] = sum_out
        assert chain is None, "pass carry chain must terminate in-window"
        for pos in range(lo, hi + 1):
            s_col[pos] = new_s.get(pos)
        # every carry in [lo, chi+1] was either consumed this pass or
        # regenerated into new_c; stale entries below clo must clear too.
        for pos in range(lo, min(chi + 2, 2 * n)):
            c_col[pos] = new_c.get(pos)

    # -- final carry-propagate over positions still in redundant form --------
    # The last pass wrote parity len(groups) % 2; final sums go to the OTHER
    # plane (stale in range), and the ripple carry rides the free CC columns.
    live_c = [p for p in range(2 * n) if c_col.get(p) is not None]
    if live_c:
        fin = (len(groups) + 1) % 2
        CARRY: Optional[int] = None
        for pos in range(min(live_c), 2 * n):
            s = s_col.get(pos)
            c = c_col.get(pos)
            sum_out = S[fin][pos]
            terms = [x for x in (s, c, CARRY) if x is not None]
            if not terms:
                s_col[pos] = None
                CARRY = None
                continue
            b.init_range(S[fin][pos], S[fin][pos])
            b.init_range(PPA, STRIP_HI)
            cout_out = None
            if len(terms) >= 2 and pos + 1 < 2 * n:
                cout_out = CC[pos % 2]
                b.init_range(cout_out, cout_out)
            _reduce(b, terms, T1, sum_out, cout_out)
            s_col[pos] = sum_out
            CARRY = cout_out

    result = tuple(
        s_col[p] if s_col.get(p) is not None else PPA for p in range(2 * n)
    )
    if any(s_col.get(p) is None for p in range(2 * n)):
        zero = PPA
        b.init_range(T1[0], T1[0])
        b.init_range(zero, zero)
        b.gate("NOT", (T1[0],), zero)  # NOT(1) = 0
        result = tuple(
            s_col[p] if s_col.get(p) is not None else zero for p in range(2 * n)
        )

    prog = b.program
    prog.name = f"compressor42-mult-{n}b"
    return SerialMultiplier(
        program=prog,
        n_bits=n,
        a_cols=tuple(A),
        b_cols=tuple(B),
        result_cols=result,
    )
