"""Compile-once / execute-many front-end for the PIM stack.

The paper separates *what* a partitioned crossbar computes (the
Operation/Program layer) from *how* it is practically driven (periphery,
control, execution).  This module is the driving side, as one API:

* :func:`compile_dot` / :func:`compile_matmul` — build (once) and cache a
  :class:`CompiledPim` artifact: the gate program, its flat microcode, and
  the I/O column layout, keyed on
  ``(n_terms, n_bits, model, accumulate, n_cols)``.  Repeated calls with
  the same key return the *same* artifact without rebuilding (program
  construction is the expensive Python part — thousands of gate appends).
* :func:`execute` — run an artifact over integer operands on any of the
  registered simulator backends (``"scan"`` lax.scan oracle, ``"unrolled"``
  static-index variant, ``"pallas"`` TPU kernel) through one registry
  instead of scattered imports; :func:`register_backend` adds more.  Note
  ``"unrolled"`` XLA-compiles one op per microcode row — fast per step but
  compile time grows with program length, so reserve it for short programs
  (the benchmark uses it to measure exactly that trade-off).

  Registered backends (one registry = one dispatch point; "state" backends
  map ``(crossbar_state, microcode) -> state``, "linear" backends map
  ``(x, w) -> y`` and are dispatched by ``models.layers.linear``, "mult"
  backends map ``(n_bits, n_cols) -> multiplier build`` and are raced by
  ``pim.autotune`` / priced by ``pim.cost_model``):

  ============  ======  ==========  =========  ============================
  backend       kind    jit         shard_map  grad
  ============  ======  ==========  =========  ============================
  scan/jnp      state   yes         yes        no (integer state)
  unrolled      state   traced-only yes        no (integer state)
  pallas        state   yes         yes        no (integer state)
  numpy         state   host-only   n/a        no (the ``pure_callback``
                                               route; see ``sim_linear``)
  quant_tp      linear  yes         IS one     straight-through custom_vjp
  serial        mult    n/a (build  n/a        n/a (gate program; executes
                        -time only)            on any state backend)
  serial_fast   mult    n/a         n/a        n/a (7-gate NAND/OR/AND FA,
                                               arXiv 2410.09953)
  compressor42  mult    n/a         n/a        n/a (4:2 two-rows-per-pass
                                               reducer, arXiv 2407.09980)
  ============  ======  ==========  =========  ============================

  (The "quant" and "pim_sim" *modes* lower through
  ``kernels.quant_matmul.quant_linear`` — jit yes, shard_map yes,
  grad no — and :func:`sim_linear` — jit via ``pure_callback``,
  shard_map yes, straight-through grad — respectively; they predate the
  registry and keep their direct call sites in ``models.layers``.

  Every non-"xla" lowering quantizes activations **per row**, which makes
  a batched multi-position decode step bit-identical per row to the
  single-position step — the invariant self-speculative decoding
  (``serving.speculative``) turns into throughput: a cheap mode drafts,
  an expensive mode verifies all ``k`` drafts in one step, and greedy
  acceptance is a pure integer token comparison.  Draft and verify must
  share that per-row quantization family ("quant"/"quant_tp"/"pim_sim"
  agree bit-for-bit; "xla" floats differ) for acceptance to stay ~100% —
  any pairing is still *correct* (rejections re-decode exactly), just
  slower.  :func:`draft_ctx` namespaces the drafting pass's
  :class:`ExecutionSession` pool ("draft") so its uploads reuse the
  compiled-artifact cache but can never LRU-evict the verify path's
  resident crossbar state.)
* :class:`ExecutionSession` / :func:`session_for` — persistent execution:
  crossbar state stays resident across ``execute`` calls, keyed per
  (geometry, weight) — a crossbar array in real PIM *is* a weight matrix —
  so repeated GEMMs stream only the *activation* columns while the weights
  stay resident (weight-stationary operation, the paper's steady-state
  driving cost; a program's microcode re-INITs every working column it
  reads and never writes operand columns, so reuse is bit-exact — asserted
  by the test suite).  :func:`matmul_int` (and therefore the ``pim_sim``
  linear) routes through a process-wide session pool, so PIM-mode decode
  pays the full state upload once per (artifact, weight), not once per
  token.
* :func:`mode` / :func:`current_mode` — an explicit, exception-safe context
  manager selecting how ``models.layers.linear`` lowers a matmul
  (``"xla"`` | ``"quant"`` | ``"quant_tp"`` | ``"pim_sim"``), replacing the
  old process-wide mutable mode dict.  ``ModelConfig.pim_mode`` threads the
  same selection through configs (MaxText-style quantization-config
  threading); an explicit config field wins over the ambient context.
  ``"quant_tp"`` is the tensor-parallel quant path: per-rank int8 Pallas
  tiles over the mesh "model" axis (the crossbar-partition analogue at
  mesh level), registered as the ``"quant_tp"`` backend and bit-identical
  to ``"quant"`` at model=1 or outside a mesh.
* :func:`sim_linear` — the bit-accurate crossbar linear, routed through
  ``jax.pure_callback`` with exact result shapes so it composes with
  ``jax.jit`` (the old implementation called ``jax.device_get`` on tracers
  and silently broke under ``jit``/``shard_map``).

Like ``dist.use_mesh``, the ambient mode is read at **trace** time and is
not part of jax's jit cache key: trace (or re-jit) inside the ``mode``
block, one jitted callable per mode.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
from typing import Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MODES",
    "CompiledPim",
    "CacheInfo",
    "compile_dot",
    "compile_matmul",
    "cache_info",
    "clear_cache",
    "register_backend",
    "get_backend",
    "backend_kind",
    "backends",
    "build_multiplier",
    "execute",
    "execute_state",
    "ExecutionSession",
    "session_for",
    "matmul_int",
    "sim_linear",
    "mode",
    "current_mode",
    "resolve_mode",
    "draft_ctx",
    "current_session_ns",
]


# ==========================================================================
# execution-mode selection (replaces the old process-wide mode global)
# ==========================================================================

MODES = ("xla", "quant", "quant_tp", "pim_sim")
_DEFAULT_MODE = "xla"


class _ModeStack(threading.local):
    def __init__(self):
        self.frames = []


_mode_stack = _ModeStack()


def _check_mode(name: str) -> str:
    if name not in MODES:
        raise ValueError(f"unknown PIM mode {name!r}; expected one of {MODES}")
    return name


@contextlib.contextmanager
def mode(name: str) -> Iterator[str]:
    """Select the linear-lowering mode for the enclosed block (re-entrant).

    The prior mode is restored on exit, including on exception.  Thread
    local, so concurrent traces don't race each other.
    """
    _mode_stack.frames.append(_check_mode(name))
    try:
        yield name
    finally:
        _mode_stack.frames.pop()


def current_mode() -> str:
    """The innermost ``mode(...)`` selection, or ``"xla"`` outside any."""
    return _mode_stack.frames[-1] if _mode_stack.frames else _DEFAULT_MODE


def resolve_mode(override: Optional[str] = None) -> str:
    """Explicit (config-threaded) mode if given, else the ambient mode."""
    if override is not None:
        return _check_mode(override)
    return current_mode()


class _NsStack(threading.local):
    def __init__(self):
        self.frames = []


_ns_stack = _NsStack()


@contextlib.contextmanager
def draft_ctx(name: Optional[str] = None) -> Iterator[Optional[str]]:
    """Trace context for a speculative *drafting* pass.

    Drafting runs a second, cheaper lowering (e.g. ``"quant"``) next to the
    verify path's expensive one (``"pim_sim"``) in the same process.  Both
    must share the compiled-artifact cache (gate programs are keyed on
    shape/bits/model, not on who asked), but they must *not* share
    ``ExecutionSession`` resident state: the pools are LRU-bounded, and a
    drafting pass that cycles weights through a verify session would evict
    the verify path's resident crossbars — turning every verify step back
    into cold uploads and silently erasing the speedup speculation exists
    to deliver.  Inside this context, ``sim_linear`` (and anything else
    that passes ``current_session_ns()`` to :func:`session_for` /
    :func:`matmul_int`) resolves to a ``"draft"``-namespaced session pool:
    same artifacts, separate resident state.  The namespace is read at
    **trace** time (like :func:`mode`) and baked into the host callback,
    so it holds when the jitted draft step later executes.

    ``name`` optionally selects the draft's lowering mode as well —
    ``draft_ctx("quant")`` is ``mode("quant")`` plus the namespace.
    Re-entrant and exception-safe; thread-local like the mode stack.
    """
    _ns_stack.frames.append("draft")
    try:
        if name is None:
            yield None
        else:
            with mode(name):
                yield name
    finally:
        _ns_stack.frames.pop()


def current_session_ns() -> str:
    """``"draft"`` inside :func:`draft_ctx`, else ``""`` (the verify/default
    session namespace)."""
    return _ns_stack.frames[-1] if _ns_stack.frames else ""


# ==========================================================================
# compile cache
# ==========================================================================

@dataclasses.dataclass(frozen=True, eq=False)
class CompiledPim:
    """An executable PIM artifact: program + microcode + I/O columns.

    Immutable and shared — every cache hit returns the same object, so
    treat ``microcode`` as read-only.
    """

    key: Tuple
    program: "object"               # repro.core.program.Program
    microcode: np.ndarray           # (G, 4) int32 flat microcode
    n_bits: int
    n_terms: int
    x_cols: Tuple[Tuple[int, ...], ...]
    w_cols: Tuple[Tuple[int, ...], ...]
    acc_cols: Tuple[int, ...]
    # winning autotune.TunedPlan, attached (object.__setattr__) by the tuner
    # when this artifact is the picked configuration for its compile key;
    # None until tuned.  Not part of the cache key.
    plan: Optional["object"] = None

    @property
    def n_cols(self) -> int:
        return self.program.cfg.n


@dataclasses.dataclass(frozen=True)
class CacheInfo:
    hits: int
    misses: int
    builds: int
    size: int
    # backend-level execution counters (ExecutionSession): how many executes
    # reused resident crossbar state (streaming only activation columns —
    # the weights were already resident) vs paid a cold full-state upload.
    exec_hits: int = 0
    exec_uploads: int = 0
    # autotuner counters (pim.autotune): table lookups served from a cached
    # pick vs searches run, and how many timed candidate trials those
    # searches spent.
    tune_hits: int = 0
    tune_misses: int = 0
    tune_trials: int = 0


_cache: Dict[Tuple, CompiledPim] = {}
_cache_lock = threading.Lock()
_hits = 0
_misses = 0
_builds = 0


def compile_dot(n_terms: int, n_bits: int = 8, *, model: str = "minimal",
                accumulate: str = "carry_save", n_cols: int = 1024
                ) -> CompiledPim:
    """Compile (or fetch) the single-row dot-product program.

    The artifact computes ``sum_i x_i * w_i`` over ``n_terms`` pairs of
    ``n_bits``-bit unsigned ints per simulator row.
    """
    global _hits, _misses, _builds
    key = (n_terms, n_bits, model, accumulate, n_cols)
    with _cache_lock:
        art = _cache.get(key)
        if art is not None:
            _hits += 1
            return art
        _misses += 1
    # build outside the lock: a multi-second build must not stall unrelated
    # cache hits or other keys' builds.  On a lost race the first insert
    # wins and the duplicate build is discarded.
    from repro.pim.matmul import build_dot

    dot = build_dot(n_terms, n_bits, n_cols=n_cols, model=model,
                    accumulate=accumulate)
    art = CompiledPim(
        key=key,
        program=dot.program,
        microcode=dot.program.to_microcode(),
        n_bits=dot.n_bits,
        n_terms=dot.n_terms,
        x_cols=dot.x_cols,
        w_cols=dot.w_cols,
        acc_cols=dot.acc_cols,
    )
    with _cache_lock:
        existing = _cache.get(key)
        if existing is not None:
            return existing
        _builds += 1
        _cache[key] = art
        return art


def compile_matmul(n_terms: int, n_bits: int = 8, *, model: str = "minimal",
                   accumulate: str = "carry_save", n_cols: int = 1024
                   ) -> CompiledPim:
    """Compile (or fetch) the artifact driving an integer GEMM.

    A GEMM with inner dimension ``K = n_terms`` runs the dot program on
    every (m, o) output element concurrently — one simulator row each —
    so the artifact is exactly the dot artifact; this alias documents the
    intent at GEMM call sites.
    """
    return compile_dot(n_terms, n_bits, model=model, accumulate=accumulate,
                       n_cols=n_cols)


def cache_info() -> CacheInfo:
    from repro.pim import autotune

    with _cache_lock:
        info = CacheInfo(hits=_hits, misses=_misses, builds=_builds,
                         size=len(_cache))
    with _session_lock:
        info = dataclasses.replace(info, exec_hits=_exec_hits,
                                   exec_uploads=_exec_uploads)
    t = autotune.table_info()
    return dataclasses.replace(info, tune_hits=t.hits, tune_misses=t.misses,
                               tune_trials=t.trials)


def clear_cache() -> None:
    global _hits, _misses, _builds, _exec_hits, _exec_uploads
    from repro.pim import autotune

    with _cache_lock:
        _cache.clear()
        _hits = _misses = _builds = 0
    with _session_lock:
        _sessions.clear()
        _exec_hits = _exec_uploads = 0
    # picks must not leak across benchmark runs: the tuner table (and its
    # counters) clears with the compile cache it indexes into
    autotune.clear()


# ==========================================================================
# backend registry
# ==========================================================================

# A "state" backend maps (state, microcode, **kw) -> new state, where state
# is the bit-packed (C, n, W) uint32 crossbar tensor and microcode the
# (G, 4) rows; a "linear" backend maps (x, w, **kw) -> y over float
# operands and is dispatched by models.layers.linear; a "mult" backend maps
# (n_bits, n_cols, **kw) -> a built multiplier (program + I/O columns) and
# is dispatched by build_multiplier for cost_model pricing and autotune
# races (see the registry table in the module docstring).  One registry,
# tagged kinds: picking a name of the wrong kind at a dispatch point is a
# clear error, not a shape explosion deep in a kernel.
Backend = Callable[..., "object"]

BACKEND_KINDS = ("state", "linear", "mult")

_backends: Dict[str, Backend] = {}
_backend_kinds: Dict[str, str] = {}
_backends_lock = threading.Lock()


def register_backend(name: str, fn: Backend, *, kind: str = "state") -> None:
    if kind not in BACKEND_KINDS:
        raise ValueError(f"backend kind must be one of {BACKEND_KINDS}, "
                         f"got {kind!r}")
    with _backends_lock:
        _backends[name] = fn
        _backend_kinds[name] = kind


_defaults_registered = False


def _ensure_default_backends() -> None:
    global _defaults_registered
    if _defaults_registered:
        return
    from repro.kernels.crossbar_exec.ref import crossbar_exec_ref
    from repro.pim import executor as ex

    def scan(state, microcode, **kw):
        # crossbar_exec_ref owns the donate-argnums contract (copies the
        # caller's state before the donating executor.execute)
        return crossbar_exec_ref(state, microcode)

    def unrolled(state, microcode, **kw):
        return ex.execute_unrolled(state, np.asarray(microcode))

    def pallas(state, microcode, **kw):
        from repro.kernels.crossbar_exec.crossbar_exec import crossbar_exec

        return crossbar_exec(state, jnp.asarray(microcode, jnp.int32),
                             w_tile=kw.get("w_tile", 128))

    def quant_tp(x, w, **kw):
        # linear-lowering backend (see the registry table in the module
        # docstring): operands are (x, w) float arrays, not crossbar state.
        # models.layers.linear dispatches mode "quant_tp" here; the tile
        # shards over the active mesh's "model" axis at trace time.
        from repro.kernels.quant_matmul.tp import tp_quant_linear

        return tp_quant_linear(x, w, **kw)

    from repro.pim.compressor42 import build_compressor42_multiplier
    from repro.pim.mult_serial import build_serial_multiplier
    from repro.pim.mult_serial_fast import build_fast_serial_multiplier

    with _backends_lock:
        for nm, fn, kind in (("scan", scan, "state"),
                             ("jnp", scan, "state"),  # historical alias
                             ("unrolled", unrolled, "state"),
                             ("pallas", pallas, "state"),
                             ("numpy", _numpy_interpret, "state"),
                             ("quant_tp", quant_tp, "linear"),
                             ("serial", build_serial_multiplier, "mult"),
                             ("serial_fast", build_fast_serial_multiplier,
                              "mult"),
                             ("compressor42", build_compressor42_multiplier,
                              "mult")):
            _backends.setdefault(nm, fn)
            _backend_kinds.setdefault(nm, kind)
        # only after everything registered: a failed import above leaves the
        # flag unset so the next call retries, and a concurrent caller never
        # observes the flag without the backends
        _defaults_registered = True


def _numpy_interpret(state, microcode, **kw):
    """Pure-numpy microcode interpreter (no jax anywhere).

    The only backend safe to run *inside* a ``jax.pure_callback`` — jax
    does not support re-entering jax (even jitted eager calls) from a host
    callback, so :func:`sim_linear` routes here.  Semantics match
    ``executor.execute`` bit for bit; gate codes follow ``GATE_CODES``.
    """
    st = np.array(state, dtype=np.uint32, copy=True)
    ones = np.uint32(0xFFFFFFFF)
    for code, ia, ib, out in np.asarray(microcode).tolist():
        a = st[:, ia, :]
        b = st[:, ib, :]
        if code == 0:                       # INIT
            res = np.full_like(a, ones)
        elif code == 1:                     # NOT
            res = ~a
        elif code == 2:                     # NOR
            res = ~(a | b)
        elif code == 3:                     # OR
            res = a | b
        elif code == 4:                     # NAND
            res = ~(a & b)
        else:                               # AND
            res = a & b
        st[:, out, :] = res
    return st


def get_backend(name: str) -> Backend:
    _ensure_default_backends()
    with _backends_lock:
        fn = _backends.get(name)
    if fn is None:
        raise ValueError(f"unknown backend {name!r}; "
                         f"registered: {sorted(_backends)}")
    return fn


def backends() -> Tuple[str, ...]:
    _ensure_default_backends()
    with _backends_lock:
        return tuple(sorted(_backends))


def backend_kind(name: str) -> str:
    """``"state"``, ``"linear"`` or ``"mult"`` (see the registry
    comment above)."""
    _ensure_default_backends()
    with _backends_lock:
        if name not in _backends:
            raise ValueError(f"unknown backend {name!r}; "
                             f"registered: {sorted(_backends)}")
        return _backend_kinds.get(name, "state")


def execute_state(state, microcode, *, backend: str = "scan", **kw):
    """Run flat microcode over raw crossbar state on the chosen backend."""
    kind = backend_kind(backend)
    if kind != "state":
        what = ("a linear lowering" if kind == "linear"
                else "a multiplier algorithm")
        raise ValueError(
            f"backend {backend!r} is {what}, not a crossbar-state "
            f"executor; it cannot run microcode")
    return get_backend(backend)(state, microcode, **kw)


def build_multiplier(name: str, n_bits: int, *, n_cols: int = 1024, **kw):
    """Build (uncached) a registered multiplier algorithm by name.

    Dispatches ``kind="mult"`` registry entries — the algorithms the
    autotuner races and ``cost_model.mult_cost`` prices.  Guarded like
    :func:`execute_state`: a state/linear backend name is a clear error.
    """
    kind = backend_kind(name)
    if kind != "mult":
        raise ValueError(
            f"backend {name!r} is a {kind!r} backend, not a multiplier "
            f"algorithm; it cannot build a gate program")
    return get_backend(name)(n_bits, n_cols, **kw)


# ==========================================================================
# execution front-end
# ==========================================================================

def _grid_shape(artifact: CompiledPim, x: np.ndarray, w: np.ndarray,
                rows_per_crossbar: int) -> Tuple[int, int, int, int, int]:
    """Validate operands; return ``(M, O, K, n_cb, total)`` of the row grid."""
    M, K = x.shape
    O, K2 = w.shape
    if K != K2:
        raise ValueError(f"inner dims disagree: x {x.shape} vs w {w.shape}")
    if K != artifact.n_terms:
        raise ValueError(
            f"artifact compiled for {artifact.n_terms} terms, got K={K}")
    total = M * O
    n_cb = (total + rows_per_crossbar - 1) // rows_per_crossbar
    return M, O, K, n_cb, total


def _pack_grid(grid: np.ndarray, n_cb: int, rows_per_crossbar: int
               ) -> np.ndarray:
    """(M*O, K) operand rows -> (n_cb, rows_per_crossbar, K), zero-padded to
    whole crossbars (the paper's rows x crossbars way-parallelism)."""
    pad = n_cb * rows_per_crossbar - grid.shape[0]
    if pad:
        grid = np.pad(grid, ((0, pad), (0, 0)))
    return grid.reshape(n_cb, rows_per_crossbar, grid.shape[-1])


_sessions: Dict[Tuple, "ExecutionSession"] = {}
_session_lock = threading.Lock()
_exec_hits = 0
_exec_uploads = 0


class ExecutionSession:
    """Persistent crossbar execution for one compiled artifact.

    Resident state is kept per ``(geometry, weight)`` — a crossbar array in
    real PIM *is* a weight matrix, so each distinct weight gets its own
    resident copy (bounded by ``max_resident``, LRU-evicted).  The first
    ``execute`` against a weight pays a full state upload (a *cold
    upload*); every later call with that weight reuses the post-execution
    state and streams only the activation columns — the weights stay
    resident in the crossbar, exactly the serving decode steady state the
    ROADMAP's "batched/persistent" item describes.  Reuse is bit-exact
    because every dot/matmul program INITs each working column before
    reading it, and never writes its operand columns (verified by
    ``tests/test_engine_session.py``).

    ``max_resident`` bounds the resident set (LRU eviction).  It is sized
    for the simulator's tiny-shape domain; a cyclic access pattern larger
    than the cap has a 0% hit rate and degenerates to cold uploads — raise
    it (via :func:`session_for`) before concluding the persistent path is
    broken.  Instances also feed the process-wide ``cache_info`` execution
    counters (``exec_hits`` / ``exec_uploads``).  Not thread-safe; share
    across threads only with external locking (the pooled sessions from
    :func:`session_for` are fine under the ``pure_callback`` host route,
    which serializes per device).
    """

    def __init__(self, artifact: CompiledPim, *, backend: str = "scan",
                 rows_per_crossbar: int = 256, max_resident: int = 1024,
                 **backend_kw):
        self.artifact = artifact
        self.backend = backend
        self.rows_per_crossbar = rows_per_crossbar
        self.max_resident = max_resident
        self.backend_kw = backend_kw
        self._states: Dict[Tuple, "object"] = {}  # (geometry, w bytes)
        self.uploads = 0
        self.hits = 0

    def _count(self, cold: bool) -> None:
        global _exec_hits, _exec_uploads
        with _session_lock:
            if cold:
                _exec_uploads += 1
            else:
                _exec_hits += 1

    def reset(self) -> None:
        """Drop resident state (next execute pays a cold upload again)."""
        self._states.clear()

    def execute(self, x: np.ndarray, w: np.ndarray) -> np.ndarray:
        """Integer GEMM ``(M, K) x (O, K) -> (M, O)`` on resident state.

        Exact for unsigned operands up to ``artifact.n_bits`` bits;
        returns uint64.
        """
        from repro.pim import executor as ex

        art = self.artifact
        rows = self.rows_per_crossbar
        x = np.asarray(x)
        w = np.asarray(w)
        M, O, K, n_cb, total = _grid_shape(art, x, w, rows)

        # key resident state by the weight *bytes* (native dtype, no
        # conversion copy): dict equality compares them exactly, so a hash
        # collision can never silently reuse another weight's crossbar state
        key = (n_cb, M, O, w.dtype.str, w.tobytes())
        state = self._states.pop(key, None)      # pop: re-insert moves to MRU
        cold = state is None

        xs = _pack_grid(np.repeat(x, O, axis=0), n_cb, rows)
        # the tiled weight grid is only consumed on a cold upload — warm
        # (weight-stationary) calls never build it
        ws = _pack_grid(np.tile(w, (M, 1)), n_cb, rows) if cold else None

        if self.backend == "numpy":
            # jax-free round trip (callback-safe, see _numpy_interpret)
            if cold:
                w_words = (rows + 31) // 32
                state = np.zeros((n_cb, art.n_cols, w_words), np.uint32)
            else:
                state = np.array(state, copy=True)

            def write(cols, values):
                values = np.asarray(values, np.uint64)
                for bit, c in enumerate(cols):
                    state[:, c, :] = ex.pack_rows(
                        (values >> np.uint64(bit)) & np.uint64(1))

            for i in range(K):
                write(art.x_cols[i], xs[:, :, i])
                if cold:
                    write(art.w_cols[i], ws[:, :, i])
        else:
            if cold:
                state = ex.blank_state(n_cb, art.n_cols, rows)
            for i in range(K):
                state = ex.write_numbers(state, art.x_cols[i], xs[:, :, i])
                if cold:
                    state = ex.write_numbers(state, art.w_cols[i],
                                             ws[:, :, i])
        state = execute_state(state, art.microcode, backend=self.backend,
                              **self.backend_kw)
        self._states[key] = state
        while len(self._states) > self.max_resident:
            self._states.pop(next(iter(self._states)))   # LRU eviction
        if cold:
            self.uploads += 1
        else:
            self.hits += 1                       # resident weights: x-only
        self._count(cold)
        acc = ex.read_numbers(state, art.acc_cols, rows)
        return acc.reshape(-1)[:total].reshape(M, O)


def session_for(artifact: CompiledPim, *, backend: str = "scan",
                rows_per_crossbar: int = 256,
                max_resident: Optional[int] = None,
                namespace: str = "") -> ExecutionSession:
    """The process-wide persistent session for ``(artifact, backend,
    rows_per_crossbar, namespace)`` — created on first use, then reused so
    repeated GEMMs with the same artifact keep their crossbar state
    resident.  ``max_resident`` applies on creation (and raises the cap of
    an existing session).  ``namespace`` partitions the pool — a
    speculative drafting pass runs under ``"draft"`` (see
    :func:`draft_ctx`) so its uploads can never LRU-evict the verify
    path's resident state.  ``clear_cache()`` drops all pooled sessions."""
    key = (artifact.key, backend, rows_per_crossbar, namespace)
    with _session_lock:
        sess = _sessions.get(key)
        if sess is None:
            sess = ExecutionSession(artifact, backend=backend,
                                    rows_per_crossbar=rows_per_crossbar,
                                    **({} if max_resident is None
                                       else {"max_resident": max_resident}))
            _sessions[key] = sess
        elif max_resident is not None:
            sess.max_resident = max(sess.max_resident, max_resident)
        return sess


def execute(artifact: CompiledPim, x: np.ndarray, w: np.ndarray, *,
            backend: str = "scan", rows_per_crossbar: int = 256,
            **backend_kw) -> np.ndarray:
    """One-shot integer GEMM: (M, K) x (O, K) -> (M, O).

    Allocates fresh crossbar state every call (counted as a cold upload).
    Steady-state callers — anything executing the same artifact repeatedly —
    should hold an :class:`ExecutionSession` (or go through
    :func:`session_for` / :func:`matmul_int`, which pool sessions) instead.
    """
    sess = ExecutionSession(artifact, backend=backend,
                            rows_per_crossbar=rows_per_crossbar,
                            **backend_kw)
    return sess.execute(x, w)


def matmul_int(x: np.ndarray, w: np.ndarray, n_bits: int = 8, *,
               model: str = "minimal", rows_per_crossbar: int = 256,
               backend: str = "scan", accumulate: str = "carry_save",
               plan: Optional["object"] = None,
               tune_ctx: Optional[str] = None,
               session_ns: str = "") -> np.ndarray:
    """Compile-and-execute convenience: bit-exact integer GEMM.

    The compile step is cached — calling twice with the same (K, n_bits,
    model) builds the gate program exactly once.  Execution goes through
    the pooled :class:`ExecutionSession` for the artifact, so repeated
    calls (the ``pim_sim`` decode loop) keep crossbar state resident and
    stream only operand columns.  Inner dimensions longer than one row's
    column budget are split into chunked GEMMs (at most two distinct chunk
    sizes, both cached) whose uint64 partials are summed exactly on the
    host — so any K works, not just what fits one row.

    ``plan`` (an ``autotune.TunedPlan``) overrides model / crossbar
    geometry / chunking / execution backend with a tuned pick; passing
    ``tune_ctx`` (a pim-mode string, e.g. ``"pim_sim"``) instead looks the
    plan up in the autotuner table when tuning is enabled — a miss falls
    back to the defaults above, it never triggers a search.  Every tuned
    configuration computes the same exact integer GEMM, so plans change
    speed, never results.

    ``session_ns`` routes execution to a namespaced session pool (see
    :func:`draft_ctx`): a speculative drafting pass passes ``"draft"`` so
    its state uploads never evict the verify path's resident crossbars.
    """
    from repro.pim.matmul import max_dot_terms

    K = x.shape[1]
    if plan is None and tune_ctx is not None:
        from repro.pim import autotune

        plan = autotune.lookup(K, n_bits, shape=(x.shape[0], w.shape[0]),
                               pim_mode=tune_ctx, model=model)
    n_cols = 1024
    if plan is not None:
        model, n_cols, backend = plan.model, plan.n_cols, plan.backend
    chunk = max_dot_terms(n_bits, n_cols)
    if chunk <= 0:
        raise ValueError(f"n_bits={n_bits} does not fit the crossbar layout")
    if plan is not None and 0 < plan.chunk <= chunk:
        chunk = plan.chunk

    def run(xs, ws):
        artifact = compile_matmul(xs.shape[1], n_bits, model=model,
                                  accumulate=accumulate, n_cols=n_cols)
        return session_for(artifact, backend=backend,
                           rows_per_crossbar=rows_per_crossbar,
                           namespace=session_ns).execute(xs, ws)

    if K <= chunk:
        return run(x, w)
    acc = None
    for lo in range(0, K, chunk):
        part = run(x[:, lo:lo + chunk], w[:, lo:lo + chunk])
        acc = part if acc is None else acc + part
    return acc


# ==========================================================================
# jit-composable simulator linear
# ==========================================================================

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _sim_mm(bits: int, model: str, backend: str, ns: str, x, w):
    out_shape = x.shape[:-1] + (w.shape[-1],)
    out_dtype = jnp.result_type(x.dtype)
    qmax = 2 ** (bits - 1) - 1
    off = qmax + 1

    def host(xv, wv):
        xf = np.asarray(xv, np.float32)
        wf = np.asarray(wv, np.float32)
        lead = xf.shape[:-1]
        xf = xf.reshape(-1, xf.shape[-1])
        xs = np.maximum(np.abs(xf).max(axis=1, keepdims=True), 1e-8) / qmax
        wsc = np.maximum(np.abs(wf).max(axis=0, keepdims=True), 1e-8) / qmax
        xq = np.clip(np.round(xf / xs), -qmax, qmax).astype(np.int64)
        wq = np.clip(np.round(wf / wsc), -qmax, qmax).astype(np.int64)
        # crossbars store magnitudes; signs handled by 2's-complement
        # offset: shift into unsigned, multiply, correct ((a+off)(b+off))
        # tune_ctx="pim_sim": pick up any tuned plan for this (K, n_bits)
        # — a no-op unless autotune is enabled and the table has a pick
        acc = matmul_int((xq + off).astype(np.uint64),
                         (wq.T + off).astype(np.uint64),
                         n_bits=bits + 1, model=model, backend=backend,
                         tune_ctx="pim_sim", session_ns=ns)
        acc = acc.astype(np.int64)
        corr = (off * (wq.sum(axis=0, keepdims=True) + off * xq.shape[1])
                + off * xq.sum(axis=1, keepdims=True))
        y = (acc - corr) * (xs * wsc)
        return y.reshape(*lead, wf.shape[1]).astype(out_dtype)

    result = jax.ShapeDtypeStruct(out_shape, out_dtype)
    return jax.pure_callback(host, result, x, w)


def _sim_mm_fwd(bits, model, backend, ns, x, w):
    return _sim_mm(bits, model, backend, ns, x, w), (x, w)


def _sim_mm_bwd(bits, model, backend, ns, res, g):
    # straight-through estimator: the forward is the quantized crossbar
    # result, the backward differentiates the ideal float matmul (standard
    # QAT practice; pure_callback itself defines no JVP/VJP)
    x, w = res
    gx = jnp.einsum("...n,kn->...k", g, w.astype(g.dtype)).astype(x.dtype)
    gw = jnp.einsum("...k,...n->kn", x.astype(g.dtype), g).astype(w.dtype)
    return gx, gw


_sim_mm.defvjp(_sim_mm_fwd, _sim_mm_bwd)


def sim_linear(x, w, bits: int = 7, *, model: str = "minimal",
               backend: str = "numpy"):
    """Bit-exact crossbar execution of ``x @ w`` (tiny shapes only).

    7-bit symmetric quantization so the offset-shifted unsigned operands
    fit the 8-bit (power-of-two partition count) MultPIM multiplier.  The
    simulator runs on the host through ``jax.pure_callback`` with the exact
    result ``ShapeDtypeStruct``, so the call traces under ``jax.jit`` (and
    inside ``shard_map``) and the jitted result is bit-identical to eager —
    both paths execute the same host computation.  Differentiable via a
    straight-through ``custom_vjp`` (gradient of the ideal matmul), so a
    ``pim_sim`` model trains.  The host computation defaults to the pure-
    numpy backend: jax may not be re-entered from inside a host callback.

    The ambient session namespace (:func:`current_session_ns`, set by
    :func:`draft_ctx`) is read here at trace time and baked into the host
    callback, so a jitted drafting step keeps hitting the draft-namespaced
    session pool at execution time — the callback runs on jax's runtime
    threads, where the trace-site thread-local would be invisible.
    """
    return _sim_mm(bits, model, backend, current_session_ns(), x, w)
