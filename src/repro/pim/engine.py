"""Compile-once / execute-many front-end for the PIM stack.

The paper separates *what* a partitioned crossbar computes (the
Operation/Program layer) from *how* it is practically driven (periphery,
control, execution).  This module is the driving side, as one API:

* :func:`compile_dot` / :func:`compile_matmul` — build (once) and cache a
  :class:`CompiledPim` artifact: the gate program, its flat microcode, and
  the I/O column layout, keyed on
  ``(n_terms, n_bits, model, accumulate, n_cols)``.  Repeated calls with
  the same key return the *same* artifact without rebuilding (program
  construction is the expensive Python part — thousands of gate appends).
* :func:`execute` — run an artifact over integer operands on any of the
  registered simulator backends (``"scan"`` lax.scan oracle, ``"unrolled"``
  static-index variant, ``"pallas"`` TPU kernel) through one registry
  instead of scattered imports; :func:`register_backend` adds more.  Note
  ``"unrolled"`` XLA-compiles one op per microcode row — fast per step but
  compile time grows with program length, so reserve it for short programs
  (the benchmark uses it to measure exactly that trade-off).
* :func:`mode` / :func:`current_mode` — an explicit, exception-safe context
  manager selecting how ``models.layers.linear`` lowers a matmul
  (``"xla"`` | ``"quant"`` | ``"pim_sim"``), replacing the old
  process-wide mutable mode dict.  ``ModelConfig.pim_mode`` threads the same selection
  through configs (MaxText-style quantization-config threading); an
  explicit config field wins over the ambient context.
* :func:`sim_linear` — the bit-accurate crossbar linear, routed through
  ``jax.pure_callback`` with exact result shapes so it composes with
  ``jax.jit`` (the old implementation called ``jax.device_get`` on tracers
  and silently broke under ``jit``/``shard_map``).

Like ``dist.use_mesh``, the ambient mode is read at **trace** time and is
not part of jax's jit cache key: trace (or re-jit) inside the ``mode``
block, one jitted callable per mode.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
from typing import Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MODES",
    "CompiledPim",
    "CacheInfo",
    "compile_dot",
    "compile_matmul",
    "cache_info",
    "clear_cache",
    "register_backend",
    "get_backend",
    "backends",
    "execute",
    "execute_state",
    "matmul_int",
    "sim_linear",
    "mode",
    "current_mode",
    "resolve_mode",
]


# ==========================================================================
# execution-mode selection (replaces the old process-wide mode global)
# ==========================================================================

MODES = ("xla", "quant", "pim_sim")
_DEFAULT_MODE = "xla"


class _ModeStack(threading.local):
    def __init__(self):
        self.frames = []


_mode_stack = _ModeStack()


def _check_mode(name: str) -> str:
    if name not in MODES:
        raise ValueError(f"unknown PIM mode {name!r}; expected one of {MODES}")
    return name


@contextlib.contextmanager
def mode(name: str) -> Iterator[str]:
    """Select the linear-lowering mode for the enclosed block (re-entrant).

    The prior mode is restored on exit, including on exception.  Thread
    local, so concurrent traces don't race each other.
    """
    _mode_stack.frames.append(_check_mode(name))
    try:
        yield name
    finally:
        _mode_stack.frames.pop()


def current_mode() -> str:
    """The innermost ``mode(...)`` selection, or ``"xla"`` outside any."""
    return _mode_stack.frames[-1] if _mode_stack.frames else _DEFAULT_MODE


def resolve_mode(override: Optional[str] = None) -> str:
    """Explicit (config-threaded) mode if given, else the ambient mode."""
    if override is not None:
        return _check_mode(override)
    return current_mode()


# ==========================================================================
# compile cache
# ==========================================================================

@dataclasses.dataclass(frozen=True, eq=False)
class CompiledPim:
    """An executable PIM artifact: program + microcode + I/O columns.

    Immutable and shared — every cache hit returns the same object, so
    treat ``microcode`` as read-only.
    """

    key: Tuple
    program: "object"               # repro.core.program.Program
    microcode: np.ndarray           # (G, 4) int32 flat microcode
    n_bits: int
    n_terms: int
    x_cols: Tuple[Tuple[int, ...], ...]
    w_cols: Tuple[Tuple[int, ...], ...]
    acc_cols: Tuple[int, ...]

    @property
    def n_cols(self) -> int:
        return self.program.cfg.n


@dataclasses.dataclass(frozen=True)
class CacheInfo:
    hits: int
    misses: int
    builds: int
    size: int


_cache: Dict[Tuple, CompiledPim] = {}
_cache_lock = threading.Lock()
_hits = 0
_misses = 0
_builds = 0


def compile_dot(n_terms: int, n_bits: int = 8, *, model: str = "minimal",
                accumulate: str = "carry_save", n_cols: int = 1024
                ) -> CompiledPim:
    """Compile (or fetch) the single-row dot-product program.

    The artifact computes ``sum_i x_i * w_i`` over ``n_terms`` pairs of
    ``n_bits``-bit unsigned ints per simulator row.
    """
    global _hits, _misses, _builds
    key = (n_terms, n_bits, model, accumulate, n_cols)
    with _cache_lock:
        art = _cache.get(key)
        if art is not None:
            _hits += 1
            return art
        _misses += 1
    # build outside the lock: a multi-second build must not stall unrelated
    # cache hits or other keys' builds.  On a lost race the first insert
    # wins and the duplicate build is discarded.
    from repro.pim.matmul import build_dot

    dot = build_dot(n_terms, n_bits, n_cols=n_cols, model=model,
                    accumulate=accumulate)
    art = CompiledPim(
        key=key,
        program=dot.program,
        microcode=dot.program.to_microcode(),
        n_bits=dot.n_bits,
        n_terms=dot.n_terms,
        x_cols=dot.x_cols,
        w_cols=dot.w_cols,
        acc_cols=dot.acc_cols,
    )
    with _cache_lock:
        existing = _cache.get(key)
        if existing is not None:
            return existing
        _builds += 1
        _cache[key] = art
        return art


def compile_matmul(n_terms: int, n_bits: int = 8, *, model: str = "minimal",
                   accumulate: str = "carry_save", n_cols: int = 1024
                   ) -> CompiledPim:
    """Compile (or fetch) the artifact driving an integer GEMM.

    A GEMM with inner dimension ``K = n_terms`` runs the dot program on
    every (m, o) output element concurrently — one simulator row each —
    so the artifact is exactly the dot artifact; this alias documents the
    intent at GEMM call sites.
    """
    return compile_dot(n_terms, n_bits, model=model, accumulate=accumulate,
                       n_cols=n_cols)


def cache_info() -> CacheInfo:
    with _cache_lock:
        return CacheInfo(hits=_hits, misses=_misses, builds=_builds,
                         size=len(_cache))


def clear_cache() -> None:
    global _hits, _misses, _builds
    with _cache_lock:
        _cache.clear()
        _hits = _misses = _builds = 0


# ==========================================================================
# backend registry
# ==========================================================================

# A backend maps (state, microcode, **kw) -> new state, where state is the
# bit-packed (C, n, W) uint32 crossbar tensor and microcode the (G, 4) rows.
Backend = Callable[..., "object"]

_backends: Dict[str, Backend] = {}
_backends_lock = threading.Lock()


def register_backend(name: str, fn: Backend) -> None:
    with _backends_lock:
        _backends[name] = fn


_defaults_registered = False


def _ensure_default_backends() -> None:
    global _defaults_registered
    if _defaults_registered:
        return
    from repro.kernels.crossbar_exec.ref import crossbar_exec_ref
    from repro.pim import executor as ex

    def scan(state, microcode, **kw):
        # crossbar_exec_ref owns the donate-argnums contract (copies the
        # caller's state before the donating executor.execute)
        return crossbar_exec_ref(state, microcode)

    def unrolled(state, microcode, **kw):
        return ex.execute_unrolled(state, np.asarray(microcode))

    def pallas(state, microcode, **kw):
        from repro.kernels.crossbar_exec.crossbar_exec import crossbar_exec

        return crossbar_exec(state, jnp.asarray(microcode, jnp.int32),
                             w_tile=kw.get("w_tile", 128))

    with _backends_lock:
        _backends.setdefault("scan", scan)
        _backends.setdefault("jnp", scan)          # historical alias
        _backends.setdefault("unrolled", unrolled)
        _backends.setdefault("pallas", pallas)
        _backends.setdefault("numpy", _numpy_interpret)
        # only after everything registered: a failed import above leaves the
        # flag unset so the next call retries, and a concurrent caller never
        # observes the flag without the backends
        _defaults_registered = True


def _numpy_interpret(state, microcode, **kw):
    """Pure-numpy microcode interpreter (no jax anywhere).

    The only backend safe to run *inside* a ``jax.pure_callback`` — jax
    does not support re-entering jax (even jitted eager calls) from a host
    callback, so :func:`sim_linear` routes here.  Semantics match
    ``executor.execute`` bit for bit; gate codes follow ``GATE_CODES``.
    """
    st = np.array(state, dtype=np.uint32, copy=True)
    ones = np.uint32(0xFFFFFFFF)
    for code, ia, ib, out in np.asarray(microcode).tolist():
        a = st[:, ia, :]
        b = st[:, ib, :]
        if code == 0:                       # INIT
            res = np.full_like(a, ones)
        elif code == 1:                     # NOT
            res = ~a
        elif code == 2:                     # NOR
            res = ~(a | b)
        elif code == 3:                     # OR
            res = a | b
        elif code == 4:                     # NAND
            res = ~(a & b)
        else:                               # AND
            res = a & b
        st[:, out, :] = res
    return st


def get_backend(name: str) -> Backend:
    _ensure_default_backends()
    with _backends_lock:
        fn = _backends.get(name)
    if fn is None:
        raise ValueError(f"unknown backend {name!r}; "
                         f"registered: {sorted(_backends)}")
    return fn


def backends() -> Tuple[str, ...]:
    _ensure_default_backends()
    with _backends_lock:
        return tuple(sorted(_backends))


def execute_state(state, microcode, *, backend: str = "scan", **kw):
    """Run flat microcode over raw crossbar state on the chosen backend."""
    return get_backend(backend)(state, microcode, **kw)


# ==========================================================================
# execution front-end
# ==========================================================================

def execute(artifact: CompiledPim, x: np.ndarray, w: np.ndarray, *,
            backend: str = "scan", rows_per_crossbar: int = 256,
            **backend_kw) -> np.ndarray:
    """Integer GEMM through a compiled artifact: (M, K) x (O, K) -> (M, O).

    Each (m, o) output is one simulator row running ``artifact``'s dot
    program; the (m, o) grid is packed 32 rows/word and split across
    crossbars (the paper's rows x crossbars way-parallelism).  Exact for
    unsigned operands up to ``artifact.n_bits`` bits; returns uint64.
    """
    from repro.pim import executor as ex

    x = np.asarray(x)
    w = np.asarray(w)
    M, K = x.shape
    O, K2 = w.shape
    if K != K2:
        raise ValueError(f"inner dims disagree: x {x.shape} vs w {w.shape}")
    if K != artifact.n_terms:
        raise ValueError(
            f"artifact compiled for {artifact.n_terms} terms, got K={K}")

    total = M * O
    xs = np.repeat(x, O, axis=0)      # (M*O, K)
    ws = np.tile(w, (M, 1))           # (M*O, K)
    n_cb = (total + rows_per_crossbar - 1) // rows_per_crossbar
    pad = n_cb * rows_per_crossbar - total
    if pad:
        xs = np.pad(xs, ((0, pad), (0, 0)))
        ws = np.pad(ws, ((0, pad), (0, 0)))
    xs = xs.reshape(n_cb, rows_per_crossbar, K)
    ws = ws.reshape(n_cb, rows_per_crossbar, K)

    if backend == "numpy":
        # keep the whole round trip jax-free (callback-safe, see
        # _numpy_interpret)
        w_words = (rows_per_crossbar + 31) // 32
        state = np.zeros((n_cb, artifact.n_cols, w_words), np.uint32)

        def write(cols, values):
            values = np.asarray(values, np.uint64)
            for bit, c in enumerate(cols):
                state[:, c, :] = ex.pack_rows(
                    (values >> np.uint64(bit)) & np.uint64(1))

        for i in range(K):
            write(artifact.x_cols[i], xs[:, :, i])
            write(artifact.w_cols[i], ws[:, :, i])
    else:
        state = ex.blank_state(n_cb, artifact.n_cols, rows_per_crossbar)
        for i in range(K):
            state = ex.write_numbers(state, artifact.x_cols[i], xs[:, :, i])
            state = ex.write_numbers(state, artifact.w_cols[i], ws[:, :, i])
    state = execute_state(state, artifact.microcode, backend=backend,
                          **backend_kw)
    acc = ex.read_numbers(state, artifact.acc_cols, rows_per_crossbar)
    return acc.reshape(-1)[:total].reshape(M, O)


def matmul_int(x: np.ndarray, w: np.ndarray, n_bits: int = 8, *,
               model: str = "minimal", rows_per_crossbar: int = 256,
               backend: str = "scan", accumulate: str = "carry_save"
               ) -> np.ndarray:
    """Compile-and-execute convenience: bit-exact integer GEMM.

    The compile step is cached — calling twice with the same (K, n_bits,
    model) builds the gate program exactly once.  Inner dimensions longer
    than one row's column budget are split into chunked GEMMs (at most two
    distinct chunk sizes, both cached) whose uint64 partials are summed
    exactly on the host — so any K works, not just what fits one row.
    """
    from repro.pim.matmul import max_dot_terms

    K = x.shape[1]
    chunk = max_dot_terms(n_bits)
    if chunk <= 0:
        raise ValueError(f"n_bits={n_bits} does not fit the crossbar layout")

    def run(xs, ws):
        artifact = compile_matmul(xs.shape[1], n_bits, model=model,
                                  accumulate=accumulate)
        return execute(artifact, xs, ws, backend=backend,
                       rows_per_crossbar=rows_per_crossbar)

    if K <= chunk:
        return run(x, w)
    acc = None
    for lo in range(0, K, chunk):
        part = run(x[:, lo:lo + chunk], w[:, lo:lo + chunk])
        acc = part if acc is None else acc + part
    return acc


# ==========================================================================
# jit-composable simulator linear
# ==========================================================================

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _sim_mm(bits: int, model: str, backend: str, x, w):
    out_shape = x.shape[:-1] + (w.shape[-1],)
    out_dtype = jnp.result_type(x.dtype)
    qmax = 2 ** (bits - 1) - 1
    off = qmax + 1

    def host(xv, wv):
        xf = np.asarray(xv, np.float32)
        wf = np.asarray(wv, np.float32)
        lead = xf.shape[:-1]
        xf = xf.reshape(-1, xf.shape[-1])
        xs = np.maximum(np.abs(xf).max(axis=1, keepdims=True), 1e-8) / qmax
        wsc = np.maximum(np.abs(wf).max(axis=0, keepdims=True), 1e-8) / qmax
        xq = np.clip(np.round(xf / xs), -qmax, qmax).astype(np.int64)
        wq = np.clip(np.round(wf / wsc), -qmax, qmax).astype(np.int64)
        # crossbars store magnitudes; signs handled by 2's-complement
        # offset: shift into unsigned, multiply, correct ((a+off)(b+off))
        acc = matmul_int((xq + off).astype(np.uint64),
                         (wq.T + off).astype(np.uint64),
                         n_bits=bits + 1, model=model, backend=backend)
        acc = acc.astype(np.int64)
        corr = (off * (wq.sum(axis=0, keepdims=True) + off * xq.shape[1])
                + off * xq.sum(axis=1, keepdims=True))
        y = (acc - corr) * (xs * wsc)
        return y.reshape(*lead, wf.shape[1]).astype(out_dtype)

    result = jax.ShapeDtypeStruct(out_shape, out_dtype)
    return jax.pure_callback(host, result, x, w)


def _sim_mm_fwd(bits, model, backend, x, w):
    return _sim_mm(bits, model, backend, x, w), (x, w)


def _sim_mm_bwd(bits, model, backend, res, g):
    # straight-through estimator: the forward is the quantized crossbar
    # result, the backward differentiates the ideal float matmul (standard
    # QAT practice; pure_callback itself defines no JVP/VJP)
    x, w = res
    gx = jnp.einsum("...n,kn->...k", g, w.astype(g.dtype)).astype(x.dtype)
    gw = jnp.einsum("...k,...n->kn", x.astype(g.dtype), g).astype(w.dtype)
    return gx, gw


_sim_mm.defvjp(_sim_mm_fwd, _sim_mm_bwd)


def sim_linear(x, w, bits: int = 7, *, model: str = "minimal",
               backend: str = "numpy"):
    """Bit-exact crossbar execution of ``x @ w`` (tiny shapes only).

    7-bit symmetric quantization so the offset-shifted unsigned operands
    fit the 8-bit (power-of-two partition count) MultPIM multiplier.  The
    simulator runs on the host through ``jax.pure_callback`` with the exact
    result ``ShapeDtypeStruct``, so the call traces under ``jax.jit`` (and
    inside ``shard_map``) and the jitted result is bit-identical to eager —
    both paths execute the same host computation.  Differentiable via a
    straight-through ``custom_vjp`` (gradient of the ideal matmul), so a
    ``pim_sim`` model trains.  The host computation defaults to the pure-
    numpy backend: jax may not be re-entered from inside a host callback.
    """
    return _sim_mm(bits, model, backend, x, w)
