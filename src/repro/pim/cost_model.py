"""Analytical PIM cost model for LM workloads (Bitlet-style [18]).

Scales the *measured* per-row program costs (cycles, gates, control bits —
from the cycle-accurate simulator) to full LM-layer GEMMs, using the same
mapping as ``pim/matmul.py``: one output element per crossbar row, K
multiply-accumulate steps per row, all rows/crossbars in parallel.

This is how the paper's contribution meets the assigned architectures
(DESIGN.md §3): for any ``Linear`` in any of the 10 LM configs, the model
reports what executing it on a PartitionPIM memristive accelerator would
cost under each partition design, including the controller->crossbar
traffic that the paper's control designs reduce by 607/79/36 bits per cycle.

Device assumptions (documented, configurable):
* crossbar: 1024 x 1024, k=32 partitions (paper's evaluation point);
* cycle time 10 ns (memristor SET/RESET limited);
* switching energy 0.1 pJ/gate  (order-of-magnitude RRAM figure);
* TPU v5e comparison point: 197 TFLOP/s bf16, 819 GB/s HBM.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict

from repro.core.control import message_bits
from repro.core.operation import PartitionConfig

__all__ = ["PimDeviceParams", "GemmCost", "gemm_cost", "mult_cost"]

TPU_PEAK_FLOPS = 197e12
TPU_HBM_BW = 819e9


@dataclasses.dataclass(frozen=True)
class PimDeviceParams:
    n_cols: int = 1024
    n_rows: int = 1024
    k: int = 32
    cycle_ns: float = 10.0
    gate_energy_pj: float = 0.1
    crossbars: int = 65536  # one "PIM chip" = 64Gb of memristors


@functools.lru_cache(maxsize=None)
def mult_cost(n_bits: int, model: str, n_cols: int = 1024) -> Dict[str, int]:
    """Measured per-row multiplication cost from the built programs."""
    if model == "baseline":
        from repro.pim.mult_serial import build_serial_multiplier

        prog = build_serial_multiplier(n_bits, n_cols).program
    else:
        from repro.pim.multpim import build_multpim

        prog = build_multpim(n_bits, n_cols, model=model).program
    st = prog.stats()
    return dict(cycles=st.cycles, gates=st.energy_gates,
                area=st.area_columns,
                msg_bits=st.control_bits_per_message)


@functools.lru_cache(maxsize=None)
def _dot_extra_cost(n_bits: int, model: str) -> Dict[str, int]:
    """Per-term cost (copies + multiply + accumulate) of the dot mapping.

    Partition models: measured from ``build_dot`` (carry-save accumulate).
    Baseline: the serial multiplier plus a serial ripple accumulate and
    per-bit operand copies (a crossbar without partitions executes one gate
    per cycle; there is nothing to fuse)."""
    if model == "baseline":
        mc = mult_cost(n_bits, "baseline")
        n = n_bits
        ripple = (2 * n + 2) * 13      # FA chain incl. per-position inits
        copies = 4 * n + 2             # double-NOT per input bit + inits
        return dict(cycles=mc["cycles"] + ripple + copies,
                    gates=mc["gates"] + (2 * n + 2) * 10 + 4 * n)
    from repro.pim.matmul import build_dot

    def build(n):
        try:
            return build_dot(n, n_bits, model=model)
        except ValueError:  # wide operands need a wider crossbar (m = n/k)
            return build_dot(n, n_bits, n_cols=4096, model=model)

    one = build(1).program.stats()
    two = build(2).program.stats()
    return dict(cycles=two.cycles - one.cycles,
                gates=two.energy_gates - one.energy_gates)


@dataclasses.dataclass
class GemmCost:
    model: str
    n_bits: int
    m: int
    k_dim: int
    n: int
    crossbars: int          # concurrently busy crossbars
    waves: int              # sequential waves if the chip is smaller
    cycles_per_wave: int
    time_s: float
    energy_j: float
    control_bits: int       # controller->crossbar traffic for the whole GEMM
    tpu_time_s: float       # bf16 MXU reference point

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.k_dim * self.n


def gemm_cost(m: int, k_dim: int, n: int, n_bits: int = 8,
              model: str = "minimal",
              dev: PimDeviceParams = PimDeviceParams()) -> GemmCost:
    """Cost of ``(m x k_dim) @ (k_dim x n)`` on a PartitionPIM accelerator."""
    per_term = _dot_extra_cost(n_bits, model)
    rows_needed = m * n
    rows_per_cb = dev.n_rows
    cbs_needed = -(-rows_needed // rows_per_cb)
    waves = -(-cbs_needed // dev.crossbars)
    busy = min(cbs_needed, dev.crossbars)
    cycles = k_dim * per_term["cycles"]
    time_s = waves * cycles * dev.cycle_ns * 1e-9
    # energy: gates per row x rows actually computing
    gates = k_dim * per_term["gates"] * rows_needed
    energy_j = gates * dev.gate_energy_pj * 1e-12
    # control: one message per cycle per (independently-programmed) crossbar
    # column group — crossbars executing the same program share a broadcast
    # message, so traffic is cycles x message_bits per wave.
    bits = waves * cycles * mult_cost(n_bits, model)["msg_bits"]
    tpu_time = max(2.0 * m * k_dim * n / TPU_PEAK_FLOPS,
                   (m * k_dim + k_dim * n + m * n) * 2 / TPU_HBM_BW)
    return GemmCost(model=model, n_bits=n_bits, m=m, k_dim=k_dim, n=n,
                    crossbars=busy, waves=waves, cycles_per_wave=cycles,
                    time_s=time_s, energy_j=energy_j, control_bits=bits,
                    tpu_time_s=tpu_time)
