"""Analytical PIM cost model for LM workloads (Bitlet-style [18]).

Scales the *measured* per-row program costs (cycles, gates, control bits —
from the cycle-accurate simulator) to full LM-layer GEMMs, using the same
mapping as ``pim/matmul.py``: one output element per crossbar row, K
multiply-accumulate steps per row, all rows/crossbars in parallel.

This is how the paper's contribution meets the assigned architectures
(DESIGN.md §3): for any ``Linear`` in any of the 10 LM configs, the model
reports what executing it on a PartitionPIM memristive accelerator would
cost under each partition design, including the controller->crossbar
traffic that the paper's control designs reduce by 607/79/36 bits per cycle.

``pim.autotune`` uses this model as its planner: :func:`gemm_cost` accepts
a crossbar geometry (``n_cols``) and a chunking (``chunk``) so candidate
configurations — partition model x geometry x inner-dimension split — are
priced consistently, and :func:`mult_cost` prices any ``kind="mult"``
algorithm in the engine registry (the NOR serial baseline plus the
``serial_fast`` / ``compressor42`` backends), so new multiplier algorithms
join the race by registering, not by editing this file.

Device assumptions (documented, configurable):
* crossbar: 1024 x 1024, k=32 partitions (paper's evaluation point);
* cycle time 10 ns (memristor SET/RESET limited);
* switching energy 0.1 pJ/gate  (order-of-magnitude RRAM figure);
* TPU v5e comparison point: 197 TFLOP/s bf16, 819 GB/s HBM.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional


__all__ = ["PimDeviceParams", "GemmCost", "gemm_cost", "mult_cost"]

TPU_PEAK_FLOPS = 197e12
TPU_HBM_BW = 819e9


@dataclasses.dataclass(frozen=True)
class PimDeviceParams:
    n_cols: int = 1024
    n_rows: int = 1024
    k: int = 32
    cycle_ns: float = 10.0
    gate_energy_pj: float = 0.1
    crossbars: int = 65536  # one "PIM chip" = 64Gb of memristors


def _mult_backend(model: str) -> Optional[str]:
    """Registry name if ``model`` is a serial multiplier algorithm."""
    from repro.pim import engine

    name = "serial" if model == "baseline" else model
    try:
        kind = engine.backend_kind(name)
    except ValueError:
        return None
    return name if kind == "mult" else None


@functools.lru_cache(maxsize=None)
def mult_cost(n_bits: int, model: str, n_cols: int = 1024) -> Dict[str, int]:
    """Measured per-row multiplication cost from the built programs.

    ``model`` is a partition design (``unlimited``/``standard``/``minimal``)
    or a serial multiplier algorithm from the engine's ``kind="mult"``
    registry (``baseline`` aliases ``serial``).
    """
    mult = _mult_backend(model)
    if mult is not None:
        from repro.pim import engine

        prog = engine.build_multiplier(mult, n_bits, n_cols=n_cols).program
    else:
        from repro.pim.multpim import build_multpim

        prog = build_multpim(n_bits, n_cols, model=model).program
    st = prog.stats()
    return dict(cycles=st.cycles, gates=st.energy_gates,
                area=st.area_columns,
                msg_bits=st.control_bits_per_message)


@functools.lru_cache(maxsize=None)
def _dot_extra_cost(n_bits: int, model: str, n_cols: int = 1024
                    ) -> Dict[str, int]:
    """Per-term cost (copies + multiply + accumulate) of the dot mapping,
    plus the per-program fixed cost (setup + final carry resolution).

    Partition models: measured from ``build_dot`` (carry-save accumulate) —
    per-term is the 1->2-term cycle delta, fixed is what a 1-term program
    costs beyond one term.  Serial algorithms: the multiplier program plus
    a serial ripple accumulate and per-bit operand copies (a crossbar
    without partitions executes one gate per cycle; there is nothing to
    fuse); the ripple constant matches the algorithm's adder family
    (9-gate NOR vs 7-gate NAND/OR/AND)."""
    if _mult_backend(model) is not None:
        mc = mult_cost(n_bits, model, n_cols)
        n = n_bits
        per_pos = 10 if model in ("serial_fast", "compressor42") else 13
        ripple = (2 * n + 2) * per_pos  # FA chain incl. per-position inits
        copies = 4 * n + 2              # double-NOT per input bit + inits
        return dict(cycles=mc["cycles"] + ripple + copies,
                    gates=mc["gates"] + (2 * n + 2) * 10 + 4 * n,
                    fixed_cycles=0)
    from repro.pim.matmul import build_dot

    def build(n):
        try:
            return build_dot(n, n_bits, n_cols=n_cols, model=model)
        except ValueError:  # wide operands need a wider crossbar (m = n/k)
            return build_dot(n, n_bits, n_cols=max(n_cols, 4096), model=model)

    one = build(1).program.stats()
    two = build(2).program.stats()
    per = two.cycles - one.cycles
    return dict(cycles=per,
                gates=two.energy_gates - one.energy_gates,
                fixed_cycles=max(0, one.cycles - per))


@dataclasses.dataclass
class GemmCost:
    model: str
    n_bits: int
    m: int
    k_dim: int
    n: int
    crossbars: int          # concurrently busy crossbars
    waves: int              # sequential waves if the chip is smaller
    cycles_per_wave: int
    time_s: float
    energy_j: float
    control_bits: int       # controller->crossbar traffic for the whole GEMM
    tpu_time_s: float       # bf16 MXU reference point
    n_cols: int = 1024      # crossbar geometry priced
    chunks: int = 1         # inner-dimension splits (host-summed partials)

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.k_dim * self.n


def gemm_cost(m: int, k_dim: int, n: int, n_bits: int = 8,
              model: str = "minimal",
              dev: PimDeviceParams = PimDeviceParams(),
              n_cols: Optional[int] = None,
              chunk: Optional[int] = None) -> GemmCost:
    """Cost of ``(m x k_dim) @ (k_dim x n)`` on a PartitionPIM accelerator.

    ``n_cols`` overrides the device's crossbar width (a wider row fits more
    dot terms but pays more control bits per message); ``chunk`` prices the
    engine's inner-dimension split — each of the ``ceil(k_dim / chunk)``
    chunked programs pays the fixed setup + final carry-resolution cost.
    Left as ``None``, both collapse to the classic single-program pricing
    at the device geometry.
    """
    geom = dev.n_cols if n_cols is None else n_cols
    per_term = _dot_extra_cost(n_bits, model, geom)
    rows_needed = m * n
    rows_per_cb = dev.n_rows
    cbs_needed = -(-rows_needed // rows_per_cb)
    waves = -(-cbs_needed // dev.crossbars)
    busy = min(cbs_needed, dev.crossbars)
    cycles = k_dim * per_term["cycles"]
    n_chunks = 1
    if chunk is not None and 0 < chunk < k_dim:
        n_chunks = -(-k_dim // chunk)
    if chunk is not None:
        cycles += n_chunks * per_term["fixed_cycles"]
    time_s = waves * cycles * dev.cycle_ns * 1e-9
    # energy: gates per row x rows actually computing
    gates = k_dim * per_term["gates"] * rows_needed
    energy_j = gates * dev.gate_energy_pj * 1e-12
    # control: one message per cycle per (independently-programmed) crossbar
    # column group — crossbars executing the same program share a broadcast
    # message, so traffic is cycles x message_bits per wave.
    bits = waves * cycles * mult_cost(n_bits, model, geom)["msg_bits"]
    tpu_time = max(2.0 * m * k_dim * n / TPU_PEAK_FLOPS,
                   (m * k_dim + k_dim * n + m * n) * 2 / TPU_HBM_BW)
    return GemmCost(model=model, n_bits=n_bits, m=m, k_dim=k_dim, n=n,
                    crossbars=busy, waves=waves, cycles_per_wave=cycles,
                    time_s=time_s, energy_j=energy_j, control_bits=bits,
                    tpu_time_s=tpu_time, n_cols=geom, chunks=n_chunks)
