"""Optimized serial single-row multiplier (the paper's baseline, §5).

Schoolbook carry-save multiplication with NOT/NOR stateful logic, one gate
per cycle (a crossbar without partitions).  Optimizations (this is the
*optimized* serial baseline the paper compares against — the partition
speedup must be isolated from algorithmic slack):

* ``NOT a_j`` precomputed once (reused by every partial product);
* partial products written straight into the accumulator on iteration 0;
* double-buffered carry-save accumulator — no in-place updates, so no
  copy-backs; finalized low bits are tracked symbolically and never moved;
* degenerate adders (half-adder / bare XOR) wherever an operand is known
  zero at build time;
* contiguous workspace so each inner step re-initializes with ONE range
  init (the same init policy the partitioned versions use — DESIGN.md §2).

The 9-gate NOR full adder: u1=NOR(x,y), u2=NOR(x,u1), u3=NOR(y,u1),
u4=NOR(u2,u3)=XNOR(x,y), u5=NOR(u4,c), u6=NOR(u4,u5), u7=NOR(c,u5),
sum=NOR(u6,u7), cout=NOR(u1,u5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.operation import PartitionConfig
from repro.core.program import Program, ProgramBuilder

__all__ = ["SerialMultiplier", "build_serial_multiplier"]


@dataclasses.dataclass
class SerialMultiplier:
    program: Program
    n_bits: int
    a_cols: Tuple[int, ...]
    b_cols: Tuple[int, ...]
    result_cols: Tuple[int, ...]


def _full_adder(b: ProgramBuilder, x: int, y: int, c: int, u: List[int],
                sum_out: int, cout_out: Optional[int]):
    """9 NOR gates (8 if cout is dropped); u = 7 fresh (initialized) temps."""
    u1, u2, u3, u4, u5, u6, u7 = u
    b.gate("NOR", (x, y), u1)
    b.gate("NOR", (x, u1), u2)
    b.gate("NOR", (y, u1), u3)
    b.gate("NOR", (u2, u3), u4)  # XNOR(x, y)
    b.gate("NOR", (u4, c), u5)
    b.gate("NOR", (u4, u5), u6)
    b.gate("NOR", (c, u5), u7)
    b.gate("NOR", (u6, u7), sum_out)  # x ^ y ^ c
    if cout_out is not None:
        b.gate("NOR", (u1, u5), cout_out)  # majority(x, y, c)


def _half_adder(b: ProgramBuilder, x: int, y: int, v: List[int], sum_out: int,
                cout_out: Optional[int]):
    """6 NOR/NOT gates (5 without cout); v = 4 fresh temps."""
    v1, v2, v3, v4 = v
    b.gate("NOR", (x, y), v1)
    b.gate("NOR", (x, v1), v2)
    b.gate("NOR", (y, v1), v3)
    b.gate("NOR", (v2, v3), v4)  # XNOR
    b.gate("NOT", (v4,), sum_out)  # x ^ y
    if cout_out is not None:
        b.gate("NOR", (v1, sum_out), cout_out)  # x & y = NOR(NOR(x,y), XOR(x,y))


def build_serial_multiplier(n_bits: int = 32, n_cols: int = 1024,
                            k: int = 32) -> SerialMultiplier:
    """N-bit x N-bit -> 2N-bit product in a single row, one gate per cycle."""
    n = n_bits
    cfg = PartitionConfig(n_cols, k)
    b = ProgramBuilder(cfg, "baseline")

    # -- column layout -------------------------------------------------------
    A = list(range(0, n))
    B = list(range(n, 2 * n))
    NA = list(range(2 * n, 3 * n))
    NB = 3 * n
    # workspace: [PP, U1..U7] contiguous for one-range inits
    PP = 3 * n + 1
    U = list(range(3 * n + 2, 3 * n + 9))
    base = 3 * n + 9
    S = [list(range(base, base + 2 * n)),
         list(range(base + 2 * n, base + 4 * n))]
    C = [list(range(base + 4 * n, base + 6 * n + 1)),
         list(range(base + 6 * n + 1, base + 8 * n + 2))]
    assert C[1][-1] < n_cols, "layout exceeds crossbar width"

    # symbolic accumulator: position -> column (None = known zero)
    s_col: Dict[int, Optional[int]] = {}
    c_col: Dict[int, Optional[int]] = {}

    # -- NOT(a) once ---------------------------------------------------------
    b.init_range(NA[0], NA[-1], "init-na")
    for j in range(n):
        b.gate("NOT", (A[j],), NA[j], "na")

    # -- iteration 0: partial products straight into the accumulator --------
    w = 1  # write parity of iteration i is (i+1) % 2
    b.init_range(NB, NB, "init-nb")
    b.gate("NOT", (B[0],), NB, "nb")
    b.init_range(S[w][0], S[w][n - 1], "init-s0")
    for j in range(n):
        b.gate("NOR", (NA[j], NB), S[w][j], "pp0")  # a_j & b_0
        s_col[j] = S[w][j]

    # -- iterations 1..N-1 ---------------------------------------------------
    for i in range(1, n):
        w = (i + 1) % 2
        b.init_range(NB, NB)
        b.gate("NOT", (B[i],), NB, "nb")
        # fresh window of the write-parity buffers
        b.init_range(S[w][i], S[w][i + n - 1], "init-sw")
        b.init_range(C[w][i + 1], C[w][i + n], "init-cw")
        # carry-save semantics: every adder in this iteration reads the
        # PREVIOUS iteration's carries; new carries become visible next
        # iteration (they live in the other parity's columns anyway).
        new_s: Dict[int, Optional[int]] = {}
        new_c: Dict[int, Optional[int]] = {}
        for j in range(n):
            pos = i + j
            s = s_col.get(pos)
            c = c_col.get(pos)
            sum_out = S[w][pos]
            cout_out = C[w][pos + 1]
            if s is None and c is None:
                # bare partial product (top position, first time touched)
                b.gate("NOR", (NA[j], NB), sum_out, "pp-top")
                new_c[pos + 1] = None
            elif c is None or s is None:
                other = s if c is None else c
                b.init_range(PP, U[3])  # PP + 4 temps
                b.gate("NOR", (NA[j], NB), PP, "pp")
                _half_adder(b, other, PP, U[:4], sum_out, cout_out)
                new_c[pos + 1] = cout_out
            else:
                b.init_range(PP, U[-1])  # PP + 7 temps
                b.gate("NOR", (NA[j], NB), PP, "pp")
                _full_adder(b, s, PP, c, U, sum_out, cout_out)
                new_c[pos + 1] = cout_out
            new_s[pos] = sum_out
        s_col.update(new_s)
        c_col.update(new_c)

    # -- final carry-propagate over positions N..2N-1 ------------------------
    # Iteration N-1 wrote parity n % 2; its S/C entries are the live operands,
    # so the final outputs go to the OTHER parity (stale above position n).
    fin = (n + 1) % 2
    CARRY: Optional[int] = None  # ripple carry column (None = zero)
    for pos in range(n, 2 * n):
        s = s_col.get(pos)
        c = c_col.get(pos)
        sum_out = S[fin][pos]
        cout_out = C[fin][pos + 1] if pos + 1 < 2 * n else None
        terms = [t for t in (s, c, CARRY) if t is not None]
        b.init_range(S[fin][pos], S[fin][pos])
        if cout_out is not None:
            b.init_range(C[fin][pos + 1], C[fin][pos + 1])
        if len(terms) == 3:
            b.init_range(PP, U[-1])
            _full_adder(b, terms[0], terms[1], terms[2], U, sum_out, cout_out)
        elif len(terms) == 2:
            b.init_range(PP, U[3])
            _half_adder(b, terms[0], terms[1], U[:4], sum_out, cout_out)
        elif len(terms) == 1:
            b.init_range(PP, PP)
            b.gate("NOT", (terms[0],), PP)  # copy via double NOT
            b.gate("NOT", (PP,), sum_out)
            cout_out = None
        else:
            cout_out = None  # stays zero; sum bit is zero -> handled by read
        s_col[pos] = sum_out if terms else None
        CARRY = cout_out

    result = tuple(
        s_col[p] if s_col.get(p) is not None else NB  # NB never ends as result
        for p in range(2 * n)
    )
    # positions with no column are structurally zero; map them to a column we
    # force to zero at the end (cheap: one init + one NOT of an init'd col).
    if any(s_col.get(p) is None for p in range(2 * n)):
        zero = PP
        b.init_range(U[0], U[0])
        b.init_range(zero, zero)
        b.gate("NOT", (U[0],), zero)  # NOT(1) = 0
        result = tuple(
            s_col[p] if s_col.get(p) is not None else zero for p in range(2 * n)
        )

    prog = b.program
    prog.name = f"serial-mult-{n}b"
    return SerialMultiplier(
        program=prog,
        n_bits=n,
        a_cols=tuple(A),
        b_cols=tuple(B),
        result_cols=result,
    )
