"""Partitioned single-row multiplication (MultPIM [14], rebuilt on the
unlimited / standard / minimal models of PartitionPIM).

``k = N`` partitions multiply two N-bit numbers per row with carry-save
accumulation sliced across partitions.  Invariant at the start of iteration
``i``: partition ``j`` holds the accumulator sum/carry of weight ``i + j``.
Each iteration:

1. **broadcast** ``NOT b_i`` from partition ``i`` to all partitions in
   ``log2(k)`` grid-doubling stages (MultPIM's logarithmic broadcast), each
   stage a *periodic* semi-parallel operation (distance ``d``, period
   ``2d``) — legal in every model including minimal;
2. **partial product** ``pp_j = a_j AND b_i`` as one parallel operation;
3. **full adder** across all partitions concurrently (7 parallel ops for the
   NOR-FA internals);
4. **fused shift**: the FA sum of partition ``j`` is written directly into
   partition ``j-1`` (two semi-parallel distance-1 operations, even/odd —
   MultPIM's constant-time shift), the top partition is refilled with a
   constant 0, and partition 0's sum is emitted as result bit ``r_i``.

After N iterations a ripple carry-propagate resolves the high half.  Model
differences are expressed through ``is_legal``-guarded fusions: operations
that mix intra-partition indices (e.g. folding the top-partition zero-fill
into the shift operation) are only fused under *unlimited*; the fallback
decomposition costs extra cycles under standard/minimal — the mechanism of
the paper's §5 evaluation.  Our schedule is deliberately periodic
(co-designed for the minimal model), so the measured unlimited/standard/
minimal spread is *smaller* than the paper's retrofit of the original
MultPIM — see EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.operation import GateOp, Operation, PartitionConfig
from repro.core.program import Program, ProgramBuilder

__all__ = ["PartitionedMultiplier", "build_multpim", "Layout"]


@dataclasses.dataclass(frozen=True)
class Layout:
    """Intra-partition column map (identical in every partition)."""

    IA: int = 0      # a_j
    IB: int = 1      # b_j
    INA: int = 2     # NOT a_j
    NZ: int = 3      # constant 1 (freshly initialized, never gated)
    S0: int = 4      # accumulator sum, parity 0
    C0: int = 5      # accumulator carry, parity 0
    BB: int = 6      # broadcast slot (holds NOT b_i)
    TB: int = 7      # broadcast stage temps TB .. TB+n_stages-1
    # PP/U/S1/C1 computed from n_stages so the per-iteration init window is
    # a single contiguous range for either write parity (see _init_window).
    R_OFF: int = 0   # set in __post_init__ equivalents below

    @staticmethod
    def make(k: int):
        n_stages = max(1, (k - 1).bit_length())
        tb = 7
        pp = tb + n_stages
        u = pp + 1
        s1 = u + 7
        c1 = s1 + 1
        r = c1 + 1
        r2 = r + 1
        cc = r2 + 1
        ct = cc + 1
        nz2 = ct + 1
        return dict(n_stages=n_stages, TB=tb, PP=pp, U=u, S1=s1, C1=c1,
                    R=r, R2=r2, CC=cc, CT=ct, NZ2=nz2, width=nz2 + 1)


@dataclasses.dataclass
class PartitionedMultiplier:
    program: Program
    n_bits: int
    a_cols: Tuple[int, ...]       # bit j at (partition j, IA)
    b_cols: Tuple[int, ...]
    result_cols: Tuple[int, ...]  # 2N columns, LSB first
    layout: dict


def build_multpim(n_bits: int = 32, n_cols: int = 1024,
                  model: str = "minimal") -> PartitionedMultiplier:
    """Build the partitioned multiplier program for one of the three models."""
    N = n_bits
    k = N
    if k & (k - 1):
        raise ValueError("bit width (= partition count) must be a power of two")
    cfg = PartitionConfig(n_cols, k)
    L = Layout.make(k)
    m = cfg.m
    if L["width"] > m:
        raise ValueError(f"layout needs {L['width']} intra columns, have {m}")

    IA, IB, INA, NZ = Layout.IA, Layout.IB, Layout.INA, Layout.NZ
    S = [Layout.S0, L["S1"]]
    C = [Layout.C0, L["C1"]]
    BB, TB, PP, U = Layout.BB, L["TB"], L["PP"], L["U"]
    R, R2, CC, CT, NZ2 = L["R"], L["R2"], L["CC"], L["CT"], L["NZ2"]
    n_stages = L["n_stages"]

    b = ProgramBuilder(cfg, model)
    col = cfg.col

    def par_gate(gate, ins_intra, out_intra, label=""):
        """One gate in every partition at identical intra indices."""
        gates = tuple(
            GateOp(gate, tuple(col(p, i) for i in ins_intra), col(p, out_intra))
            for p in range(k)
        )
        b.emit(Operation(gates=gates, label=label))

    # ---------------- setup ----------------
    b.init_periodic(INA, NZ, label="setup-init")          # INA, NZ
    b.init_periodic(R, NZ2, label="setup-init-res")        # R,R2,CC,CT,NZ2
    par_gate("NOT", (IA,), INA, "na")

    # ---------------- broadcast ----------------
    def broadcast(i: int):
        """Spread NOT(b_i) from partition i to all partitions' BB column."""
        b.emit(Operation(gates=(GateOp("NOT", (col(i, IB),), col(i, BB)),),
                         label="nb"))
        for t in range(1, n_stages + 1):
            d = k >> t
            step = 2 * d
            start = i % step
            # T: stage complement staging at every 'have' partition
            b.emit(Operation(init=None, gates=tuple(
                GateOp("NOT", (col(p, BB),), col(p, TB + t - 1))
                for p in range(start, k, step)
            ), label=f"bcast-T{t}"))
            right = [p for p in range(start, k, step) if p + d < k]
            left = [p for p in range(start, k, step) if p - d >= 0]
            if right:
                b.emit(Operation(gates=tuple(
                    GateOp("NOT", (col(p, TB + t - 1),), col(p + d, BB))
                    for p in right), label=f"bcast-R{t}"))
            if left:
                b.emit(Operation(gates=tuple(
                    GateOp("NOT", (col(p, TB + t - 1),), col(p - d, BB))
                    for p in left), label=f"bcast-L{t}"))

    def init_window(w: int, label: str):
        """One contiguous periodic init covering BB, TBs, PP, U and the
        write-parity S/C — the read parity is outside the range either way."""
        if w == 1:
            b.init_periodic(BB, C[1], label=label)      # [BB .. C1]
        else:
            b.init_periodic(S[0], U + 6, label=label)   # [S0 .. U7]

    def shift_writes(w: int, sum_src: Tuple[int, int]):
        """Sum of partition j -> S_w of partition j-1 (even/odd), top zero-fill.

        Under unlimited the top-partition zero-fill — NOR of two constant-one
        columns (= 0) at different intra indices — fuses into the even op
        (Identical Indices forbids it under standard/minimal: paper fn. 4).
        """
        sa, sb = sum_src
        odd = tuple(GateOp("NOR", (col(j, sa), col(j, sb)), col(j - 1, S[w]))
                    for j in range(1, k, 2))
        even = tuple(GateOp("NOR", (col(j, sa), col(j, sb)), col(j - 1, S[w]))
                     for j in range(2, k, 2))
        top = GateOp("NOR", (col(k - 1, NZ), col(k - 1, NZ2)), col(k - 1, S[w]))
        b.emit(Operation(gates=odd, label="shift-odd"))
        b.fuse_or(
            Operation(gates=even + (top,), label="shift-even+top"),
            [Operation(gates=even, label="shift-even"),
             Operation(gates=(top,), label="top-zero")],
        )

    # NZ2 constant: both NZ and NZ2 are init'd (=1) and never gated.
    # ---------------- iteration 0 ----------------
    init_window(1, "iter0-init")
    broadcast(0)
    # partial products, pre-shifted: S1[j] = pp_{j+1}
    odd0 = tuple(GateOp("NOR", (col(j, INA), col(j, BB)), col(j - 1, S[1]))
                 for j in range(1, k, 2))
    even0 = tuple(GateOp("NOR", (col(j, INA), col(j, BB)), col(j - 1, S[1]))
                  for j in range(2, k, 2))
    top0 = GateOp("NOR", (col(k - 1, NZ), col(k - 1, NZ2)), col(k - 1, S[1]))
    b.emit(Operation(gates=odd0, label="pp0-odd"))
    b.fuse_or(
        Operation(gates=even0 + (top0,), label="pp0-even+top"),
        [Operation(gates=even0, label="pp0-even"),
         Operation(gates=(top0,), label="top-zero")],
    )
    par_gate("NOT", (NZ,), C[1], "c0-zero")  # all carries start at 0
    b.emit(Operation(gates=(GateOp("NOR", (col(0, INA), col(0, BB)), col(0, R)),),
                     label="emit-r0"))

    # ---------------- iterations 1 .. N-1 ----------------
    for i in range(1, N):
        w = (i + 1) % 2
        r = i % 2
        init_window(w, f"iter{i}-init")
        broadcast(i)
        par_gate("NOR", (INA, BB), PP, "pp")
        # NOR full adder: x=S_r, y=PP, cin=C_r
        par_gate("NOR", (S[r], PP), U + 0, "u1")
        par_gate("NOR", (S[r], U + 0), U + 1, "u2")
        par_gate("NOR", (PP, U + 0), U + 2, "u3")
        par_gate("NOR", (U + 1, U + 2), U + 3, "u4")   # XNOR(x,y)
        par_gate("NOR", (U + 3, C[r]), U + 4, "u5")
        par_gate("NOR", (U + 3, U + 4), U + 5, "u6")
        par_gate("NOR", (C[r], U + 4), U + 6, "u7")
        shift_writes(w, sum_src=(U + 5, U + 6))
        par_gate("NOR", (U + 0, U + 4), C[w], "cout")
        b.emit(Operation(gates=(GateOp(
            "NOR", (col(0, U + 5), col(0, U + 6)), col(i, R)),), label="emit"))

    # ---------------- final ripple carry-propagate -----------------------
    fin = N % 2  # parity written by iteration N-1
    carry_known_zero = True
    for j in range(k):
        b.init_periodic(PP, U + 6, p_start=j, p_end=j, label="fin-init")
        x, y = col(j, S[fin]), col(j, C[fin])
        cin = col(j, CT)
        sum_out, cout_out = col(j, R2), col(j, CC)
        u = [col(j, U + t) for t in range(7)]
        if carry_known_zero:
            # half adder
            b.emit(Operation(gates=(GateOp("NOR", (x, y), u[0]),)))
            b.emit(Operation(gates=(GateOp("NOR", (x, u[0]), u[1]),)))
            b.emit(Operation(gates=(GateOp("NOR", (y, u[0]), u[2]),)))
            b.emit(Operation(gates=(GateOp("NOR", (u[1], u[2]), u[3]),)))
            b.emit(Operation(gates=(GateOp("NOT", (u[3],), sum_out),)))
            if j < k - 1:
                # x & y = NOR(NOR(x,y), XOR(x,y))
                b.emit(Operation(gates=(GateOp("NOR", (u[0], sum_out), cout_out),)))
            carry_known_zero = False
        else:
            b.emit(Operation(gates=(GateOp("NOR", (x, y), u[0]),)))
            b.emit(Operation(gates=(GateOp("NOR", (x, u[0]), u[1]),)))
            b.emit(Operation(gates=(GateOp("NOR", (y, u[0]), u[2]),)))
            b.emit(Operation(gates=(GateOp("NOR", (u[1], u[2]), u[3]),)))
            b.emit(Operation(gates=(GateOp("NOR", (u[3], cin), u[4]),)))
            b.emit(Operation(gates=(GateOp("NOR", (u[3], u[4]), u[5]),)))
            b.emit(Operation(gates=(GateOp("NOR", (cin, u[4]), u[6]),)))
            b.emit(Operation(gates=(GateOp("NOR", (u[5], u[6]), sum_out),)))
            if j < k - 1:
                b.emit(Operation(gates=(GateOp("NOR", (u[0], u[4]), cout_out),)))
        if j < k - 1:
            # ripple the carry into the next partition (double NOT via PP)
            b.emit(Operation(gates=(GateOp("NOT", (cout_out,), col(j, PP)),)))
            b.emit(Operation(gates=(GateOp("NOT", (col(j, PP),), col(j + 1, CT)),)))

    prog = b.program
    prog.name = f"multpim-{model}-{N}b"
    result = tuple(col(i, R) for i in range(N)) + tuple(col(j, R2) for j in range(k))
    return PartitionedMultiplier(
        program=prog,
        n_bits=N,
        a_cols=tuple(col(j, IA) for j in range(N)),
        b_cols=tuple(col(j, IB) for j in range(N)),
        result_cols=result,
        layout=L,
    )
