"""Fast serial single-row multiplier using the extended FELIX gate set.

Same schoolbook carry-save schedule as ``mult_serial`` (the paper's
optimized NOT/NOR baseline), but built on the richer stateful gate set
(AND/NAND/OR) that memristive serial-multiplier follow-up work exploits
(arXiv 2410.09953): a partial product is a single ``AND(a_j, b_i)`` — no
precomputed operand complements at all — and the full adder drops from
9 NOR gates to 7 mixed gates:

    t1 = NAND(x, y)          t4 = NAND(t3, c)
    t2 = OR(x, y)            t5 = OR(t3, c)
    t3 = AND(t1, t2) = x^y   sum  = AND(t4, t5) = x^y^c
                             cout = NAND(t1, t4) = xy + c(x^y)

and the half adder to 4 gates (NAND/OR/AND for the XOR, one AND for the
carry).  Everything else — double-buffered carry-save accumulator,
symbolic known-zero tracking, one-range-init workspace — matches the
reference serial multiplier, so cycle savings are purely the gate-count
win (~25-30% at 32 bits).  Bit-exact N x N -> 2N.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.operation import PartitionConfig
from repro.core.program import ProgramBuilder
from repro.pim.mult_serial import SerialMultiplier

__all__ = ["build_fast_serial_multiplier", "fast_full_adder",
           "fast_half_adder"]


def fast_full_adder(b: ProgramBuilder, x: int, y: int, c: int, t: List[int],
                    sum_out: int, cout_out: Optional[int]):
    """7 mixed gates (6 if cout is dropped); t = 5 fresh (initialized) temps."""
    t1, t2, t3, t4, t5 = t
    b.gate("NAND", (x, y), t1)
    b.gate("OR", (x, y), t2)
    b.gate("AND", (t1, t2), t3)  # x ^ y
    b.gate("NAND", (t3, c), t4)
    b.gate("OR", (t3, c), t5)
    b.gate("AND", (t4, t5), sum_out)  # x ^ y ^ c
    if cout_out is not None:
        b.gate("NAND", (t1, t4), cout_out)  # majority(x, y, c)


def fast_half_adder(b: ProgramBuilder, x: int, y: int, t: List[int],
                    sum_out: int, cout_out: Optional[int]):
    """4 mixed gates (3 without cout); t = 2 fresh temps."""
    t1, t2 = t
    b.gate("NAND", (x, y), t1)
    b.gate("OR", (x, y), t2)
    b.gate("AND", (t1, t2), sum_out)  # x ^ y
    if cout_out is not None:
        b.gate("AND", (x, y), cout_out)


def build_fast_serial_multiplier(n_bits: int = 32, n_cols: int = 1024,
                                 k: int = 32) -> SerialMultiplier:
    """N-bit x N-bit -> 2N-bit product in a single row, one gate per cycle."""
    n = n_bits
    cfg = PartitionConfig(n_cols, k)
    b = ProgramBuilder(cfg, "baseline")

    # -- column layout -------------------------------------------------------
    A = list(range(0, n))
    B = list(range(n, 2 * n))
    # workspace: [PP, T1..T5] contiguous for one-range inits
    PP = 2 * n
    T = list(range(2 * n + 1, 2 * n + 6))
    base = 2 * n + 6
    S = [list(range(base, base + 2 * n)),
         list(range(base + 2 * n, base + 4 * n))]
    C = [list(range(base + 4 * n, base + 6 * n + 1)),
         list(range(base + 6 * n + 1, base + 8 * n + 2))]
    assert C[1][-1] < n_cols, "layout exceeds crossbar width"

    # symbolic accumulator: position -> column (None = known zero)
    s_col: Dict[int, Optional[int]] = {}
    c_col: Dict[int, Optional[int]] = {}

    # -- iteration 0: partial products straight into the accumulator --------
    w = 1  # write parity of iteration i is (i+1) % 2
    b.init_range(S[w][0], S[w][n - 1], "init-s0")
    for j in range(n):
        b.gate("AND", (A[j], B[0]), S[w][j], "pp0")  # a_j & b_0
        s_col[j] = S[w][j]

    # -- iterations 1..N-1 ---------------------------------------------------
    for i in range(1, n):
        w = (i + 1) % 2
        # fresh window of the write-parity buffers
        b.init_range(S[w][i], S[w][i + n - 1], "init-sw")
        b.init_range(C[w][i + 1], C[w][i + n], "init-cw")
        # carry-save semantics: adders read the PREVIOUS iteration's carries;
        # new carries become visible next iteration (other parity's columns).
        new_s: Dict[int, Optional[int]] = {}
        new_c: Dict[int, Optional[int]] = {}
        for j in range(n):
            pos = i + j
            s = s_col.get(pos)
            c = c_col.get(pos)
            sum_out = S[w][pos]
            cout_out = C[w][pos + 1]
            if s is None and c is None:
                # bare partial product (top position, first time touched)
                b.gate("AND", (A[j], B[i]), sum_out, "pp-top")
                new_c[pos + 1] = None
            elif c is None or s is None:
                other = s if c is None else c
                b.init_range(PP, T[1])  # PP + 2 temps
                b.gate("AND", (A[j], B[i]), PP, "pp")
                fast_half_adder(b, other, PP, T[:2], sum_out, cout_out)
                new_c[pos + 1] = cout_out
            else:
                b.init_range(PP, T[-1])  # PP + 5 temps
                b.gate("AND", (A[j], B[i]), PP, "pp")
                fast_full_adder(b, s, PP, c, T, sum_out, cout_out)
                new_c[pos + 1] = cout_out
            new_s[pos] = sum_out
        s_col.update(new_s)
        c_col.update(new_c)

    # -- final carry-propagate over positions N..2N-1 ------------------------
    # Iteration N-1 wrote parity n % 2; the final outputs go to the OTHER
    # parity (stale above position n).
    fin = (n + 1) % 2
    CARRY: Optional[int] = None  # ripple carry column (None = zero)
    for pos in range(n, 2 * n):
        s = s_col.get(pos)
        c = c_col.get(pos)
        sum_out = S[fin][pos]
        cout_out = C[fin][pos + 1] if pos + 1 < 2 * n else None
        terms = [t for t in (s, c, CARRY) if t is not None]
        b.init_range(S[fin][pos], S[fin][pos])
        if cout_out is not None:
            b.init_range(C[fin][pos + 1], C[fin][pos + 1])
        if len(terms) == 3:
            b.init_range(PP, T[-1])
            fast_full_adder(b, terms[0], terms[1], terms[2], T, sum_out,
                            cout_out)
        elif len(terms) == 2:
            b.init_range(PP, T[1])
            fast_half_adder(b, terms[0], terms[1], T[:2], sum_out, cout_out)
        elif len(terms) == 1:
            b.gate("AND", (terms[0], terms[0]), sum_out)  # 1-gate copy
            cout_out = None
        else:
            cout_out = None  # stays zero; sum bit is zero -> handled by read
        s_col[pos] = sum_out if terms else None
        CARRY = cout_out

    result = tuple(
        s_col[p] if s_col.get(p) is not None else PP  # placeholder
        for p in range(2 * n)
    )
    # positions with no column are structurally zero; map them to a column we
    # force to zero at the end (one init + one NOT of an init'd col).
    if any(s_col.get(p) is None for p in range(2 * n)):
        zero = PP
        b.init_range(T[0], T[0])
        b.init_range(zero, zero)
        b.gate("NOT", (T[0],), zero)  # NOT(1) = 0
        result = tuple(
            s_col[p] if s_col.get(p) is not None else zero for p in range(2 * n)
        )

    prog = b.program
    prog.name = f"fast-serial-mult-{n}b"
    return SerialMultiplier(
        program=prog,
        n_bits=n,
        a_cols=tuple(A),
        b_cols=tuple(B),
        result_cols=result,
    )
