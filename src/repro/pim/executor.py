"""Vectorized crossbar simulator: execute microcode over bit-packed state.

TPU-native adaptation of stateful logic (DESIGN.md §2): a stateful-logic gate
acts on *whole columns*, identically across rows, so we bit-pack 32 rows into
one ``uint32`` word.  Crossbar state is ``(C, n, W)``: ``C`` independent
crossbars, ``n`` columns (bitlines), ``W = ceil(rows/32)`` row-words.  A gate
is then a bitwise op on ``(C, W)`` slices — ideal for TPU VPU lanes (and CPU
SIMD in this container).

Two backends:

* :func:`execute` — pure-jnp ``lax.scan`` over the microcode (also the
  oracle for the Pallas kernel, re-exported as ``kernels.crossbar_exec.ref``);
* ``kernels/crossbar_exec`` — the Pallas TPU kernel (VMEM-tiled), validated
  against this oracle in interpret mode.

Both (plus :func:`execute_unrolled`) are registered in the
``repro.pim.engine`` backend registry as ``"scan"``, ``"pallas"`` and
``"unrolled"`` — select through ``engine.execute_state(...)`` rather than
importing executors directly.

The microcode ABI is produced by :meth:`repro.core.program.Program.to_microcode`:
int32 rows ``(gate_code, in_a, in_b, out)``; gate codes from
``repro.core.gates.GATE_CODES`` (INIT=0 sets the output column to all-ones).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gates import ALL_ONES

__all__ = [
    "blank_state",
    "pack_rows",
    "unpack_rows",
    "write_bits",
    "read_bits",
    "write_numbers",
    "read_numbers",
    "execute",
    "execute_unrolled",
]


def blank_state(crossbars: int, n: int, rows: int) -> jnp.ndarray:
    """All-zero crossbar state ``(C, n, W)`` (memristors in RESET)."""
    w = (rows + 31) // 32
    return jnp.zeros((crossbars, n, w), jnp.uint32)


def pack_rows(bits: np.ndarray) -> np.ndarray:
    """Pack boolean ``(..., rows)`` into uint32 words ``(..., W)`` (LSB=row 0)."""
    bits = np.asarray(bits, np.uint8)
    rows = bits.shape[-1]
    pad = (-rows) % 32
    if pad:
        bits = np.concatenate(
            [bits, np.zeros(bits.shape[:-1] + (pad,), np.uint8)], axis=-1
        )
    b = bits.reshape(bits.shape[:-1] + (-1, 32)).astype(np.uint32)
    shifts = np.arange(32, dtype=np.uint32)
    return (b << shifts).sum(axis=-1, dtype=np.uint32)


def unpack_rows(words: np.ndarray, rows: int) -> np.ndarray:
    """Inverse of :func:`pack_rows` -> boolean ``(..., rows)``."""
    words = np.asarray(words, np.uint32)
    shifts = np.arange(32, dtype=np.uint32)
    bits = (words[..., None] >> shifts) & 1
    bits = bits.reshape(words.shape[:-1] + (-1,))
    return bits[..., :rows].astype(bool)


def write_bits(state: jnp.ndarray, col: int, bits: np.ndarray) -> jnp.ndarray:
    """Write per-row bits (C, rows) into one column."""
    return state.at[:, col, :].set(jnp.asarray(pack_rows(bits)))


def read_bits(state: jnp.ndarray, col: int, rows: int) -> np.ndarray:
    return unpack_rows(np.asarray(state[:, col, :]), rows)


def write_numbers(
    state: jnp.ndarray, cols: Tuple[int, ...], values: np.ndarray
) -> jnp.ndarray:
    """Write integers ``values`` (C, rows) bit-sliced onto ``cols`` (LSB first)."""
    values = np.asarray(values, np.uint64)
    for bit, col in enumerate(cols):
        state = write_bits(state, col, (values >> np.uint64(bit)) & np.uint64(1))
    return state


def read_numbers(state: jnp.ndarray, cols: Tuple[int, ...], rows: int) -> np.ndarray:
    """Read integers from bit-sliced columns (LSB first) -> (C, rows) uint64."""
    out = np.zeros(state.shape[:1] + (rows,), np.uint64)
    for bit, col in enumerate(cols):
        out |= read_bits(state, col, rows).astype(np.uint64) << np.uint64(bit)
    return out


def _apply_gate(code, a, b):
    """Gate semantics on packed words; order must match GATE_CODES."""
    return jax.lax.switch(
        code,
        [
            lambda a, b: jnp.full_like(a, ALL_ONES),          # INIT
            lambda a, b: jnp.bitwise_not(a),                  # NOT
            lambda a, b: jnp.bitwise_not(jnp.bitwise_or(a, b)),   # NOR
            lambda a, b: jnp.bitwise_or(a, b),                # OR
            lambda a, b: jnp.bitwise_not(jnp.bitwise_and(a, b)),  # NAND
            lambda a, b: jnp.bitwise_and(a, b),               # AND
        ],
        a,
        b,
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def execute(state: jnp.ndarray, microcode: jnp.ndarray) -> jnp.ndarray:
    """Run flat microcode ``(G, 4)`` int32 over state ``(C, n, W)``.

    ``lax.scan`` keeps the HLO size O(1) in program length; each step is a
    3-column dynamic gather + 1-column dynamic update — the whole scan stays
    resident, so HBM traffic on real hardware is one read/write of the state.
    """

    def step(words, mc):
        code, ia, ib, out = mc[0], mc[1], mc[2], mc[3]
        a = jnp.take(words, ia, axis=1)  # (C, W)
        b = jnp.take(words, ib, axis=1)
        res = _apply_gate(code, a, b)
        words = jax.lax.dynamic_update_slice_in_dim(
            words, res[:, None, :], out, axis=1
        )
        return words, None

    state, _ = jax.lax.scan(step, state, microcode)
    return state


def execute_unrolled(state: jnp.ndarray, microcode: np.ndarray) -> jnp.ndarray:
    """Python-unrolled variant (static indices; no scan).

    Faster per-step on small programs — XLA sees static column indices and
    fuses runs of bitwise ops — but compile time grows with program length.
    Used by the throughput benchmark to compare against :func:`execute`.
    """
    microcode = np.asarray(microcode)

    @jax.jit
    def run(words):
        for code, ia, ib, out in microcode.tolist():
            a = words[:, ia, :]
            b = words[:, ib, :]
            if code == 0:
                res = jnp.full_like(a, ALL_ONES)
            elif code == 1:
                res = jnp.bitwise_not(a)
            elif code == 2:
                res = jnp.bitwise_not(jnp.bitwise_or(a, b))
            elif code == 3:
                res = jnp.bitwise_or(a, b)
            elif code == 4:
                res = jnp.bitwise_not(jnp.bitwise_and(a, b))
            else:
                res = jnp.bitwise_and(a, b)
            words = words.at[:, out, :].set(res)
        return words

    return run(state)
