"""Sharded, atomic, resumable checkpoints (no external deps).

Layout:  <dir>/step_<N>/
             manifest.json      step, config, data position, tree structure
             shard_<i>.npz      flattened leaves (path-keyed)

* **atomic publish** — written to ``step_<N>.tmp`` then renamed, so a crash
  mid-save never corrupts the latest checkpoint;
* **sharded** — leaves are split across ``shard_count`` npz files by a stable
  hash of the path; on a real cluster each host writes/reads its own shards
  (here shard_count defaults to 1);
* **self-describing** — restore rebuilds the tree from the manifest, and
  verifies leaf shapes/dtypes against the target spec tree if given.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "available_steps"]

_SEP = "/"


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, tree, *,
                    metadata: Optional[Dict] = None,
                    shard_count: int = 1) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    shards: List[Dict[str, np.ndarray]] = [dict() for _ in range(shard_count)]
    index = {}
    for key, leaf in flat:
        sh = zlib.crc32(key.encode()) % shard_count
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":  # npz-portable encoding
            arr = arr.view(np.uint16)
            key_dtype = "bfloat16"
        else:
            key_dtype = arr.dtype.name
        shards[sh][key] = arr
        index[key] = [sh, key_dtype]
    for i, sh in enumerate(shards):
        np.savez(os.path.join(tmp, f"shard_{i}.npz"), **sh)
    manifest = {
        "step": step,
        "shard_count": shard_count,
        "index": index,
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def available_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = available_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, step: int, like) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (a tree of arrays or specs)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    loaded: Dict[str, np.ndarray] = {}
    for i in range(manifest["shard_count"]):
        with np.load(os.path.join(path, f"shard_{i}.npz")) as z:
            for k in z.files:
                loaded[k] = z[k]
    index = manifest["index"]
    flat_like = _flatten(like)
    leaves = []
    for key, leaf in flat_like:
        if key not in loaded:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = loaded[key]
        entry = index.get(key)
        stored_dtype = entry[1] if isinstance(entry, list) else arr.dtype.name
        if stored_dtype == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: shape {arr.shape} != {want_shape}")
        leaves.append(np.asarray(arr).astype(leaf.dtype, copy=False)
                      if stored_dtype != "bfloat16"
                      else jax.numpy.asarray(arr).astype(leaf.dtype))
    tdef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(tdef, leaves), manifest["metadata"]
