"""Stateful-logic gate definitions for digital memristive PIM.

Binary values are stored as memristor resistance states; stateful logic
(MAGIC [Kvatinsky'14], FELIX [Gupta'18]) executes a gate across *all rows*
of a crossbar in one cycle by applying voltages on bitlines.

The simulator bit-packs 32 rows into one ``uint32`` word, so a gate is a
bitwise function on words.  The paper's evaluation (and ours) assumes the
NOT/NOR gate set of MAGIC; the FELIX extensions (OR, NAND, Minority3) are
defined here as well and are legal in every partition model (the control
message carries the gate type out-of-band, see ``core/control.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax.numpy as jnp

__all__ = ["GateDef", "GATE_DEFS", "GATE_CODES", "gate_by_code", "ALL_ONES"]

ALL_ONES = jnp.uint32(0xFFFFFFFF)


@dataclasses.dataclass(frozen=True)
class GateDef:
    """A stateful-logic gate executable in a single crossbar cycle."""

    name: str
    n_inputs: int
    code: int  # microcode id used by the executors (jnp + pallas)
    fn: Callable[..., jnp.ndarray]

    def __call__(self, *words):
        assert len(words) == self.n_inputs, (self.name, len(words))
        return self.fn(*words)


def _init() -> jnp.ndarray:
    # MAGIC initialization: output memristors are SET to logic '1'.
    return ALL_ONES


def _not(a):
    return jnp.bitwise_not(a)


def _nor(a, b):
    return jnp.bitwise_not(jnp.bitwise_or(a, b))


def _or(a, b):
    return jnp.bitwise_or(a, b)


def _nand(a, b):
    return jnp.bitwise_not(jnp.bitwise_and(a, b))


def _and(a, b):
    return jnp.bitwise_and(a, b)


# Codes are stable ABI for the microcode executors; INIT must be 0.
GATE_DEFS: Dict[str, GateDef] = {
    "INIT": GateDef("INIT", 0, 0, _init),
    "NOT": GateDef("NOT", 1, 1, _not),
    "NOR": GateDef("NOR", 2, 2, _nor),
    "OR": GateDef("OR", 2, 3, _or),
    "NAND": GateDef("NAND", 2, 4, _nand),
    "AND": GateDef("AND", 2, 5, _and),
}

GATE_CODES: Dict[str, int] = {name: g.code for name, g in GATE_DEFS.items()}
_BY_CODE: Tuple[GateDef, ...] = tuple(
    sorted(GATE_DEFS.values(), key=lambda g: g.code)
)


def gate_by_code(code: int) -> GateDef:
    return _BY_CODE[code]
