"""PartitionPIM core: partition models, half-gate periphery, control codecs."""
from repro.core.gates import GATE_CODES, GATE_DEFS, gate_by_code
from repro.core.operation import (
    GateOp,
    InitOp,
    LegalityError,
    Operation,
    PartitionConfig,
    gate_interval,
    op_intervals,
    tight_selects,
)
from repro.core.models import MODELS, is_legal, validate
from repro.core.control import decode, encode, message_bits
from repro.core.program import Program, ProgramBuilder, ProgramStats
from repro.core import bounds, periphery

__all__ = [
    "GATE_CODES",
    "GATE_DEFS",
    "gate_by_code",
    "GateOp",
    "InitOp",
    "LegalityError",
    "Operation",
    "PartitionConfig",
    "gate_interval",
    "op_intervals",
    "tight_selects",
    "MODELS",
    "is_legal",
    "validate",
    "decode",
    "encode",
    "message_bits",
    "Program",
    "ProgramBuilder",
    "ProgramStats",
    "bounds",
    "periphery",
]
