"""Combinatorial lower bounds on control-message length (paper §2.3/§3.3/§4.3).

Counts the supported operations of each design with exact integer arithmetic;
``ceil(log2(count))`` lower-bounds any message encoding.  Paper values for
(k=32, n=1024): unlimited >= 443 bits, standard >= 46 bits, minimal >= 25 bits
(vs implemented 607 / 79 / 36).
"""
from __future__ import annotations

import math
from repro.core.operation import PartitionConfig

__all__ = [
    "count_serial",
    "count_parallel",
    "unlimited_lower_bound",
    "standard_lower_bound",
    "minimal_lower_bound",
]


def _comb(n: int, r: int) -> int:
    return math.comb(n, r)


def count_serial(n: int) -> int:
    """C(n,2) * (n-2): unordered input pair x distinct output column."""
    return _comb(n, 2) * (n - 2)


def count_parallel(n: int, k: int) -> int:
    """[C(m,2) * (m-2)]^k: every partition runs an independent gate."""
    m = n // k
    return (_comb(m, 2) * (m - 2)) ** k


def unlimited_lower_bound(cfg: PartitionConfig) -> int:
    """§2.3: serial + parallel operations alone (semi-parallel not counted —
    valid since we seek a lower bound)."""
    total = count_serial(cfg.n) + count_parallel(cfg.n, cfg.k)
    return math.ceil(math.log2(total))


def standard_lower_bound(cfg: PartitionConfig) -> int:
    """§3.3: 2 * sum_m C(k-1, m-1) * C(n/k, 2) * (n/k - 2).

    For each number of sections m there are C(k-1, m-1) section divisions;
    shared intra indices contribute C(m,2)*(m-2) gate choices; the factor 2
    is the global direction.
    """
    m_cols = cfg.m
    per_idx = _comb(m_cols, 2) * (m_cols - 2)
    total = 2 * sum(_comb(cfg.k - 1, s - 1) for s in range(1, cfg.k + 1)) * per_idx
    return math.ceil(math.log2(total))


def minimal_lower_bound(cfg: PartitionConfig) -> int:
    """§4.3: all non-input-split serial operations are supported.

    Input partition (k) x *ordered* intra input pair m*(m-1) (InA and InB
    are distinct message fields) x output column anywhere (n-2); distance
    and direction are implied by the output choice.  Gives 25 bits at
    (k=32, n=1024), matching the paper.
    """
    per = cfg.k * cfg.m * (cfg.m - 1) * (cfg.n - 2)
    return math.ceil(math.log2(per))
