"""Crossbar periphery: the half-gates technique (paper §2.2, Table 1, Fig 4/5).

Each partition has its own small column decoder (k CMOS n/k-decoders replace
one CMOS n-decoder — *fewer* CMOS gates than a partition-free crossbar) plus a
3-bit opcode ``(enA, enB, enOut)``:

    ===== ==========================  ===== ==========================
    000   —                            100   Gate(InA,?) -> ?
    001   ? -> Out                     101   Gate(InA,?) -> Out
    010   Gate(?,InB) -> ?             110   Gate(InA,InB) -> ?
    011   Gate(?,InB) -> Out           111   Gate(InA,InB) -> Out
    ===== ==========================  ===== ==========================

A partition applies only the halves its opcode enables; the *combination* of
half-gates along a section forms one valid gate.  This module implements:

* :func:`op_opcodes` — the opcodes/indices a controller derives for a given
  operation (the unlimited model's message payload).
* :func:`standard_opcode_generator` — §3.2.2: opcodes from transistor selects
  + per-partition enables + a global direction bit (two 2:1 muxes/partition).
* :func:`minimal_range_generator` — §4.2: input opcodes from a range
  generator (p_start, p_end, period), output opcodes by shifting by the
  partition distance, transistor selects derived from the opcodes.
* :func:`simulate_voltages` / :func:`sections_from_selects` — an electrical-
  level check that the applied half-gates combine into exactly the intended
  gates (used by the tests as an independent validation path).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.core.gates import GATE_DEFS
from repro.core.operation import (
    GateOp,
    LegalityError,
    Operation,
    PartitionConfig,
    tight_selects,
)

__all__ = [
    "PartitionOpcode",
    "op_opcodes",
    "standard_opcode_generator",
    "minimal_range_generator",
    "sections_from_selects",
    "simulate_voltages",
]


@dataclasses.dataclass(frozen=True)
class PartitionOpcode:
    """Opcode + intra-partition indices for one partition's column decoder."""

    en_a: bool = False
    en_b: bool = False
    en_out: bool = False
    idx_a: int = 0
    idx_b: int = 0
    idx_out: int = 0

    @property
    def bits(self) -> int:
        return (self.en_a << 2) | (self.en_b << 1) | int(self.en_out)


def op_opcodes(
    op: Operation, cfg: PartitionConfig
) -> Tuple[List[PartitionOpcode], List[bool]]:
    """Derive per-partition opcodes + tight transistor selects for a logic op.

    This is exactly what the unlimited model's control message carries.
    Half-gates: the input partition of a gate raises ``en_a``/``en_b``; the
    output partition raises ``en_out``; intermediate partitions stay at 000.
    Split-input gates (unlimited only) raise ``en_a`` and ``en_b`` in
    different partitions.
    """
    assert not op.is_init
    ops: List[Dict] = [dict(en_a=False, en_b=False, en_out=False,
                            idx_a=0, idx_b=0, idx_out=0) for _ in range(cfg.k)]
    for g in op.gates:
        pa = cfg.partition(g.inputs[0])
        ops[pa]["en_a"] = True
        ops[pa]["idx_a"] = cfg.intra(g.inputs[0])
        if len(g.inputs) > 1:
            pb = cfg.partition(g.inputs[1])
            ops[pb]["en_b"] = True
            ops[pb]["idx_b"] = cfg.intra(g.inputs[1])
        po = cfg.partition(g.output)
        ops[po]["en_out"] = True
        ops[po]["idx_out"] = cfg.intra(g.output)
    return [PartitionOpcode(**o) for o in ops], tight_selects(op, cfg)


def standard_opcode_generator(
    selects: Sequence[bool], enables: Sequence[bool], direction: int
) -> List[Tuple[bool, bool, bool]]:
    """§3.2.2 opcode generation — two 2:1 multiplexers per partition.

    ``selects[i]`` is the transistor between partitions i and i+1 (True =
    selected = non-conducting = section boundary); the crossbar edges are
    implicit boundaries.  For direction +1 ("inputs left of outputs") the
    input-enable of partition p is the select of the transistor to its LEFT
    (p is then the leftmost partition of its section, where the standard
    model's gates keep their inputs) and the output-enable is the select to
    its RIGHT; vice versa for direction -1.  Everything is ANDed with the
    partition enable.
    """
    k = len(enables)
    assert len(selects) == k - 1
    out: List[Tuple[bool, bool, bool]] = []
    for p in range(k):
        left = selects[p - 1] if p > 0 else True
        right = selects[p] if p < k - 1 else True
        in_en = left if direction >= 0 else right
        out_en = right if direction >= 0 else left
        e = bool(enables[p])
        out.append((in_en and e, in_en and e, out_en and e))
    return out


def minimal_range_generator(
    k: int, p_start: int, p_end: int, period: int, distance: int, direction: int
) -> Tuple[List[bool], List[bool], List[bool]]:
    """§4.2 periphery: (input enables, output enables, transistor selects).

    * input enables: logical one every ``period`` partitions in
      ``[p_start, p_end]`` (two shifters + a decoder in hardware);
    * output enables: input enables shifted by ``distance`` along
      ``direction`` (up-to-k shifter);
    * transistor selects: derived — for direction +1, the transistor between
      p and p+1 isolates iff an *output* sits at p (a gate ends there) or an
      *input* sits at p+1 (a gate begins there); mirrored for direction -1.
    """
    in_en = [False] * k
    for p in range(p_start, p_end + 1, max(period, 1)):
        in_en[p] = True
    out_en = [False] * k
    for p in range(k):
        if in_en[p]:
            q = p + distance * (1 if direction >= 0 else -1)
            if not 0 <= q < k:
                raise LegalityError(f"output partition {q} out of range")
            out_en[q] = True
    selects = []
    for i in range(k - 1):
        if direction >= 0:
            selects.append(out_en[i] or in_en[i + 1])
        else:
            selects.append(in_en[i] or out_en[i + 1])
    return in_en, out_en, selects


def sections_from_selects(selects: Sequence[bool]) -> List[Tuple[int, int]]:
    """Partition intervals induced by transistor selects (True = boundary)."""
    k = len(selects) + 1
    sections = []
    start = 0
    for i in range(k - 1):
        if selects[i]:
            sections.append((start, i))
            start = i + 1
    sections.append((start, k - 1))
    return sections


def simulate_voltages(
    opcodes: Sequence[PartitionOpcode],
    selects: Sequence[bool],
    cfg: PartitionConfig,
    gate_type: str,
) -> List[GateOp]:
    """Electrically combine half-gates into whole gates.

    Applies each partition's half-gate voltages onto its bitlines, splits the
    crossbar by the (non-)conducting transistors, and checks each section
    carries either nothing or exactly one valid gate's voltages (the right
    number of input drivers and exactly one output driver).  Returns the
    reconstructed gates — the tests assert these equal the intended ones.
    """
    n_inputs = GATE_DEFS[gate_type].n_inputs
    gates: List[GateOp] = []
    for lo, hi in sections_from_selects(selects):
        a_cols: List[int] = []
        b_cols: List[int] = []
        out_cols: List[int] = []
        for p in range(lo, hi + 1):
            oc = opcodes[p]
            if oc.en_a:
                a_cols.append(cfg.col(p, oc.idx_a))
            if oc.en_b:
                b_cols.append(cfg.col(p, oc.idx_b))
            if oc.en_out:
                out_cols.append(cfg.col(p, oc.idx_out))
        if not (a_cols or b_cols or out_cols):
            continue  # idle section
        if len(out_cols) != 1:
            raise LegalityError(f"section [{lo},{hi}]: {len(out_cols)} output drivers")
        if n_inputs >= 1 and len(a_cols) != 1:
            raise LegalityError(f"section [{lo},{hi}]: {len(a_cols)} InA drivers")
        if n_inputs == 2 and len(b_cols) != 1:
            raise LegalityError(f"section [{lo},{hi}]: {len(b_cols)} InB drivers")
        inputs = tuple(a_cols[:1] + b_cols[:1])[:n_inputs]
        gates.append(GateOp(gate_type, inputs, out_cols[0]))
    return gates
