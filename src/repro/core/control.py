"""Controller→crossbar control messages (paper §2.3, §3.3, §4.3).

Implements the *actual bit-level codecs* for the four designs, so the paper's
message lengths are measured from working encoders rather than asserted:

    ============  ==============================================  =======
    design        bit formula                                      k=32,
                                                                   n=1024
    ============  ==============================================  =======
    baseline      3*log2(n)                                        30
    unlimited     3k*log2(n/k) + 3k + (k-1)                        607
    standard      3*log2(n/k) + (2k-1) + 1                         79
    minimal       3*log2(n/k) + 3*log2(k) + log2(k) + 1            36
    ============  ==============================================  =======

Encoders take a legal :class:`Operation` and emit a bit string of *exactly*
the design's length; decoders reconstruct the operation (via the periphery
logic of ``core/periphery.py``), and the tests assert the roundtrip.  The
gate type (NOT vs NOR vs the FELIX gates) selects the analog voltage
configuration and is conveyed out-of-band, as in the paper's bit counts.

Init operations are writes; they reuse the same message framing (their index
payload fits within the design's message length), so every cycle costs one
message of the design's fixed length.
"""
from __future__ import annotations

import math
from typing import List

from repro.core.models import gate_direction, gate_distance, validate
from repro.core.operation import (
    GateOp,
    LegalityError,
    Operation,
    PartitionConfig,
    tight_selects,
)
from repro.core.periphery import (PartitionOpcode, minimal_range_generator,
                                  op_opcodes, simulate_voltages,
                                  standard_opcode_generator)

__all__ = [
    "message_bits",
    "encode",
    "decode",
    "BitWriter",
    "BitReader",
]


def _log2(x: int) -> int:
    l = int(math.log2(x))
    assert (1 << l) == x, f"{x} must be a power of two"
    return l


def message_bits(model: str, cfg: PartitionConfig) -> int:
    """Message length in bits for one cycle under each design."""
    n, k, m = cfg.n, cfg.k, cfg.m
    if model == "baseline":
        return 3 * _log2(n)
    if model == "unlimited":
        return 3 * k * _log2(m) + 3 * k + (k - 1)
    if model == "standard":
        return 3 * _log2(m) + (2 * k - 1) + 1
    if model == "minimal":
        return 3 * _log2(m) + 3 * _log2(k) + _log2(k) + 1
    raise ValueError(model)


class BitWriter:
    def __init__(self):
        self.bits: List[int] = []

    def write(self, value: int, width: int) -> "BitWriter":
        assert 0 <= value < (1 << width), (value, width)
        for i in reversed(range(width)):
            self.bits.append((value >> i) & 1)
        return self

    def write_flag(self, b: bool) -> "BitWriter":
        self.bits.append(int(b))
        return self

    def payload(self, total: int) -> str:
        assert len(self.bits) <= total, (len(self.bits), total)
        return "".join(map(str, self.bits)) + "0" * (total - len(self.bits))


class BitReader:
    def __init__(self, s: str):
        self.s = s
        self.pos = 0

    def read(self, width: int) -> int:
        v = int(self.s[self.pos : self.pos + width], 2)
        self.pos += width
        return v

    def read_flag(self) -> bool:
        return bool(self.read(1))


# ---------------------------------------------------------------------------
# Encoding
# ---------------------------------------------------------------------------
# Every message starts with a 2-bit frame [is_init, init_kind] carried on the
# command lines alongside the (out-of-band) gate-type selection; the paper's
# message-length accounting covers the index/opcode payload, which is what the
# ``message_bits`` formulas (and our payload widths) measure.


def _encode_init(op: Operation, cfg: PartitionConfig, model: str, w: BitWriter):
    init = op.init
    lg_n, lg_m, lg_k = _log2(cfg.n), _log2(cfg.m), _log2(cfg.k)
    if init.kind == "range":
        width = lg_n if model in ("baseline", "unlimited") else lg_m
        if model in ("baseline", "unlimited"):
            w.write(init.lo, width).write(init.hi, width)
        else:
            # standard/minimal: absolute range re-expressed as (partition,
            # intra) pairs; must live inside one partition or span aligned.
            p_lo, p_hi = cfg.partition(init.lo), cfg.partition(init.hi)
            if p_lo == p_hi:
                w.write_flag(False)
                w.write(cfg.intra(init.lo), lg_m).write(cfg.intra(init.hi), lg_m)
                w.write(p_lo, lg_k)
            else:  # spanning range init (e.g. clearing a workspace)
                if model == "minimal" and p_hi != cfg.k - 1:
                    raise LegalityError(
                        "minimal: spanning range init must end at the last partition"
                    )
                w.write_flag(True)
                w.write(cfg.intra(init.lo), lg_m).write(cfg.intra(init.hi), lg_m)
                w.write(p_lo, lg_k)
                if model == "standard":
                    w.write(p_hi, lg_k)
    else:  # periodic
        w.write(init.lo, lg_m).write(init.hi, lg_m)
        w.write(init.p_start, lg_k).write(init.p_end, lg_k)
        w.write(init.period - 1, lg_k)


def encode(op: Operation, cfg: PartitionConfig, model: str) -> str:
    """Encode a legal operation into the design's fixed-length message."""
    validate(op, cfg, model)
    total = message_bits(model, cfg)
    lg_n, lg_m, lg_k = _log2(cfg.n), _log2(cfg.m), _log2(cfg.k)
    w = BitWriter()
    w.write_flag(op.is_init)
    if op.is_init:
        w.write_flag(op.init.kind == "periodic")
        _encode_init(op, cfg, model, w)
        payload = "".join(map(str, w.bits))
        if len(payload) > total + 2:
            raise LegalityError(f"init payload {len(payload)} > frame {total + 2}")
        return payload + "0" * (total + 2 - len(payload))
    w.write_flag(False)

    if model == "baseline":
        (g,) = op.gates
        in_a = g.inputs[0]
        in_b = g.inputs[1] if len(g.inputs) > 1 else g.inputs[0]
        w.write(in_a, lg_n).write(in_b, lg_n).write(g.output, lg_n)
        return w.payload(total + 2)

    if model == "unlimited":
        opcodes, selects = op_opcodes(op, cfg)
        for oc in opcodes:
            w.write_flag(oc.en_a).write_flag(oc.en_b).write_flag(oc.en_out)
            w.write(oc.idx_a, lg_m).write(oc.idx_b, lg_m).write(oc.idx_out, lg_m)
        for s in selects:
            w.write_flag(s)
        return w.payload(total + 2)

    # standard / minimal: shared intra indices.
    g0 = op.gates[0]
    idx_a = cfg.intra(g0.inputs[0])
    idx_b = cfg.intra(g0.inputs[1]) if len(g0.inputs) > 1 else idx_a
    idx_out = cfg.intra(g0.output)
    dirs = {gate_direction(g, cfg) for g in op.gates} - {0}
    direction = dirs.pop() if dirs else 1
    w.write(idx_a, lg_m).write(idx_b, lg_m).write(idx_out, lg_m)

    if model == "standard":
        selects = tight_selects(op, cfg)
        active = [False] * cfg.k
        for g in op.gates:
            lo, hi = (
                min(cfg.partition(g.inputs[0]), cfg.partition(g.output)),
                max(cfg.partition(g.inputs[0]), cfg.partition(g.output)),
            )
            for p in range(lo, hi + 1):
                active[p] = True
        for e in active:
            w.write_flag(e)
        for s in selects:
            w.write_flag(s)
        w.write_flag(direction > 0)
        return w.payload(total + 2)

    # minimal
    dist = gate_distance(op.gates[0], cfg)
    ips = sorted(cfg.partition(g.inputs[0]) for g in op.gates)
    period = (ips[1] - ips[0]) if len(ips) >= 2 else dist + 1
    w.write(ips[0], lg_k).write(ips[-1], lg_k).write(period - 1, lg_k)
    w.write(dist, lg_k)
    w.write_flag(direction > 0)
    return w.payload(total + 2)


# ---------------------------------------------------------------------------
# Decoding — reconstructs the operation through the periphery logic.
# ---------------------------------------------------------------------------


def _decode_init(r: BitReader, cfg: PartitionConfig, model: str) -> Operation:
    from repro.core.operation import InitOp

    lg_n, lg_m, lg_k = _log2(cfg.n), _log2(cfg.m), _log2(cfg.k)
    periodic = r.read_flag()
    if periodic:
        lo, hi = r.read(lg_m), r.read(lg_m)
        p_start, p_end = r.read(lg_k), r.read(lg_k)
        period = r.read(lg_k) + 1
        return Operation(init=InitOp("periodic", lo, hi, p_start, p_end, period))
    if model in ("baseline", "unlimited"):
        lo, hi = r.read(lg_n), r.read(lg_n)
        return Operation(init=InitOp("range", lo, hi))
    spanning = r.read_flag()
    ilo, ihi = r.read(lg_m), r.read(lg_m)
    p_lo = r.read(lg_k)
    if not spanning:
        return Operation(init=InitOp("range", cfg.col(p_lo, ilo), cfg.col(p_lo, ihi)))
    p_hi = r.read(lg_k) if model == "standard" else cfg.k - 1
    return Operation(init=InitOp("range", cfg.col(p_lo, ilo), cfg.col(p_hi, ihi)))


def decode(message: str, cfg: PartitionConfig, model: str, gate_type: str) -> Operation:
    """Decode a message back into an Operation (periphery-level path)."""
    from repro.core.gates import GATE_DEFS

    r = BitReader(message)
    if r.read_flag():
        return _decode_init(r, cfg, model)
    r.read_flag()
    lg_n, lg_m, lg_k = _log2(cfg.n), _log2(cfg.m), _log2(cfg.k)
    n_inputs = GATE_DEFS[gate_type].n_inputs

    if model == "baseline":
        in_a, in_b, out = r.read(lg_n), r.read(lg_n), r.read(lg_n)
        inputs = (in_a, in_b)[:n_inputs]
        return Operation(gates=(GateOp(gate_type, inputs, out),))

    if model == "unlimited":
        opcodes = []
        for _ in range(cfg.k):
            en_a, en_b, en_out = r.read_flag(), r.read_flag(), r.read_flag()
            idx_a, idx_b, idx_out = r.read(lg_m), r.read(lg_m), r.read(lg_m)
            opcodes.append(
                PartitionOpcode(en_a, en_b and n_inputs == 2, en_out,
                                idx_a, idx_b, idx_out)
            )
        selects = [r.read_flag() for _ in range(cfg.k - 1)]
        gates = simulate_voltages(opcodes, selects, cfg, gate_type)
        return Operation(gates=tuple(gates))

    idx_a, idx_b, idx_out = r.read(lg_m), r.read(lg_m), r.read(lg_m)

    if model == "standard":
        enables = [r.read_flag() for _ in range(cfg.k)]
        selects = [r.read_flag() for _ in range(cfg.k - 1)]
        direction = 1 if r.read_flag() else -1
        trios = standard_opcode_generator(selects, enables, direction)
        opcodes = [
            PartitionOpcode(a, b and n_inputs == 2, o, idx_a, idx_b, idx_out)
            for (a, b, o) in trios
        ]
        gates = simulate_voltages(opcodes, selects, cfg, gate_type)
        return Operation(gates=tuple(gates))

    # minimal
    p_start, p_end = r.read(lg_k), r.read(lg_k)
    period = r.read(lg_k) + 1
    dist = r.read(lg_k)
    direction = 1 if r.read_flag() else -1
    in_en, out_en, selects = minimal_range_generator(
        cfg.k, p_start, p_end, period, dist, direction
    )
    opcodes = [
        PartitionOpcode(
            in_en[p], in_en[p] and n_inputs == 2, out_en[p], idx_a, idx_b, idx_out
        )
        for p in range(cfg.k)
    ]
    gates = simulate_voltages(opcodes, selects, cfg, gate_type)
    return Operation(gates=tuple(gates))
