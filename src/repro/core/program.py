"""Programs: validated sequences of crossbar operations + cost accounting.

A :class:`Program` is the unit the benchmarks measure, mirroring the paper's
evaluation metrics (§5):

* **latency**  — number of cycles = number of operations (each operation,
  init included, occupies one crossbar cycle and one control message);
* **energy**   — stateful-logic energy is dominated by memristor switching,
  approximated by the total gate count [Ronen'21]; init SETs are counted
  separately (``init_columns``) and reported both ways;
* **area**     — algorithmic area = distinct memristor columns used per row;
* **control**  — total control traffic = cycles x message_bits(model).

``Program.validate()`` checks every operation against the model's legality
rules; ``Program.check_messages()`` additionally runs every operation through
the *actual* control codec (encode -> decode -> same gates), proving the
reported message lengths really carry the program.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from repro.core import control as control_mod
from repro.core.gates import GATE_CODES
from repro.core.models import validate as validate_op
from repro.core.operation import (
    GateOp,
    InitOp,
    LegalityError,
    Operation,
    PartitionConfig,
)

__all__ = ["Program", "ProgramStats", "ProgramBuilder"]

# Microcode ABI: rows of (gate_code, in_a, in_b, out); INIT rows use
# (0, 0, 0, col).  Executors (jnp + pallas) consume this flat form.
MICROCODE_WIDTH = 4


@dataclasses.dataclass
class ProgramStats:
    cycles: int
    logic_gates: int
    init_columns: int
    area_columns: int
    control_bits_per_message: int
    total_control_bits: int
    op_class_counts: Dict[str, int]

    @property
    def energy_gates(self) -> int:
        """Paper §5.4 proxy: total gate count (logic + init switching)."""
        return self.logic_gates + self.init_columns


@dataclasses.dataclass
class Program:
    cfg: PartitionConfig
    model: str
    ops: List[Operation] = dataclasses.field(default_factory=list)
    name: str = ""

    def append(self, op: Operation) -> None:
        self.ops.append(op)

    def validate(self) -> None:
        for i, op in enumerate(self.ops):
            try:
                validate_op(op, self.cfg, self.model)
            except LegalityError as e:
                raise LegalityError(f"op {i} ({op.label or op.gate_type}): {e}") from e

    def check_messages(self, sample_every: int = 1) -> None:
        """Round-trip every (sample_every-th) op through the control codec."""
        for i, op in enumerate(self.ops):
            if i % sample_every:
                continue
            msg = control_mod.encode(op, self.cfg, self.model)
            back = control_mod.decode(msg, self.cfg, self.model, op.gate_type)
            if op.is_init:
                want = set(op.init.columns(self.cfg))
                got = set(back.init.columns(self.cfg))
            else:
                want = {(g.gate, g.inputs, g.output) for g in op.gates}
                got = {(g.gate, g.inputs, g.output) for g in back.gates}
            if want != got:
                raise LegalityError(
                    f"codec roundtrip mismatch at op {i} ({op.label}): "
                    f"{sorted(want)[:4]} != {sorted(got)[:4]}"
                )

    # -- cost accounting ----------------------------------------------------

    def stats(self) -> ProgramStats:
        logic = 0
        init_cols = 0
        used: Set[int] = set()
        classes: Dict[str, int] = {}
        for op in self.ops:
            cls = op.classify(self.cfg)
            classes[cls] = classes.get(cls, 0) + 1
            if op.is_init:
                cols = op.init.columns(self.cfg)
                init_cols += len(cols)
                used.update(cols)
            else:
                logic += len(op.gates)
                for g in op.gates:
                    used.update(g.columns)
        bits = control_mod.message_bits(self.model, self.cfg)
        return ProgramStats(
            cycles=len(self.ops),
            logic_gates=logic,
            init_columns=init_cols,
            area_columns=len(used),
            control_bits_per_message=bits,
            total_control_bits=bits * len(self.ops),
            op_class_counts=classes,
        )

    # -- microcode ------------------------------------------------------------

    def to_microcode(self) -> np.ndarray:
        """Flatten to (G, 4) int32 microcode for the executors.

        Gates within one operation are electrically concurrent in disjoint
        sections, hence order-independent; the executor applies them
        sequentially, which is semantics-preserving (validated legality
        guarantees column-disjointness inside an operation).
        """
        rows: List[Tuple[int, int, int, int]] = []
        for op in self.ops:
            if op.is_init:
                for c in op.init.columns(self.cfg):
                    rows.append((GATE_CODES["INIT"], 0, 0, c))
            else:
                for g in op.gates:
                    code = GATE_CODES[g.gate]
                    in_a = g.inputs[0]
                    in_b = g.inputs[1] if len(g.inputs) > 1 else g.inputs[0]
                    rows.append((code, in_a, in_b, g.output))
        if not rows:
            return np.zeros((0, MICROCODE_WIDTH), np.int32)
        return np.asarray(rows, np.int32)


class ProgramBuilder:
    """Convenience builder used by the arithmetic algorithms.

    This is the ONE program-construction API: ``pim/matmul.py``,
    ``pim/multpim.py`` and ``pim/mult_serial.py`` all emit operations
    through it (they used to carry private ``_B`` clones).  Three layers of
    helpers:

    * raw:        ``emit`` (append a pre-built Operation);
    * gate-level: ``gate`` (one serial gate), ``par`` (one fused parallel
      operation), ``init_range`` / ``init_periodic`` (SET windows);
    * model-aware: ``try_op`` / ``fuse_or`` append a fused operation if it
      is legal under the program's model and otherwise the provided legal
      fallback decomposition — the mechanism the paper uses to adapt
      MultPIM to standard/minimal (§5).
    """

    def __init__(self, cfg: PartitionConfig, model: str, name: str = ""):
        self.program = Program(cfg=cfg, model=model, name=name)
        self.cfg = cfg
        self.model = model

    # -- raw ----------------------------------------------------------------

    def emit(self, op: Operation) -> None:
        self.program.append(op)

    # -- gate level ---------------------------------------------------------

    def op(self, *gates: GateOp, label: str = "") -> None:
        self.program.append(Operation(gates=tuple(gates), label=label))

    def init(self, init_op: InitOp, label: str = "") -> None:
        self.program.append(Operation(init=init_op, label=label))

    def gate(self, name: str, ins: Iterable[int], out: int,
             label: str = "") -> None:
        """One serial gate as its own operation."""
        self.program.append(
            Operation(gates=(GateOp(name, tuple(ins), out),), label=label))

    def par(self, gates: Iterable[GateOp], label: str = "") -> None:
        """One parallel operation of concurrent gates."""
        self.program.append(Operation(gates=tuple(gates), label=label))

    def init_range(self, lo: int, hi: int, label: str = "") -> None:
        """SET the contiguous column range ``[lo, hi]``."""
        self.program.append(Operation(init=InitOp("range", lo, hi),
                                      label=label))

    def init_periodic(self, ilo: int, ihi: int, p_start: int = 0,
                      p_end: Optional[int] = None, period: int = 1,
                      label: str = "") -> None:
        """SET intra range ``[ilo, ihi]`` in partitions ``p_start..p_end``
        (default: all) with the given period."""
        p_end = self.cfg.k - 1 if p_end is None else p_end
        self.program.append(Operation(
            init=InitOp("periodic", ilo, ihi, p_start, p_end, period),
            label=label))

    # -- model-aware --------------------------------------------------------

    def fuse_or(self, fused: Operation, fallback: Iterable[Operation],
                label: str = "") -> bool:
        """Append ``fused`` if legal under the model, else ``fallback``."""
        return self.try_op((fused,), fallback, label=label)

    def try_op(
        self,
        fused: Iterable[Operation],
        fallback: Iterable[Operation],
        label: str = "",
    ) -> bool:
        """Append ``fused`` if every op in it is legal; else ``fallback``."""
        from repro.core.models import is_legal

        fused = list(fused)
        if all(is_legal(o, self.cfg, self.model) for o in fused):
            for o in fused:
                self.program.append(o)
            return True
        for o in fallback:
            self.program.append(o)
        return False

    def build(self, check: bool = True) -> Program:
        if check:
            self.program.validate()
        return self.program
