"""Operations on a partitioned memristive crossbar (paper §2.1).

A crossbar has ``n`` bitlines divided by ``k-1`` transistors into ``k``
evenly-spaced *partitions* of ``m = n // k`` bitlines.  Setting a subset of
transistors non-conducting dynamically divides the crossbar into *sections*
(disjoint intervals of partitions); each section may execute one stateful
logic gate per cycle.  An :class:`Operation` is the set of gates executed in
one cycle; the paper classifies operations as *serial* (one gate, whole
crossbar one section), *parallel* (one gate per partition) and
*semi-parallel* (anything in between — gates spanning several partitions).

Column indices are absolute in ``[0, n)``.  ``partition(c) = c // m`` and the
*intra-partition index* is ``c % m`` — the quantity shared across decoders in
the standard/minimal models.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

__all__ = [
    "PartitionConfig",
    "GateOp",
    "InitOp",
    "Operation",
    "LegalityError",
    "gate_interval",
    "op_intervals",
    "tight_selects",
]


class LegalityError(ValueError):
    """Raised when an operation is illegal under a partition model."""


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    """Evenly spaced partitions: ``n`` bitlines, ``k`` partitions."""

    n: int = 1024
    k: int = 32

    def __post_init__(self):
        if self.n % self.k != 0:
            raise ValueError(f"n={self.n} must be divisible by k={self.k}")

    @property
    def m(self) -> int:
        """Bitlines per partition."""
        return self.n // self.k

    def partition(self, col: int) -> int:
        if not 0 <= col < self.n:
            raise ValueError(f"column {col} out of range [0,{self.n})")
        return col // self.m

    def intra(self, col: int) -> int:
        return col % self.m

    def col(self, partition: int, intra: int) -> int:
        assert 0 <= partition < self.k and 0 <= intra < self.m
        return partition * self.m + intra

    def scaled(self, *, n: Optional[int] = None,
               k: Optional[int] = None) -> "PartitionConfig":
        """A validated copy at a different geometry (autotune candidates).

        Widening ``n`` at fixed ``k`` grows the per-partition column budget
        ``m`` (more dot terms per row) but also the column-index field in
        every control message; the trade-off is what ``pim.autotune``
        searches over.
        """
        return PartitionConfig(self.n if n is None else n,
                               self.k if k is None else k)


@dataclasses.dataclass(frozen=True)
class GateOp:
    """One stateful-logic gate: ``gate(*inputs) -> output`` (column indices)."""

    gate: str
    inputs: Tuple[int, ...]
    output: int

    def __post_init__(self):
        from repro.core.gates import GATE_DEFS

        g = GATE_DEFS[self.gate]
        if g.n_inputs != len(self.inputs):
            raise ValueError(f"{self.gate} takes {g.n_inputs} inputs")
        if self.output in self.inputs:
            raise ValueError("MAGIC output memristor must differ from inputs")

    @property
    def columns(self) -> Tuple[int, ...]:
        return self.inputs + (self.output,)


@dataclasses.dataclass(frozen=True)
class InitOp:
    """Initialization (SET to logic '1') of a set of columns in one cycle.

    Initialization is a plain memory *write* (no sneak paths: unconditional
    SET of whole columns), so — as in prior simulators — a contiguous column
    range may be initialized in a single cycle.  Two forms exist:

    * ``range``:    absolute columns ``[lo, hi]`` (legal in every model,
                    including the baseline crossbar: it is just a write).
    * ``periodic``: intra-partition range ``[ilo, ihi]`` replicated at
                    partitions ``p_start, p_start+T, ..., p_end`` — the
                    partition-parallel form used by partitioned algorithms.

    This assumption is applied identically to the serial baseline and to all
    partition models, so latency *ratios* are unaffected by it (DESIGN.md §2).
    """

    kind: str  # "range" | "periodic"
    lo: int = 0
    hi: int = 0  # inclusive; intra-partition for "periodic"
    p_start: int = 0
    p_end: int = 0
    period: int = 1

    def columns(self, cfg: PartitionConfig) -> List[int]:
        if self.kind == "range":
            return list(range(self.lo, self.hi + 1))
        cols: List[int] = []
        for p in range(self.p_start, self.p_end + 1, self.period):
            cols.extend(cfg.col(p, i) for i in range(self.lo, self.hi + 1))
        return cols


@dataclasses.dataclass(frozen=True)
class Operation:
    """One crossbar cycle: either a set of concurrent gates or an init.

    All gates in a logic operation share a single gate type (the gate type
    selects the analog voltage configuration V_IN/V_OUT and is conveyed
    out-of-band of the index message, as in the paper's bit counts).
    """

    gates: Tuple[GateOp, ...] = ()
    init: Optional[InitOp] = None
    label: str = ""

    def __post_init__(self):
        if (self.init is None) == (len(self.gates) == 0):
            raise ValueError("operation must be either gates or an init")
        if self.gates:
            types = {g.gate for g in self.gates}
            if len(types) > 1:
                raise LegalityError(
                    f"one gate type per operation (voltage config): {types}"
                )

    @property
    def is_init(self) -> bool:
        return self.init is not None

    @property
    def gate_type(self) -> str:
        return "INIT" if self.is_init else self.gates[0].gate

    def classify(self, cfg: PartitionConfig) -> str:
        """Paper taxonomy: serial / parallel / semi-parallel (§2.1)."""
        if self.is_init:
            return "init"
        if len(self.gates) == 1:
            return "serial"
        ivals = op_intervals(self, cfg)
        if len(ivals) == cfg.k and all(l == r for l, r in ivals):
            return "parallel"
        return "semi-parallel"


def gate_interval(g: GateOp, cfg: PartitionConfig) -> Tuple[int, int]:
    """The (inclusive) partition interval a gate's section must span."""
    parts = [cfg.partition(c) for c in g.columns]
    return (min(parts), max(parts))


def op_intervals(op: Operation, cfg: PartitionConfig) -> List[Tuple[int, int]]:
    """Sorted section intervals of a logic op; raises if they overlap.

    Disjointness is the *physical* requirement shared by every model: two
    concurrent gates must live in electrically isolated sections.
    """
    assert not op.is_init
    ivals = sorted(gate_interval(g, cfg) for g in op.gates)
    for (l0, r0), (l1, r1) in zip(ivals, ivals[1:]):
        if r0 >= l1:
            raise LegalityError(
                f"concurrent gates overlap partitions: [{l0},{r0}] and [{l1},{r1}]"
            )
    return ivals


def tight_selects(op: Operation, cfg: PartitionConfig) -> List[bool]:
    """Tight section division (paper §3.2.2) as transistor 'selects'.

    ``selects[i]`` refers to the transistor between partitions ``i`` and
    ``i+1``; ``True`` means *selected* = non-conducting = a section boundary.
    Tight: a transistor conducts only if some gate's section spans it.
    """
    assert not op.is_init
    selects = [True] * (cfg.k - 1)
    for g in op.gates:
        l, r = gate_interval(g, cfg)
        for i in range(l, r):
            selects[i] = False
    return selects
