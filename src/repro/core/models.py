"""The three partition models (paper §2–§4) as legality checkers.

* ``baseline``  — crossbar without partitions: one gate per cycle.
* ``unlimited`` — any set of gates in disjoint sections (§2); per-partition
                  opcodes + indices; 607-bit messages at (k=32, n=1024).
* ``standard``  — §3 restrictions: *Identical Indices*, *No Split-Input*,
                  *Uniform Direction*; 79-bit messages.
* ``minimal``   — §4 restrictions (in addition): *Uniform Partition-Distance*
                  and *Periodic*; 36-bit messages.

``validate(op, cfg, model)`` raises :class:`LegalityError` with the violated
criterion; algorithms use ``is_legal`` to pick between a fused operation and
its legal decomposition, which is exactly how the paper's evaluation replaces
MultPIM's unsupported operations with compatible alternatives (§5, fn. 4/5).
"""
from __future__ import annotations


from repro.core.operation import (GateOp, InitOp, LegalityError, Operation,
                                  PartitionConfig, op_intervals)

__all__ = ["MODELS", "validate", "is_legal", "gate_direction", "gate_distance"]

MODELS = ("baseline", "unlimited", "standard", "minimal")


def gate_direction(g: GateOp, cfg: PartitionConfig) -> int:
    """+1 if inputs left of output, -1 if right, 0 if same partition."""
    in_part = cfg.partition(g.inputs[0])
    out_part = cfg.partition(g.output)
    return (out_part > in_part) - (out_part < in_part)


def gate_distance(g: GateOp, cfg: PartitionConfig) -> int:
    """Partition distance (paper §4.1): |output partition - input partition|."""
    return abs(cfg.partition(g.output) - cfg.partition(g.inputs[0]))


def _check_no_split_input(op: Operation, cfg: PartitionConfig) -> None:
    for g in op.gates:
        parts = {cfg.partition(c) for c in g.inputs}
        if len(parts) > 1:
            raise LegalityError(f"split input across partitions {parts} ({g})")


def _check_identical_indices(op: Operation, cfg: PartitionConfig) -> None:
    in_a = {cfg.intra(g.inputs[0]) for g in op.gates}
    in_b = {cfg.intra(g.inputs[1]) for g in op.gates if len(g.inputs) > 1}
    out = {cfg.intra(g.output) for g in op.gates}
    for name, s in (("InA", in_a), ("InB", in_b), ("Out", out)):
        if len(s) > 1:
            raise LegalityError(f"intra-partition {name} indices differ: {sorted(s)}")


def _check_uniform_direction(op: Operation, cfg: PartitionConfig) -> None:
    dirs = {gate_direction(g, cfg) for g in op.gates} - {0}
    if len(dirs) > 1:
        raise LegalityError("both gate directions present in one operation")


def _check_minimal(op: Operation, cfg: PartitionConfig) -> None:
    dists = {gate_distance(g, cfg) for g in op.gates}
    if len(dists) > 1:
        raise LegalityError(f"non-uniform partition distance: {sorted(dists)}")
    d = dists.pop()
    ips = sorted(cfg.partition(g.inputs[0]) for g in op.gates)
    if len(ips) != len(set(ips)):
        raise LegalityError("two concurrent gates share an input partition")
    if len(ips) >= 2:
        diffs = {b - a for a, b in zip(ips, ips[1:])}
        if len(diffs) > 1:
            raise LegalityError(f"input partitions not periodic: {ips}")
        t = diffs.pop()
        if t <= d:
            raise LegalityError(f"period T={t} must exceed partition distance {d}")
        if t > cfg.k - 1:
            raise LegalityError(f"period T={t} not encodable with log2(k) bits")


def _check_init(init: InitOp, cfg: PartitionConfig, model: str) -> None:
    if init.kind == "range":
        if not (0 <= init.lo <= init.hi < cfg.n):
            raise LegalityError(f"init range [{init.lo},{init.hi}] out of bounds")
        return
    if init.kind == "periodic":
        if model == "baseline":
            raise LegalityError("periodic init needs partitions")
        if not (0 <= init.lo <= init.hi < cfg.m):
            raise LegalityError("periodic init intra range out of bounds")
        if not (0 <= init.p_start <= init.p_end < cfg.k):
            raise LegalityError("periodic init partition range out of bounds")
        if init.period < 1 or init.period > max(1, cfg.k - 1):
            raise LegalityError(f"bad init period {init.period}")
        return
    raise LegalityError(f"unknown init kind {init.kind!r}")


def validate(op: Operation, cfg: PartitionConfig, model: str) -> None:
    """Raise LegalityError iff ``op`` is illegal under ``model``."""
    if model not in MODELS:
        raise ValueError(f"unknown model {model!r}")
    if op.is_init:
        _check_init(op.init, cfg, model)
        return
    for g in op.gates:
        for c in g.columns:
            if not 0 <= c < cfg.n:
                raise LegalityError(f"column {c} out of range")
    if model == "baseline":
        if len(op.gates) != 1:
            raise LegalityError("baseline crossbar: one gate per cycle")
        return
    # Physical requirement for all partition models: disjoint sections.
    op_intervals(op, cfg)
    if model == "unlimited":
        return
    _check_no_split_input(op, cfg)
    _check_identical_indices(op, cfg)
    _check_uniform_direction(op, cfg)
    if model == "minimal":
        _check_minimal(op, cfg)


def is_legal(op: Operation, cfg: PartitionConfig, model: str) -> bool:
    try:
        validate(op, cfg, model)
        return True
    except LegalityError:
        return False
