"""Active-mesh context and sharding-constraint helpers.

The model stack never receives a mesh argument; it asks this module.  A
``use_mesh`` block pushes ``(mesh, dp_axes, tp_axis)`` onto a thread-local
stack; everything sharding-related (``shard``, ``shard_batch_dim``, the
``partitioning`` factories) resolves against the top of that stack and
degrades to a no-op when it is empty.  See ``repro.dist.__doc__`` for the
axis conventions.
"""
from __future__ import annotations

import contextlib
import inspect
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec

try:  # jax>=0.6 moved shard_map to jax.shard_map
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore

#: jax < 0.6 calls the replication-check knob check_rep; newer jax check_vma.
#: Every shard_map call site in the repo goes through this one shim:
#: ``shard_map(f, ..., **{SM_CHECK_KW: False})``.
SM_CHECK_KW = ("check_vma" if "check_vma"
               in inspect.signature(shard_map).parameters else "check_rep")

__all__ = ["use_mesh", "current_mesh", "mesh_axes", "dp_axes", "tp_axis",
           "shard", "shard_batch_dim", "shard_map", "SM_CHECK_KW"]

TP_AXIS = "model"

# One spec entry: None (replicated), an axis name, or a tuple of axis names.
AxisEntry = Union[None, str, Sequence[str]]


class _Stack(threading.local):
    def __init__(self):
        self.frames = []  # [(mesh, dp_axes: tuple, tp_axis: str | None)]


_stack = _Stack()


def _resolve_axes(mesh, dp_override: Optional[Sequence[str]] = None
                  ) -> Tuple[Tuple[str, ...], Optional[str]]:
    """Split mesh axes into (data-parallel tuple, tensor-parallel axis)."""
    names = tuple(mesh.axis_names)
    if dp_override is None:
        dp = tuple(a for a in names if a != TP_AXIS)
        tp = TP_AXIS if TP_AXIS in names else None
    else:
        unknown = set(dp_override) - set(names)
        if unknown:
            raise ValueError(f"dp_axes {sorted(unknown)} not in mesh axes "
                             f"{names}")
        dp = tuple(a for a in names if a in dp_override)
        tp = TP_AXIS if TP_AXIS in names and TP_AXIS not in dp else None
    return dp, tp


@contextlib.contextmanager
def use_mesh(mesh, *, dp_axes: Optional[Sequence[str]] = None):
    """Make ``mesh`` the active mesh for the enclosed block (re-entrant).

    ``dp_axes`` overrides which axes count as data-parallel; by default all
    axes except ``"model"``.  Passing every axis (the dry-run's ``dp_only``
    policy) leaves ``tp_axis() is None`` and fully replicates weights.

    The active mesh is read at **trace** time and is not part of jax's jit
    cache key: a function jitted and first called under one context will be
    replayed with that context's shardings on later calls.  Create the jit
    wrapper inside the ``use_mesh`` block (as ``launch/dryrun.py`` does),
    one per (mesh, dp_axes) policy.
    """
    frame = (mesh,) + _resolve_axes(mesh, dp_axes)
    _stack.frames.append(frame)
    try:
        with mesh:
            yield mesh
    finally:
        _stack.frames.pop()


def current_mesh():
    """The innermost ``use_mesh`` mesh, or None outside any."""
    return _stack.frames[-1][0] if _stack.frames else None


def mesh_axes(mesh=None) -> Tuple[Tuple[str, ...], Optional[str]]:
    """(dp_axes, tp_axis) for ``mesh`` (default: the active mesh).

    For the active mesh this honours the ``use_mesh(dp_axes=...)`` override;
    for any other mesh it applies the default split.
    """
    if _stack.frames and (mesh is None or mesh is _stack.frames[-1][0]):
        return _stack.frames[-1][1], _stack.frames[-1][2]
    if mesh is None:
        return (), None
    return _resolve_axes(mesh)


def dp_axes() -> Tuple[str, ...]:
    """Data-parallel axis names of the active mesh (``()`` outside one)."""
    return _stack.frames[-1][1] if _stack.frames else ()


def tp_axis() -> Optional[str]:
    """Tensor-parallel axis name of the active mesh, or None."""
    return _stack.frames[-1][2] if _stack.frames else None


def _normalize_entry(mesh, dim_size: int, entry: AxisEntry):
    """Drop axes that are absent or do not divide ``dim_size``."""
    if entry is None:
        return None
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    axes = tuple(a for a in axes if a in mesh.axis_names
                 and mesh.shape[a] > 1)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if not axes or dim_size % size:
        return None
    return axes[0] if len(axes) == 1 else axes


def pspec_for(mesh, shape: Sequence[int], *entries: AxisEntry
              ) -> PartitionSpec:
    """A PartitionSpec for ``shape`` keeping only valid, dividing entries."""
    if len(entries) > len(shape):
        raise ValueError(f"{len(entries)} spec entries for rank-{len(shape)} "
                         f"array")
    return PartitionSpec(*(
        _normalize_entry(mesh, d, e)
        for d, e in zip(shape, tuple(entries) + (None,) * (len(shape)
                                                           - len(entries)))))


def shard(x, *entries: AxisEntry):
    """Constrain ``x``'s sharding (one entry per leading dim; missing
    trailing entries replicate).  No-op outside ``use_mesh``; axes that do
    not divide the dimension are silently dropped, so callers never need
    divisibility checks."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = pspec_for(mesh, x.shape, *entries)
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_batch_dim(x, dim: int = 0):
    """Shard dimension ``dim`` over the data-parallel axes, rest replicated."""
    entries: list = [None] * (dim + 1)
    entries[dim] = dp_axes()
    return shard(x, *entries)
