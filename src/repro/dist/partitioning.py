"""PartitionSpec factories for the dry-run / train / serve entry points.

All factories are **shape-driven**: they walk trees of
``jax.ShapeDtypeStruct`` (or concrete arrays) and assign mesh axes per
leaf, keeping every assignment divisible — a spec produced here always
compiles, on any mesh, at any model size.

Placement rules (see ``repro.dist.__doc__`` for the axis conventions):

* **params** — the largest dim divisible by the ``"model"`` axis is
  tensor-parallel (ties pick the later dim: column-parallel for square
  ``(d, ff)`` weights); with ``fsdp=True`` the largest *remaining* dim
  divisible by ``"data"`` is ZeRO-3 sharded (ties pick the earlier dim).
* **optimizer state** — mirrors the param spec; Adafactor row/col
  statistics inherit the surviving dims of their param's spec.
* **batches** — leading (batch) dim over the data-parallel axes.
* **decode caches** — dim 1 (batch; dim 0 is the stacked-layer axis) over
  the data-parallel axes, and the head dim (-2) of rank>=4 leaves over
  ``"model"``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec

from repro.dist import context as dctx

__all__ = ["param_pspecs", "opt_state_pspecs", "batch_pspecs",
           "cache_pspecs", "tree_shardings", "tp_shard_dim",
           "replica_slices"]

FSDP_AXIS = "data"


def _axis_size(mesh, axis: Optional[str]) -> int:
    return mesh.shape[axis] if axis and axis in mesh.axis_names else 1


def _is_shape_leaf(x) -> bool:
    return hasattr(x, "shape") and hasattr(x, "dtype")


def _pick_dim(shape, divisor: int, taken, *, prefer_late: bool) -> int:
    """Index of the largest dim divisible by ``divisor`` (excluding
    ``taken``), or -1.  Ties resolve to the later/earlier dim."""
    best, best_size = -1, 0
    dims = range(len(shape))
    for i in (reversed(dims) if prefer_late else dims):
        if i in taken or shape[i] % divisor or shape[i] < divisor:
            continue
        if shape[i] > best_size:
            best, best_size = i, shape[i]
    return best


def tp_shard_dim(shape, tp_size: int) -> int:
    """The dim index ``param_pspecs`` puts on the ``"model"`` axis, or -1.

    Largest dim divisible by ``tp_size``; ties resolve to the *later* dim
    (column-parallel for square weights).  The ``quant_tp`` execution mode
    (``repro.kernels.quant_matmul.tp``) keys its shard_map split off the
    same rule, so a weight's tile split always matches the layout
    ``param_pspecs`` gave it — no resharding at dispatch.
    """
    return _pick_dim(shape, tp_size, set(), prefer_late=True)


def _param_spec(shape, mesh, tp_ax: Optional[str], fsdp_ax: Optional[str]
                ) -> PartitionSpec:
    entries = [None] * len(shape)
    taken = set()
    tp_size = _axis_size(mesh, tp_ax)
    if tp_size > 1:
        i = tp_shard_dim(shape, tp_size)
        if i >= 0:
            entries[i] = tp_ax
            taken.add(i)
    fsdp_size = _axis_size(mesh, fsdp_ax)
    if fsdp_size > 1:
        i = _pick_dim(shape, fsdp_size, taken, prefer_late=False)
        if i >= 0:
            entries[i] = fsdp_ax
            taken.add(i)
    return PartitionSpec(*entries)


def param_pspecs(pshapes, mesh, *, fsdp: bool = False, tp: bool = True):
    """PartitionSpec tree for a param tree. ``tp=False`` keeps weights off
    the "model" axis (dp-only policy); ``fsdp=True`` additionally shards
    over "data" (ZeRO-3)."""
    _, tp_ax = dctx.mesh_axes(mesh)
    tp_ax = tp_ax if tp else None
    fsdp_ax = FSDP_AXIS if fsdp else None
    return jax.tree.map(
        lambda s: _param_spec(s.shape, mesh, tp_ax, fsdp_ax),
        pshapes, is_leaf=_is_shape_leaf)


def opt_state_pspecs(pshapes, param_part, opt_state, mesh):
    """Specs for ``optim.adamw`` state: moments mirror their param's spec;
    factored row/col stats keep the spec entries of their surviving dims;
    the step counter replicates."""
    flat_shapes, tdef = jax.tree.flatten(pshapes, is_leaf=_is_shape_leaf)
    flat_specs = tdef.flatten_up_to(param_part)
    flat_state = tdef.flatten_up_to(opt_state["leaves"])

    def leaf(spec: PartitionSpec, st: Dict[str, Any]) -> Dict[str, Any]:
        e = tuple(spec)
        out: Dict[str, Any] = {"m": spec}
        if "v" in st:
            out["v"] = spec
        else:  # Adafactor: vr = shape[:-1], vc = shape[:-2] + shape[-1:]
            out["vr"] = PartitionSpec(*e[:-1])
            out["vc"] = PartitionSpec(*(e[:-2] + e[-1:]))
        return out

    leaves = [leaf(sp, st) for sp, st in zip(flat_specs, flat_state)]
    return {"step": PartitionSpec(),
            "leaves": jax.tree.unflatten(tdef, leaves)}


def batch_pspecs(batch, mesh):
    """Input batches: leading dim over the data-parallel axes (dropped when
    the global batch does not divide), everything else replicated."""
    dp, _ = dctx.mesh_axes(mesh)
    return jax.tree.map(
        lambda s: dctx.pspec_for(mesh, s.shape, dp),
        batch, is_leaf=_is_shape_leaf)


def cache_pspecs(caches, mesh, *, batch_over_dp: bool = True):
    """Decode caches ``(n_super, batch, ...)``: batch dim over DP axes, the
    head dim (-2) of rank>=4 leaves over the "model" axis.

    ``batch_over_dp=False`` keeps the batch (slot) dim replicated while
    heads still ride "model" — the serving cache pool's placement:
    continuous batching scatters arbitrary slots on admit/evict, and a
    DP-sharded slot dim would turn every single-slot update into
    cross-device traffic.

    Block-paged pools (``serving.PagedCachePool``) reuse the same factory:
    their attention leaves are ``(n_super, num_blocks, block, heads, hd)``,
    so dim 1 is the *block* dim — it must stay replicated for the same
    reason slots do (any slot touches any block), hence paged pools always
    pass ``batch_over_dp=False``; heads still shard over "model".  The
    block *table* itself is a tiny replicated int32 array and never gets a
    spec here.

    Quantized-KV *scale* leaves (``k_scale``/``v_scale``) are the KV leaf
    minus its trailing head-dim axis — ``(n_super, batch, cap, heads)`` —
    so their head dim is *last*, not ``-2``: they get ``tp`` on ``-1`` to
    stay aligned with the ``(…, heads, hd)`` values they rescale (putting
    ``tp`` on ``-2`` would shard the *sequence* dim of the scales against
    the head-sharded values and force a gather per decode step).
    """
    dp, tp_ax = dctx.mesh_axes(mesh)

    def leaf(path, s):
        nd = len(s.shape)
        entries = [None] * nd
        if nd >= 2 and batch_over_dp:
            entries[1] = dp
        if nd >= 4 and tp_ax:
            name = str(getattr(path[-1], "key", path[-1])) if path else ""
            entries[-1 if name.endswith("_scale") else -2] = tp_ax
        return dctx.pspec_for(mesh, s.shape, *entries)

    return jax.tree_util.tree_map_with_path(leaf, caches,
                                            is_leaf=_is_shape_leaf)


def replica_slices(n_replicas: int, devices=None):
    """Disjoint contiguous device slices for a data-parallel replica fleet.

    The serving router gives each replica its own slice (its own mesh, KV
    pool, prefix trie); contiguity keeps each replica's model-parallel
    collectives on neighbouring devices, matching how
    ``ElasticMesh.make`` reshapes a device list.  ``n_replicas`` must
    divide the device count — a ragged fleet would hand replicas unequal
    capacity and poison the scaling benchmark.
    """
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    if n_replicas < 1 or n % n_replicas:
        raise ValueError(
            f"{n_replicas} replicas cannot evenly split {n} devices")
    per = n // n_replicas
    return [devices[i * per:(i + 1) * per] for i in range(n_replicas)]


def tree_shardings(spec_tree, mesh):
    """PartitionSpec tree -> NamedSharding tree on ``mesh``."""
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p), spec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec))
