"""``repro.dist`` — mesh context and partitioning for the LM stack.

Mesh / axis conventions (MaxText-style logical axes, reduced to the two
parallelism kinds this repo uses):

* Axis **names** are fixed: ``"pod"`` (outermost data parallelism across
  pods), ``"data"`` (within-pod data parallelism, doubles as the FSDP /
  ZeRO-3 weight-sharding axis), and ``"model"`` (tensor / expert
  parallelism).  Meshes may carry any subset — ``("data", "model")`` for a
  single pod, ``("pod", "data", "model")`` for multi-pod, ``("data",)`` for
  pure DP.
* **Data-parallel axes** (``context.dp_axes()``) are, by default, every mesh
  axis except ``"model"``; batch-like dimensions shard over them.
  ``use_mesh(mesh, dp_axes=...)`` overrides the split (the dry-run's
  ``dp_only`` policy passes all axes, leaving no tensor axis).
* The **tensor-parallel axis** (``context.tp_axis()``) is ``"model"`` when
  present and not claimed as data-parallel, else ``None``.  Heads, hidden
  (``d_ff``), vocab, and expert dimensions shard over it.

``context`` carries the active mesh in a thread-local stack so model code
can stay mesh-agnostic: ``shard``/``shard_batch_dim`` are exact no-ops
without a mesh and ``jax.lax.with_sharding_constraint`` inside one, and
every constraint silently drops axes that do not divide the dimension —
the same code runs on 1 CPU device and on a 512-chip mesh.

``partitioning`` turns trees of ``jax.ShapeDtypeStruct`` into trees of
``PartitionSpec`` / ``NamedSharding`` for params (with an ``fsdp`` knob),
optimizer state (factored-moment aware), input batches, and decode caches.
"""
from repro.dist import context, partitioning

__all__ = ["context", "partitioning"]
