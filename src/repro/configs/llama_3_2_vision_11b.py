"""llama-3.2-11B-vision [hf:meta-llama, unverified]: cross-attn image layers
every 5th layer; vision tower STUBBED — input_specs() supplies precomputed
patch embeddings at vision_dim=1280 (DESIGN.md §3)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    pattern=("ad", "ad", "ad", "adx", "ad"), activation="silu",
    vision_dim=1280, n_patches=1601,
    rope_theta=500_000.0,
    tie_embeddings=False,
)
