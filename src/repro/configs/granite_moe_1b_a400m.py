"""granite-3.0-1b-a400m [hf:ibm-granite]: 32 experts top-8, GQA kv=8."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab_size=49155,
    pattern=("ae",), activation="silu",
    n_experts=32, top_k=8, moe_d_ff=512,
    tie_embeddings=True,
)
