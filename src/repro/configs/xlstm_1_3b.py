"""xLSTM-1.3b [arXiv:2405.04517, unverified]: mLSTM/sLSTM 7:1, 4 heads,
no separate FFN (d_ff=0; blocks carry pf=2 up/down projections)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    pattern=("xs", "xm", "xm", "xm", "xm", "xm", "xm", "xm"),
    activation="gelu", xlstm_proj_factor=2.0,
    tie_embeddings=True,
)
