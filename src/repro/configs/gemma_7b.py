"""gemma-7b [arXiv:2403.08295]: GeGLU, head_dim=256, large vocab."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
    d_ff=24576, vocab_size=256000, head_dim=256,
    pattern=("ad",), activation="gelu",
    tie_embeddings=True,
)
