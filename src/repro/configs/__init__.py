"""Assigned-architecture registry: one module per config, ``get(name)`` API."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES = {
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "granite-20b": "granite_20b",
    "gemma-7b": "gemma_7b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "arctic-480b": "arctic_480b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "xlstm-1.3b": "xlstm_1_3b",
}

ARCH_NAMES: List[str] = list(_MODULES)


def get(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get(n) for n in ARCH_NAMES}
