"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B]: QKV bias, 151936 vocab."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=2816, vocab_size=151936,
    pattern=("ad",), activation="silu", qkv_bias=True,
    tie_embeddings=True,
)
