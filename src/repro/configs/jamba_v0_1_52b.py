"""jamba-v0.1 [arXiv:2403.19887]: Mamba+attention 1:7, MoE 16e top-2 every
other layer. Period-8 super-block: attention at offset 4, MoE at odd offsets."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    pattern=("md", "me", "md", "me", "ad", "me", "md", "me"),
    activation="silu",
    n_experts=16, top_k=2, moe_d_ff=14336,
    mamba_d_state=16, mamba_expand=2, mamba_d_conv=4,
    tie_embeddings=False,
)
