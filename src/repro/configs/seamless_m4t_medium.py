"""seamless-m4t-medium [arXiv:2308.11596]: enc-dec; speech frontend STUBBED —
input_specs() supplies precomputed frame embeddings (DESIGN.md §3).
12 encoder + 12 decoder layers at d_model=1024 ("medium" text stack)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    pattern=("adx",), activation="relu",
    n_encoder_layers=12, audio_frames_div=4,
    tie_embeddings=True,
)
