"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The ONLY entry point that forces 512 host devices — the env var must be set
before jax initializes, hence the first two lines.  Each invocation handles
one cell (isolates compiler failures); ``--all`` re-invokes itself per cell
and aggregates the JSON results under ``results/dryrun/``.

Per cell it records: per-device HLO FLOPs / bytes-accessed (cost_analysis),
memory footprint (memory_analysis), and the collective mix parsed from the
compiled HLO (op counts + modeled wire bytes) — the inputs to §Roofline.
"""
import os

from repro.xla_flags import ensure_host_device_count

# Respect an existing device-count override (the test suite forces 8 via
# conftest.py before jax initializes); only the standalone CLI wants 512.
ensure_host_device_count(512)

import argparse
import json
import re
import subprocess
import sys
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.dist import context as dctx
from repro.dist import partitioning as part
from repro.launch.mesh import make_production_mesh
from repro.models import model_lib as M
from repro.models.config import SHAPES, ModelConfig, ShapeSpec
from repro.models.layers import as_shapes
from repro.optim.adamw import AdamWConfig, apply_updates, init_state

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(?P<ty>\([^)]*\)|[a-z0-9]+\[[^\]]*\]\S*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_GROUPS_1D_RE = re.compile(r"replica_groups=\[(\d+)\]<=\[")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _type_bytes(ty: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(ty):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# Link bandwidths for the modeled wire time: intra-pod ICI vs the much
# thinner pod-boundary (DCN) links the 2x16x16 pass exercises.
INTRA_POD_GBPS = 100.0
INTER_POD_GBPS = 25.0


def parse_collectives(hlo_text: str, pod_size: Optional[int] = None
                      ) -> Dict[str, Dict[str, float]]:
    """Per collective kind: instruction count + modeled per-device wire bytes
    (ring algorithms: AG/RS/A2A move size*(g-1)/g, AR moves 2x that,
    permute moves its full payload once).

    With ``pod_size`` set, replica groups larger than one pod additionally
    report ``cross_pod_bytes``: a ring over ``g`` contiguous devices
    spanning ``p = ceil(g / pod_size)`` pods crosses a pod boundary on
    ``p`` of its ``g`` hops, so that fraction of each device's wire bytes
    rides the inter-pod links (the bandwidth term
    :func:`collective_time_s` charges at ``INTER_POD_GBPS``).
    """
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _type_bytes(m.group("ty"))
        g = 1
        gm = _GROUPS_RE.search(line)
        g1 = _GROUPS_1D_RE.search(line)
        gl = _GROUPS_LIST_RE.search(line)
        if gm:
            g = int(gm.group(2))
        elif g1:
            g = int(g1.group(1))
        elif gl:
            g = len(gl.group(1).split(","))
        if g <= 1:
            wire = 0.0
        elif op == "all-reduce":
            wire = 2.0 * nbytes * (g - 1) / g
        elif op == "all-gather":
            wire = nbytes * (g - 1) / g
        elif op == "reduce-scatter":
            wire = nbytes * (g - 1)  # result is the scattered shard
        elif op == "all-to-all":
            wire = nbytes * (g - 1) / g
        else:  # collective-permute
            wire = float(nbytes)
        cross = 0.0
        if pod_size and g > pod_size:
            spans = (g + pod_size - 1) // pod_size
            cross = wire * spans / g
        d = out.setdefault(op, {"count": 0, "wire_bytes": 0.0,
                                "cross_pod_bytes": 0.0})
        d["count"] += 1
        d["wire_bytes"] += wire
        d["cross_pod_bytes"] += cross
    return out


def collective_time_s(colls: Dict[str, Dict[str, float]], *,
                      intra_gbps: float = INTRA_POD_GBPS,
                      inter_gbps: float = INTER_POD_GBPS) -> float:
    """Modeled per-device wire time: intra-pod bytes at ICI bandwidth plus
    the pod-boundary fraction serialized on the inter-pod links."""
    t = 0.0
    for c in colls.values():
        cross = c.get("cross_pod_bytes", 0.0)
        t += ((c["wire_bytes"] - cross) / (intra_gbps * 1e9)
              + cross / (inter_gbps * 1e9))
    return t


# --------------------------------------------------------------------------
# per-cell lowering
# --------------------------------------------------------------------------

def _opt_cfg(cfg: ModelConfig) -> AdamWConfig:
    big = M.param_count(cfg) > 10e9
    return AdamWConfig(factored=big,
                       moment_dtype="bfloat16" if big else "float32")


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    sp: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind in ("train", "prefill"):
        sp["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if shape.kind == "train":
            sp["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.is_encoder_decoder:
            sp["frames"] = jax.ShapeDtypeStruct(
                (b, s // cfg.audio_frames_div, cfg.d_model), cfg.compute_dtype)
        if cfg.vision_dim:
            sp["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.vision_dim), cfg.compute_dtype)
    return sp


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
               unroll: bool = True, policy: str = "auto") -> Tuple:
    """Build (fn, example_args, in_shardings) for the cell kind.

    ``unroll=True`` is the cost-accounting lowering: XLA's cost analysis
    counts while-loop bodies once, so the roofline FLOP/collective numbers
    come from an unrolled stack.  ``unroll=False`` is the deployable scan
    lowering whose memory_analysis reflects real execution.
    """
    tokens = shape.global_batch * shape.seq_len
    if unroll:
        cfg = cfg.scaled(scan_layers=False, flash_attention=False,
                         loss_chunk=max(tokens // 8, min(8192, tokens)))
    if policy == "dp_only" and cfg.n_experts:
        raise ValueError("dp_only policy incompatible with expert parallelism")
    pspecs = M.param_specs(cfg)
    pshapes = as_shapes(pspecs)
    fsdp = M.param_count(cfg) > 3e9
    p_part = part.param_pspecs(pshapes, mesh, fsdp=fsdp,
                               tp=policy != "dp_only")
    p_shard = part.tree_shardings(p_part, mesh)

    if shape.kind == "train":
        ocfg = _opt_cfg(cfg)
        ostate = jax.eval_shape(lambda: init_state(ocfg, pshapes))
        o_part = part.opt_state_pspecs(pshapes, p_part, ostate, mesh)
        o_shard = part.tree_shardings(o_part, mesh)
        batch = input_specs(cfg, shape)
        b_shard = part.tree_shardings(part.batch_pspecs(batch, mesh), mesh)
        # Gradient-accumulation microbatching bounds activation memory in the
        # deployable (scan) lowering; the cost lowering keeps one full batch
        # (identical FLOPs, and scanning would hide them from cost analysis).
        n_micro = 1 if unroll else 4

        def train_step(params, opt_state, batch):
            if n_micro == 1:
                loss, grads = jax.value_and_grad(
                    lambda p: M.loss_fn(p, batch, cfg))(params)
            else:
                mb = jax.tree.map(
                    lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                        + x.shape[1:]), batch)

                def body(acc, one):
                    l, g = jax.value_and_grad(
                        lambda p: M.loss_fn(p, one, cfg))(params)
                    return (acc[0] + l,
                            jax.tree.map(jnp.add, acc[1], g)), None

                zero = (jnp.zeros((), jnp.float32),
                        jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                     params))
                (loss, grads), _ = jax.lax.scan(body, zero, mb)
                loss = loss / n_micro
                grads = jax.tree.map(lambda g: g / n_micro, grads)
            params, opt_state, metrics = apply_updates(
                ocfg, params, grads, opt_state)
            return params, opt_state, loss, metrics["grad_norm"]

        fn = jax.jit(train_step,
                     in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, None, None),
                     donate_argnums=(0, 1))
        return fn, (pshapes, ostate, batch)

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        b_shard = part.tree_shardings(part.batch_pspecs(batch, mesh), mesh)

        def prefill_step(params, batch):
            return M.prefill(params, batch, cfg)

        fn = jax.jit(prefill_step, in_shardings=(p_shard, b_shard))
        return fn, (pshapes, batch)

    # decode
    caches = M.cache_specs(cfg, shape.global_batch, shape.seq_len)
    c_part = part.cache_pspecs(caches, mesh)
    c_shard = part.tree_shardings(c_part, mesh)
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    tok_spec = jax.sharding.PartitionSpec(
        dp if shape.global_batch % dp_size == 0 else None, None)
    tok_shard = jax.sharding.NamedSharding(mesh, tok_spec)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, token, pos, caches):
        return M.decode_step(params, token, pos, caches, cfg)

    fn = jax.jit(serve_step,
                 in_shardings=(p_shard, tok_shard, None, c_shard),
                 out_shardings=(None, None, c_shard),
                 donate_argnums=(3,))
    return fn, (pshapes, tok, pos, caches)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             policy: str = "auto", kv_dtype: str = "bf16",
             mem_only: bool = False) -> Dict:
    cfg = configs.get(arch)
    if kv_dtype != "bf16":
        cfg = cfg.scaled(kv_cache_dtype=kv_dtype)
    if os.environ.get("REPRO_MOE_GATHER"):
        cfg = cfg.scaled(moe_fsdp_gather=True)
    shape = next(s for s in SHAPES if s.name == shape_name)
    ok, why = cfg.runnable(shape)
    result: Dict = {"arch": arch, "shape": shape_name,
                    "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        result.update(status="skipped", reason=why)
        return result
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp_axes = ("pod", "data", "model") if policy == "dp_only" else None
    t0 = time.time()
    try:
        with dctx.use_mesh(mesh, dp_axes=dp_axes):
            # 1) deployable scan lowering: memory analysis.  The multi-pod
            # pass stops here — it proves the "pod" axis shards; the roofline
            # accounting (single-pod only, per the brief) needs pass 2.
            fn_s, args_s = lower_cell(cfg, shape, mesh, unroll=False,
                                      policy=policy)
            compiled_s = fn_s.lower(*args_s).compile()
            ma = compiled_s.memory_analysis()
            t1 = time.time()
            result.update(
                status="ok",
                scan_compile_s=round(t1 - t0, 1),
                mem=dict(
                    argument_bytes=int(ma.argument_size_in_bytes),
                    output_bytes=int(ma.output_size_in_bytes),
                    temp_bytes=int(ma.temp_size_in_bytes),
                    code_bytes=int(ma.generated_code_size_in_bytes),
                ),
                n_devices=mesh.size,
                params=M.param_count(cfg),
            )
            if multi_pod:
                # the multi-pod pass proves the "pod" axis shards AND prices
                # its boundary: groups spanning pods pay the inter-pod
                # bandwidth term on their cross-pod byte fraction
                pod_size = mesh.size // mesh.shape["pod"]
                colls = parse_collectives(compiled_s.as_text(),
                                          pod_size=pod_size)
                result.update(
                    collectives=colls,
                    wire_bytes_per_dev=sum(c["wire_bytes"]
                                           for c in colls.values()),
                    cross_pod_bytes_per_dev=sum(c["cross_pod_bytes"]
                                                for c in colls.values()),
                    wire_time_s=collective_time_s(colls),
                )
                return result
            if mem_only:
                return result
            # 2) unrolled lowering: FLOP / byte / collective accounting
            fn, args = lower_cell(cfg, shape, mesh, unroll=True,
                                  policy=policy)
            compiled = fn.lower(*args).compile()
            t2 = time.time()
            ca = compiled.cost_analysis() or {}
            colls = parse_collectives(compiled.as_text())
        result.update(
            compile_s=round(t2 - t1, 1),
            flops_per_dev=float(ca.get("flops", 0.0)),
            bytes_per_dev=float(ca.get("bytes accessed", 0.0)),
            collectives=colls,
            wire_bytes_per_dev=sum(c["wire_bytes"] for c in colls.values()),
        )
    except Exception as e:  # noqa: BLE001 — recorded, surfaced by --all
        result.update(status="error", error=f"{type(e).__name__}: {e}"[:2000])
    return result


def _result_path(out_dir, arch, shape, multi_pod):
    mesh = "multi" if multi_pod else "single"
    safe = arch.replace("/", "_")
    return os.path.join(out_dir, f"{safe}__{shape}__{mesh}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--policy", default="auto",
                    choices=["auto", "dp_only"])
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=["bf16", "int8"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--mem-only", action="store_true",
                    help="refresh only the scan-lowering memory analysis, "
                         "merging into an existing result JSON")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        failures = 0
        for arch in configs.ARCH_NAMES:
            for shape in SHAPES:
                for mp in (False, True):
                    path = _result_path(args.out, arch, shape.name, mp)
                    if os.path.exists(path) and not args.force:
                        r = json.load(open(path))
                    else:
                        cmd = [sys.executable, "-m", "repro.launch.dryrun",
                               "--arch", arch, "--shape", shape.name,
                               "--out", args.out]
                        if mp:
                            cmd.append("--multi-pod")
                        try:
                            subprocess.run(cmd, check=False,
                                           timeout=args.timeout)
                        except subprocess.TimeoutExpired:
                            json.dump({"arch": arch, "shape": shape.name,
                                       "mesh": "2x16x16" if mp else "16x16",
                                       "status": "error",
                                       "error": "compile timeout"},
                                      open(path, "w"))
                        r = json.load(open(path)) if os.path.exists(path) \
                            else {"status": "error", "error": "no output"}
                    tag = r.get("status")
                    if tag == "error":
                        failures += 1
                    if tag == "ok":
                        info = (f"  flops/dev={r.get('flops_per_dev', 0):.3g} "
                                f"wire/dev={r.get('wire_bytes_per_dev', 0):.3g}B"
                                if not mp else
                                f"  temp/dev={r['mem']['temp_bytes']/1e9:.1f}GB")
                    else:
                        info = f"  ({r.get('reason', r.get('error', ''))[:70]})"
                    print(f"{arch:24s} {shape.name:12s} "
                          f"{'multi' if mp else 'single':6s} {tag}{info}",
                          flush=True)
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    r = run_cell(args.arch, args.shape, args.multi_pod,
                 policy=args.policy, kv_dtype=args.kv_dtype,
                 mem_only=args.mem_only)
    path = _result_path(args.out, args.arch, args.shape, args.multi_pod)
    if args.tag:
        path = path.replace(".json", f"__{args.tag}.json")
    if args.mem_only and os.path.exists(path):
        old = json.load(open(path))
        old.update({k: v for k, v in r.items()
                    if k in ("mem", "scan_compile_s", "status")})
        r = old
    with open(path, "w") as f:
        json.dump(r, f, indent=1)
    print(json.dumps({k: v for k, v in r.items() if k != "collectives"},
                     indent=1))
    return 0 if r["status"] != "error" else 1


if __name__ == "__main__":
    sys.exit(main())
