"""End-to-end trainer: data -> jit'd train step -> checkpoint/resume.

Runs any registered arch at any scale (``--smoke`` for the reduced config,
``--preset 100m`` etc. for CPU-trainable sizes).  Fault tolerance: periodic
atomic checkpoints, auto-resume (``--resume``), stateless data indexing so
the token stream continues exactly where the failed run left off;
``--fail-at-step`` injects a crash for the restart test.  On multi-device
runs the mesh comes from ``ElasticMesh`` (degrades gracefully to whatever
devices are alive); single-device runs skip mesh machinery entirely.
"""
from __future__ import annotations

import argparse
import contextlib
import functools
import json
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.data.pipeline import AudioStub, SyntheticLM, VisionStub
from repro.dist import context as dctx
from repro.dist import partitioning as dpart
from repro.models import model_lib as M
from repro.models.layers import as_shapes
from repro.optim.adamw import AdamWConfig, apply_updates, init_state
from repro.pim import engine
from repro.runtime.fault_tolerance import (CheckpointManager, ElasticMesh,
                                           StragglerMonitor)

PRESETS = {
    # (d_model, n_layers_mult, heads, kv, d_ff) scaled same-family configs
    "tiny": dict(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                 vocab_size=512, pad_vocab_multiple=8),
    "20m": dict(d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                vocab_size=4096, pad_vocab_multiple=64),
    "100m": dict(d_model=640, n_heads=10, n_kv_heads=5, d_ff=2560,
                 vocab_size=8192, pad_vocab_multiple=64),
}


def build_cfg(args):
    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.preset:
        kw = dict(PRESETS[args.preset])
        kw.update(n_layers=max(len(cfg.pattern), args.layers or 4),
                  dtype="float32", remat=False, loss_chunk=1 << 30)
        if cfg.n_experts:
            kw.update(n_experts=8, top_k=2, moe_d_ff=kw["d_ff"] // 4)
        if cfg.n_encoder_layers:
            kw.update(n_encoder_layers=2)
        if cfg.vision_dim:
            kw.update(vision_dim=64, n_patches=16)
        if cfg.family == "ssm":
            kw.update(n_kv_heads=kw["n_heads"])
        cfg = cfg.scaled(**kw)
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=list(PRESETS), default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="tensor-parallel degree on multi-device runs "
                         "(degraded automatically if devices don't divide)")
    ap.add_argument("--pim-mode", choices=list(engine.MODES), default=None,
                    help="repro.pim.engine lowering for every linear "
                         "(threaded through ModelConfig.pim_mode); quant_tp "
                         "shards int8 tiles over the 'model' axis and "
                         "trains via its straight-through custom_vjp")
    ap.add_argument("--autotune", action="store_true",
                    help="before training, print the partition autotuner's "
                         "cost-model report for every linear shape in the "
                         "model (picked configuration vs the engine "
                         "default; no timed trials)")
    args = ap.parse_args()

    # Single-device runs skip mesh machinery entirely; multi-device runs get
    # the largest valid (pod, data, model) mesh from whatever is alive.
    mesh = None
    mesh_ctx = contextlib.nullcontext()
    if jax.device_count() > 1:
        mesh = ElasticMesh(model_parallel=args.model_parallel).make()
        print(f"[mesh] {dict(mesh.shape)} over {mesh.size} devices")
        mesh_ctx = dctx.use_mesh(mesh)

    cfg = build_cfg(args)
    if args.pim_mode:
        cfg = cfg.scaled(pim_mode=args.pim_mode)
    if args.autotune:
        # cost-model report: for every distinct linear shape in the model,
        # what configuration would the partition autotuner pick, and what
        # does the cost model predict it buys over the engine default?
        # Pure prediction (trials=0) — nothing here touches the simulator.
        from repro.pim import autotune

        mode = cfg.pim_mode or "raw"
        tokens = args.batch * args.seq
        shapes = sorted({tuple(map(int, s.shape)) for s in
                         jax.tree_util.tree_leaves(
                             as_shapes(M.param_specs(cfg)))
                         if len(s.shape) == 2})
        print(f"[autotune] cost-model report, {len(shapes)} linear "
              f"shape(s) at {tokens} tokens ({mode}):")
        for k_dim, o in shapes:
            plan = autotune.autotune(k_dim, 8, (tokens, o), mode, trials=0)
            dflt = autotune.default_plan(k_dim, 8, (tokens, o), mode)
            gain = dflt.predicted_us / max(plan.predicted_us, 1e-9)
            print(f"[autotune]   K={k_dim:5d} O={o:5d} -> "
                  f"model={plan.model} n_cols={plan.n_cols} "
                  f"chunk={plan.chunk} ({gain:.2f}x vs default predicted)")
    ocfg = AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 20, 5),
                       total_steps=args.steps)
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    audio = AudioStub(cfg.d_model, args.seq // cfg.audio_frames_div) \
        if cfg.is_encoder_decoder else None
    vision = VisionStub(cfg.vision_dim, cfg.n_patches) if cfg.vision_dim \
        else None

    if mesh is not None:
        # ZeRO-3 init: jit the initializers under fsdp=True out-shardings so
        # parameters and optimizer state materialize directly onto their
        # shards — host/device memory is bounded by the *sharded* model size,
        # never the replicated one.
        pshapes = as_shapes(M.param_specs(cfg))
        p_part = dpart.param_pspecs(pshapes, mesh, fsdp=True)
        p_shard = dpart.tree_shardings(p_part, mesh)
        params = jax.jit(lambda k: M.init_params(cfg, k),
                         out_shardings=p_shard)(jax.random.PRNGKey(args.seed))
        o_part = dpart.opt_state_pspecs(
            pshapes, p_part, jax.eval_shape(lambda: init_state(ocfg, pshapes)),
            mesh)
        o_shard = dpart.tree_shardings(o_part, mesh)
        opt_state = jax.jit(lambda p: init_state(ocfg, p),
                            out_shardings=o_shard)(params)
    else:
        params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
        opt_state = init_state(ocfg, params)
    start_step = 0
    manager = None
    if args.ckpt_dir:
        manager = CheckpointManager(args.ckpt_dir, every_steps=args.ckpt_every)
        if args.resume:
            step, tree, meta = manager.resume({"p": params, "o": opt_state})
            if step is not None:
                params, opt_state = tree["p"], tree["o"]
                start_step = step
                print(f"[resume] restored step {step}")

    # donate params/opt_state through apply_updates: the updated trees alias
    # the old buffers, so a ZeRO-3 run never holds two copies of the state
    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, batch, cfg))(params)
        params, opt_state, metrics = apply_updates(ocfg, params, grads,
                                                   opt_state)
        return params, opt_state, loss, metrics

    monitor = StragglerMonitor()
    losses = []
    metrics_f = open(args.metrics_out, "a") if args.metrics_out else None
    # The active mesh is read at trace time, so the whole stepping loop sits
    # inside the context; the in-model sharding constraints do the rest.
    with mesh_ctx:
        for step in range(start_step, args.steps):
            if args.fail_at_step is not None and step == args.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
            if audio:
                batch["frames"] = jnp.asarray(audio.batch_at(step, args.batch))
            if vision:
                batch["patches"] = jnp.asarray(
                    vision.batch_at(step, args.batch))
            params, opt_state, loss, metrics = train_step(params, opt_state,
                                                          batch)
            loss = float(loss)
            losses.append(loss)
            dt = time.time() - t0
            slow = monitor.record(dt)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} {dt*1e3:.0f}ms"
                      + (" [straggler]" if slow else ""))
            if metrics_f:
                metrics_f.write(json.dumps({"step": step, "loss": loss,
                                            "dt_s": dt}) + "\n")
            if manager:
                manager.maybe_save(step + 1, {"p": params, "o": opt_state},
                                   metadata={"arch": cfg.name,
                                             "seq": args.seq,
                                             "batch": args.batch})
    if manager:
        manager.save(args.steps, {"p": params, "o": opt_state},
                     metadata={"arch": cfg.name, "final": True})
    if metrics_f:
        metrics_f.close()
    if losses:
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    else:  # --resume on an already-finished run: nothing left to step
        print(f"nothing to do: resumed at step {start_step} of {args.steps}")
    return losses


if __name__ == "__main__":
    main()
