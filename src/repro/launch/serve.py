"""Batched serving driver: prefill a prompt batch, decode greedily.

CPU-runnable with ``--smoke``/``--preset``.  On multi-device runs the
driver enters the ``ElasticMesh`` (same policy as ``launch/train.py``),
batches requests over the "data" axis, and keeps the decode caches sharded
with ``dist.cache_pspecs`` — batch over the data-parallel axes, attention
heads over "model" — so steady-state decode never gathers the caches to
one device.  ``--pim-mode`` threads a ``repro.pim.engine`` lowering mode
through the config (e.g. ``quant`` for the int8 Pallas path).
"""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import context as dctx
from repro.dist import partitioning as dpart
from repro.launch.train import PRESETS, build_cfg
from repro.models import model_lib as M
from repro.runtime.fault_tolerance import ElasticMesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=list(PRESETS), default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pim-mode", choices=["xla", "quant", "pim_sim"],
                    default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    mesh = None
    mesh_ctx = contextlib.nullcontext()
    if jax.device_count() > 1:
        mesh = ElasticMesh(model_parallel=args.model_parallel).make()
        print(f"[mesh] {dict(mesh.shape)} over {mesh.size} devices")
        mesh_ctx = dctx.use_mesh(mesh)

    cfg = build_cfg(args)
    if args.pim_mode:
        cfg = cfg.scaled(pim_mode=args.pim_mode)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(rng.normal(size=(
            args.batch, args.prompt_len // cfg.audio_frames_div,
            cfg.d_model)), jnp.float32)
    if cfg.vision_dim:
        batch["patches"] = jnp.asarray(rng.normal(size=(
            args.batch, cfg.n_patches, cfg.vision_dim)), jnp.float32)

    with mesh_ctx:
        if mesh is not None:
            # requests ride the "data" axis; the in-model constraints keep
            # activations there through the stack
            batch = jax.device_put(batch, dpart.tree_shardings(
                dpart.batch_pspecs(batch, mesh), mesh))
        prefill = jax.jit(lambda p, b: M.prefill(p, b, cfg))
        decode = jax.jit(lambda p, t, pos, c: M.decode_step(p, t, pos, c,
                                                            cfg))

        t0 = time.time()
        logits, caches = prefill(params, batch)
        logits.block_until_ready()
        t_prefill = time.time() - t0
        if mesh is not None:
            # pin the decode caches (batch over DP axes, heads over
            # "model") so every decode step reads/writes them in place
            caches = jax.device_put(caches, dpart.tree_shardings(
                dpart.cache_pspecs(caches, mesh), mesh))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

        generated = [np.asarray(tok)]
        t0 = time.time()
        for i in range(args.gen - 1):
            tok, _, caches = decode(params, tok,
                                    jnp.int32(args.prompt_len + i), caches)
            generated.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    out = np.concatenate(generated, axis=1)
    toks_per_s = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.0f}ms "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")
    print(f"decode:  {args.gen - 1} steps in {t_decode*1e3:.0f}ms "
          f"({toks_per_s:.0f} tok/s)")
    print("sample generation:", out[0, :16].tolist())
    return out


if __name__ == "__main__":
    main()
