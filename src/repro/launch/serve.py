"""Serving driver: a thin CLI over the ``repro.serving`` runtime.

Replays a synthetic (Poisson-arrival) request trace through the
continuous-batching scheduler: a fixed ``--batch``-slot decode batch whose
finished slots are backfilled from the FIFO admission queue, prefill on
admit (bucketed prompts), a persistent slot-indexed KV-cache pool, and one
jitted decode step that never recompiles as requests churn.  Prints
per-request TTFT/TPOT and aggregate tokens/sec; ``--sequential`` runs the
same trace one-request-at-a-time (a max_batch=1 scheduler) for an A/B
throughput comparison.  ``--paged`` swaps in the block-paged KV pool
(``--block-size`` / ``--num-blocks``): long-tail prompts reserve only
their own block need instead of worst-case slots, and sliding-window
architectures — which page unconditionally — serve as rings over their
block lists.  ``--prefix-cache`` (implies paged) attaches the trie prefix
index with copy-on-write sharing — pair it with ``--shared-prefix N`` to
give every synthetic prompt one N-token system prompt and watch warm
admits skip its prefill entirely.  ``--prefill-chunk N`` +
``--step-token-budget B`` interleave long-prompt prefill with decode
steps (chunked prefill: no step runs more than ``B`` prefill tokens, so
decode TPOT jitter stays bounded under long-prompt bursts), and
``--packed-prefill`` batches short queued prompts into one segment-masked
prefill call; the ``[chunked]`` line echoes p99 TPOT and chunk/pack
counters, and generations stay bit-identical to whole prefill.
``--speculative`` turns on self-speculative decoding: ``--draft-mode``
(default ``quant``) drafts ``--draft-k - 1`` tokens per round and the
serving mode verifies the whole run in one batched step; greedy
acceptance keeps generations bit-identical to plain decode in every
mode, and the ``[spec]`` line echoes acceptance counters.

**Multi-replica router** (``--replicas N``): instead of one scheduler,
``N`` independent engines — each its own device slice, mesh, KV pool,
and prefix trie — behind one ``serving.Router`` that owns the global
admission queue (``--queue-policy fifo|sjf``) and dispatches per request
with ``--router-policy``: ``round_robin``, ``least_loaded`` (fewest
queued+active, most free KV blocks), or ``prefix_affinity`` (leading
block-run hash pins repeat/system prefixes to the replica whose trie
holds them).  ``--kill-replica R:S`` injects a failure — replica ``R``
dies after router step ``S``, its in-flight requests drain back to the
front of the global queue (original arrival kept, ``n_migrations``
bumped) and it respawns over its surviving devices; migrated requests
restart from their prompt, so greedy outputs are bit-identical to an
undisturbed run.  Throughput is reported on the fleet clock (a round
costs its slowest replica — see ``serving.router``); the ``[router]``
line echoes the policy, per-replica tok/s, rebalanced requests, and
restarts.

CPU-runnable with ``--smoke``/``--preset``.  On multi-device runs the
driver enters the ``ElasticMesh`` (same policy as ``launch/train.py``);
the cache pool keeps its slot dim replicated while attention heads shard
over "model" (``dist.cache_pspecs(batch_over_dp=False)``), so admits stay
single-slot writes and steady-state decode never gathers the caches.
``--pim-mode`` threads a ``repro.pim.engine`` lowering mode through the
config (e.g. ``pim_sim`` decodes on the bit-accurate crossbar simulator,
whose persistent ``ExecutionSession`` uploads crossbar state once per
artifact and streams only operand columns per token; ``quant_tp`` decodes
on per-rank int8 Pallas tiles shard_mapped over the mesh "model" axis —
pair it with ``--model-parallel``).  ``--autotune`` switches the
``repro.pim.autotune`` planner on: under ``pim_sim`` the scheduler's
warmup plans every linear shape at the decode batch bucket (partition
model x crossbar geometry x chunking x backend, cost-model-scored, timed
tie-break) and decode runs the picks; ``--autotune-table PATH`` persists
the picks as JSON (format documented in ``benchmarks/check.py``) so the
next run reloads them instead of re-searching.  The ``[autotune]`` line
echoes table size, hit/miss/trial counters, and an example pick.
"""
from __future__ import annotations

import argparse
import contextlib
import os
import time

import jax

from repro.dist import context as dctx
from repro.launch.train import PRESETS, build_cfg
from repro.models import model_lib as M
from repro.pim import autotune, engine
from repro.runtime.fault_tolerance import ElasticMesh
from repro.serving import (FailurePlan, Router, RouterConfig, Scheduler,
                           ServingConfig, synthetic_requests)
from repro.serving.router import ROUTER_POLICIES


def serve_trace(params, cfg, requests, *, max_batch: int, prompt_bucket: int,
                mesh=None, paged: bool = False, block_size: int = 16,
                num_blocks=None, prefix_cache: bool = False,
                queue_policy: str = "fifo", autotune: bool = False,
                autotune_trials: int = 1, prefill_chunk=None,
                step_token_budget=None, packed_prefill: bool = False,
                speculative: bool = False, draft_mode: str = "quant",
                draft_k: int = 4):
    """Run a request trace through the scheduler; returns (results, summary)."""
    scfg = ServingConfig(max_batch=max_batch, prompt_bucket=prompt_bucket,
                         paged=paged, block_size=block_size,
                         num_blocks=num_blocks, prefix_cache=prefix_cache,
                         queue_policy=queue_policy, autotune=autotune,
                         autotune_trials=autotune_trials,
                         prefill_chunk=prefill_chunk,
                         step_token_budget=step_token_budget,
                         packed_prefill=packed_prefill,
                         speculative=speculative, draft_mode=draft_mode,
                         draft_k=draft_k)
    sched = Scheduler(params, cfg, scfg, mesh=mesh)
    for req in requests:
        sched.submit_request(req)
    results = sched.run()
    summary = sched.metrics.summary()
    summary["decode_traces"] = sched.decode_traces
    return results, summary


def serve_fleet(params, cfg, requests, *, scfg: ServingConfig,
                rcfg: RouterConfig, devices=None, failure_plan=None):
    """Run a trace through the multi-replica router on the fleet clock;
    returns (results, summary).  Request arrival times must be on the
    fleet clock (start at 0), not ``time.monotonic``."""
    router = Router(params, cfg, scfg, rcfg, devices=devices,
                    failure_plan=failure_plan)
    for req in requests:
        router.submit_request(req)
    results = router.run()
    summary = router.metrics().summary()
    summary["decode_traces"] = sum(
        r.sched.decode_traces for r in router.replicas if r.alive)
    return results, summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=list(PRESETS), default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (continuous-batching batch size)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=64,
                    help="max synthetic prompt length (lengths cycle "
                         "through ~{1/4, 1/2, 3/4, 1} of this)")
    ap.add_argument("--gen", type=int, default=32,
                    help="generation budget per request")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate in req/s (0: closed batch)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pim-mode", choices=list(engine.MODES), default=None,
                    help="linear lowering; quant_tp shards per-rank int8 "
                         "Pallas tiles over the mesh 'model' axis "
                         "(set --model-parallel > 1)")
    ap.add_argument("--autotune", action="store_true",
                    help="plan crossbar GEMM configurations at warmup "
                         "(pim_sim: every linear shape at the decode batch "
                         "bucket; quant/quant_tp: race the two int8 "
                         "lowerings) and decode with the tuned picks")
    ap.add_argument("--autotune-table", default=None, metavar="PATH",
                    help="tuning-table JSON (format: benchmarks/check.py "
                         "header): loaded before warmup if it exists — "
                         "warmup then hits instead of re-searching — and "
                         "written back after the run")
    ap.add_argument("--autotune-trials", type=int, default=1,
                    help="timed trials per raced candidate during warmup")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--paged", action="store_true",
                    help="block-paged KV pool (admits reserve blocks from "
                         "a free list; long-tail prompts stop paying "
                         "worst-case reservation).  Sliding-window archs "
                         "page regardless of this flag")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block (paged pool)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="physical KV blocks (default: full parity with "
                         "the contiguous pool; smaller oversubscribes and "
                         "defers admissions under pressure)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="trie prefix index over the paged pool with "
                         "refcounted copy-on-write block sharing; matched "
                         "prompt blocks skip prefill (implies --paged)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="split long-prompt prefill into chunks of this "
                         "many tokens interleaved with decode steps (a "
                         "--block-size multiple; implies --paged)")
    ap.add_argument("--step-token-budget", type=int, default=None,
                    help="max prefill tokens one scheduler step may "
                         "process (chunks + admissions); bounds decode "
                         "TPOT jitter under long-prompt bursts")
    ap.add_argument("--packed-prefill", action="store_true",
                    help="pack bursts of short queued prompts into one "
                         "segment-masked prefill call (implies --paged)")
    ap.add_argument("--speculative", action="store_true",
                    help="self-speculative decode: --draft-mode drafts "
                         "draft_k-1 tokens per round, the serving mode "
                         "verifies the run in one batched step; greedy "
                         "acceptance keeps outputs bit-identical")
    ap.add_argument("--draft-mode", choices=list(engine.MODES),
                    default="quant",
                    help="cheap lowering for the draft pass (share the "
                         "verify mode's per-row quantization — quant for "
                         "pim_sim/quant_tp — for ~100%% acceptance)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="verify width: tokens checked per verify step "
                         "(draft_k-1 drafted; 1 is plain decode)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend one fixed N-token system prompt to every "
                         "synthetic request (the prefix-cache workload)")
    ap.add_argument("--sequential", action="store_true",
                    help="also run the trace one-request-at-a-time "
                         "(max_batch=1) for an A/B comparison")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serving replicas behind the router; each gets "
                         "its own device slice, mesh, KV pool, and prefix "
                         "trie (1: single scheduler, no router)")
    ap.add_argument("--router-policy", choices=list(ROUTER_POLICIES),
                    default="least_loaded",
                    help="per-request dispatch policy (--replicas > 1)")
    ap.add_argument("--queue-policy", choices=["fifo", "sjf"],
                    default="fifo",
                    help="admission order, global queue and per-replica "
                         "backfill alike (sjf: shortest prompt first)")
    ap.add_argument("--kill-replica", default=None, metavar="R:S",
                    help="inject a failure: kill replica R after router "
                         "step S (drain-and-requeue, then respawn)")
    args = ap.parse_args()

    fleet = args.replicas > 1
    mesh = None
    mesh_ctx = contextlib.nullcontext()
    if jax.device_count() > 1 and not fleet:
        mesh = ElasticMesh(model_parallel=args.model_parallel).make()
        print(f"[mesh] {dict(mesh.shape)} over {mesh.size} devices")
        mesh_ctx = dctx.use_mesh(mesh)

    cfg = build_cfg(args)
    if args.pim_mode:
        cfg = cfg.scaled(pim_mode=args.pim_mode)
    # reload persisted tuner picks before any scheduler warms up: warmup
    # then *hits* the table (counted in [autotune]) instead of re-searching
    if args.autotune_table and os.path.exists(args.autotune_table):
        n = autotune.load_table(args.autotune_table)
        print(f"[autotune] loaded {n} plan(s) from {args.autotune_table}")
    if args.autotune:
        autotune.enable(True)
    # right-size the cache pool: capacity = longest prompt + budget (decode
    # attention cost scales with pool capacity, not with tokens generated)
    cfg = cfg.scaled(max_seq_len=args.shared_prefix + args.prompt_len
                     + args.gen)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    plens = sorted({max(1, args.prompt_len * f // 4) for f in (1, 2, 3, 4)})
    requests = synthetic_requests(
        args.requests, vocab_size=cfg.vocab_size, prompt_lens=plens,
        max_new_tokens=args.gen, rate=args.rate, seed=args.seed,
        # the router's FleetClock starts at 0; the plain scheduler runs
        # on time.monotonic
        start_time=0.0 if fleet else time.monotonic(),
        shared_prefix_len=args.shared_prefix)

    # recurrent blocks fold right-padding into their state: serve those
    # unbucketed (exact; one prefill compile per distinct prompt length)
    bucket = 1 if cfg.has_recurrent_blocks else max(8, args.prompt_len // 4)

    with mesh_ctx:
        if fleet:
            plan = None
            if args.kill_replica:
                r, s = args.kill_replica.split(":")
                plan = FailurePlan(kill_replica=int(r), at_step=int(s))
            scfg = ServingConfig(
                max_batch=args.batch, prompt_bucket=bucket,
                paged=args.paged, block_size=args.block_size,
                num_blocks=args.num_blocks, prefix_cache=args.prefix_cache,
                queue_policy=args.queue_policy, autotune=args.autotune,
                autotune_trials=args.autotune_trials,
                prefill_chunk=args.prefill_chunk,
                step_token_budget=args.step_token_budget,
                packed_prefill=args.packed_prefill,
                speculative=args.speculative, draft_mode=args.draft_mode,
                draft_k=args.draft_k)
            rcfg = RouterConfig(n_replicas=args.replicas,
                                policy=args.router_policy,
                                model_parallel=args.model_parallel)
            results, summary = serve_fleet(params, cfg, requests,
                                           scfg=scfg, rcfg=rcfg,
                                           failure_plan=plan)
        else:
            results, summary = serve_trace(
                params, cfg, requests, max_batch=args.batch,
                prompt_bucket=bucket, mesh=mesh, paged=args.paged,
                block_size=args.block_size, num_blocks=args.num_blocks,
                prefix_cache=args.prefix_cache,
                queue_policy=args.queue_policy, autotune=args.autotune,
                autotune_trials=args.autotune_trials,
                prefill_chunk=args.prefill_chunk,
                step_token_budget=args.step_token_budget,
                packed_prefill=args.packed_prefill,
                speculative=args.speculative, draft_mode=args.draft_mode,
                draft_k=args.draft_k)
        print(f"served {summary['n_finished']}/{summary['n_requests']} "
              f"requests, {summary['total_tokens']} tokens @ "
              f"{summary['tokens_per_s']:.0f} tok/s "
              f"(batch {args.batch}, {summary['decode_traces']} decode "
              f"compiles)")
        print(f"TTFT {summary['mean_ttft_s'] * 1e3:.0f}ms mean | "
              f"TPOT {summary['mean_tpot_s'] * 1e3:.1f}ms | "
              f"queue wait {summary['mean_queue_wait_s'] * 1e3:.0f}ms | "
              f"active slots {summary['mean_active_slots']:.1f}")
        if args.paged or args.prefix_cache or cfg.sliding_window:
            print(f"[pool] peak KV {summary['peak_kv_bytes'] / 1e6:.2f}MB "
                  f"(peak {summary['peak_pool_blocks']:.0f} blocks, "
                  f"occupancy {summary['mean_block_occupancy'] * 100:.0f}%, "
                  f"internal frag "
                  f"{summary['mean_internal_frag'] * 100:.0f}%, "
                  f"{summary['deferred_admits']} deferred admits)")
        if args.prefix_cache:
            print(f"[prefix] hit rate "
                  f"{summary['prefix_hit_rate'] * 100:.0f}% | "
                  f"{summary['prefix_tokens_reused']:.0f} prompt tokens "
                  f"served from the index | TTFT hit "
                  f"{summary['mean_ttft_hit_s'] * 1e3:.0f}ms vs miss "
                  f"{summary['mean_ttft_miss_s'] * 1e3:.0f}ms | "
                  f"{summary['peak_blocks_shared']:.0f} blocks shared, "
                  f"{summary['cow_copies']:.0f} COW copies")
        if (args.prefill_chunk or args.step_token_budget
                or args.packed_prefill):
            print(f"[chunked] p99 TPOT {summary['p99_tpot_s'] * 1e3:.1f}ms "
                  f"| {summary['prefill_chunks']} prefill chunks, "
                  f"{summary['packed_prefills']} packed prefills "
                  f"(chunk {args.prefill_chunk}, budget "
                  f"{args.step_token_budget})")
        if fleet:
            per = ", ".join(f"r{r}: {v:.0f}" for r, v in
                            sorted(summary["per_replica_tok_s"].items()))
            print(f"[router] {summary['router_policy']} over "
                  f"{args.replicas} replicas | per-replica tok/s {{{per}}} "
                  f"| {summary['rebalanced_requests']} rebalanced, "
                  f"{summary['replica_restarts']} restarts | "
                  f"queue {args.queue_policy}, p50 wait "
                  f"{summary['p50_queue_wait_s'] * 1e3:.0f}ms")
        if args.speculative and summary.get("spec_rounds", 0):
            print(f"[spec] draft {args.draft_mode} k={args.draft_k}: "
                  f"{summary['accepted_tokens']}/"
                  f"{summary['verified_tokens']} verified tokens accepted "
                  f"({summary['drafted_tokens']} drafted) | "
                  f"mean accept len {summary['mean_accept_len']:.2f} | "
                  f"{summary['accepted_per_step']:.2f} tok/verify step")
        if args.pim_mode == "pim_sim":
            info = engine.cache_info()
            print(f"[pim] crossbar uploads {info.exec_uploads}, "
                  f"weight-stationary session hits {info.exec_hits}")
        if args.autotune and args.pim_mode in ("quant", "quant_tp"):
            # the crossbar tuner has nothing to plan here; race the two
            # int8 linear lowerings instead (PR 5's bit-exact pair)
            autotune.autotune_linear(args.batch, cfg.d_model, cfg.d_model,
                                     trials=args.autotune_trials)
        if args.autotune or args.autotune_table:
            print(f"[autotune] {autotune.summary()}")
        if args.autotune_table:
            n = autotune.save_table(args.autotune_table)
            print(f"[autotune] saved {n} plan(s) to {args.autotune_table}")
        if args.pim_mode == "quant_tp" and mesh is not None:
            from repro.kernels.quant_matmul.tp import tile_summary

            r = mesh.shape.get("model", 1)
            if r > 1:
                for line in tile_summary(cfg, r):
                    print(f"[tp] {line} x{r} ranks")
            else:
                print("[tp] model axis is 1: quant_tp fell back to "
                      "single-rank quant (set --model-parallel > 1)")
        if args.sequential:
            # replay the same trace: keep relative arrival offsets so both
            # runs are gated by the identical Poisson process
            t0 = min(r.arrival_time for r in requests)
            base = time.monotonic()
            for req in requests:
                req.arrival_time = base + (req.arrival_time - t0)
            _, seq = serve_trace(params, cfg, requests, max_batch=1,
                                 prompt_bucket=bucket, mesh=mesh)
            speed = summary["tokens_per_s"] / max(seq["tokens_per_s"], 1e-9)
            print(f"sequential baseline: {seq['tokens_per_s']:.0f} tok/s "
                  f"-> continuous batching {speed:.2f}x")
        rid0 = min(results)
        print("sample generation:", results[rid0][:16].tolist())
    return results


if __name__ == "__main__":
    main()
