"""Batched serving driver: prefill a prompt batch, decode greedily.

CPU-runnable with ``--smoke``/``--preset``; on real hardware the same
entry point shards over the production mesh (params/caches take the same
partitioning rules as the dry-run).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.launch.train import PRESETS, build_cfg
from repro.models import model_lib as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=list(PRESETS), default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = build_cfg(args)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(rng.normal(size=(
            args.batch, args.prompt_len // cfg.audio_frames_div,
            cfg.d_model)), jnp.float32)
    if cfg.vision_dim:
        batch["patches"] = jnp.asarray(rng.normal(size=(
            args.batch, cfg.n_patches, cfg.vision_dim)), jnp.float32)

    prefill = jax.jit(lambda p, b: M.prefill(p, b, cfg))
    decode = jax.jit(lambda p, t, pos, c: M.decode_step(p, t, pos, c, cfg))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

    generated = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        tok, _, caches = decode(params, tok,
                                jnp.int32(args.prompt_len + i), caches)
        generated.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    out = np.concatenate(generated, axis=1)
    toks_per_s = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.0f}ms "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")
    print(f"decode:  {args.gen - 1} steps in {t_decode*1e3:.0f}ms "
          f"({toks_per_s:.0f} tok/s)")
    print("sample generation:", out[0, :16].tolist())
    return out


if __name__ == "__main__":
    main()
