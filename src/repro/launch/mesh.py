"""Production meshes (importing this module never touches device state)."""
from __future__ import annotations

import jax

__all__ = ["make_mesh", "make_production_mesh", "make_local_mesh",
           "make_host_mesh"]


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the API supports them
    (jax >= 0.5); plain construction on older releases."""
    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    except AttributeError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips for the multi-pod pass."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist locally, as a (data, model=1) mesh."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))


def make_host_mesh(model: int = 2):
    """A (data, model) mesh over all local devices with a real tensor axis —
    the test-suite mesh for forced 8-device CPU runs."""
    n = len(jax.devices())
    if n % model:
        raise ValueError(f"{n} devices not divisible by model={model}")
    return make_mesh((n // model, model), ("data", "model"))
