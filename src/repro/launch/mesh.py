"""Production meshes (importing this module never touches device state)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips for the multi-pod pass."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh():
    """Whatever devices exist locally, as a (data, model=1) mesh."""
    n = len(jax.devices())
    return jax.make_mesh(
        (n, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
