"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) single-pod cell:
    compute term    = HLO_FLOPs_per_dev / 197 TFLOP/s     (bf16 MXU peak)
    memory term     = HLO_bytes_per_dev / 819 GB/s        (HBM bandwidth)
    collective term = wire_bytes_per_dev / 50 GB/s        (ICI link)
plus MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (inference) and the
HLO/MODEL ratio (catches remat/attention/dispatch overhead).  HLO FLOPs come
from the *unrolled* lowering (XLA cost analysis counts loop bodies once);
SSM/xLSTM sequence-recurrence FLOPs (inside lax.scan, analytically small)
are added as a correction term.

Usage:  PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List


import repro.configs as configs
from repro.models import model_lib as M
from repro.models.config import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")


def _expert_params(cfg) -> int:
    if not cfg.n_experts:
        return 0
    f = cfg.moe_d_ff or cfg.d_ff
    n_moe_layers = sum(1 for k in cfg.pattern if k in ("ae", "ar", "me")
                       ) * cfg.n_super
    return n_moe_layers * cfg.n_experts * 3 * cfg.d_model * f


def _embed_params(cfg) -> int:
    mult = 1 if cfg.tie_embeddings else 2
    return mult * cfg.padded_vocab * cfg.d_model


def active_params(cfg) -> int:
    total = M.param_count(cfg)
    ep = _expert_params(cfg)
    active_ep = ep * cfg.top_k / max(cfg.n_experts, 1)
    return int(total - _embed_params(cfg) - ep + active_ep)


def recurrence_flops(cfg, tokens: int) -> float:
    """Analytic per-token recurrence FLOPs hidden inside lax.scan bodies."""
    fl = 0.0
    per = cfg.n_super
    for kind in cfg.pattern:
        if kind in ("md", "me"):
            fl += per * 6 * cfg.d_inner * cfg.mamba_d_state
        if kind == "xm":
            p = int(cfg.xlstm_proj_factor * cfg.d_model)
            dh = p // cfg.n_heads
            fl += per * 8 * cfg.n_heads * dh * dh
        if kind == "xs":
            p = int(cfg.xlstm_proj_factor * cfg.d_model)
            fl += per * 10 * p
    return fl * tokens


def model_flops(cfg, shape) -> float:
    n_act = active_params(cfg)
    d_tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        base = 6.0 * n_act * d_tokens
        base += 3 * recurrence_flops(cfg, d_tokens)
    elif shape.kind == "prefill":
        base = 2.0 * n_act * d_tokens
        base += recurrence_flops(cfg, d_tokens)
    else:  # decode: one token per sequence
        base = 2.0 * n_act * shape.global_batch
        base += recurrence_flops(cfg, shape.global_batch)
    return base


def _advice(dominant: str, cell: Dict) -> str:
    colls = cell.get("collectives", {})
    if dominant == "collective":
        big = max(colls.items(), key=lambda kv: kv[1]["wire_bytes"])[0] \
            if colls else "?"
        return f"cut {big} volume (sharding/dtype of the reduced tensor)"
    if dominant == "memory":
        return "raise arithmetic intensity: fuse/quantize, larger per-chip tile"
    return "compute-bound: reduce remat recompute or use int8 MXU path"


def analyze(dir_: str) -> List[Dict]:
    out = []
    for arch in configs.ARCH_NAMES:
        cfg = configs.get(arch)
        for shape in SHAPES:
            path = os.path.join(
                dir_, f"{arch}__{shape.name}__single.json")
            if not os.path.exists(path):
                continue
            cell = json.load(open(path))
            row: Dict = {"arch": arch, "shape": shape.name,
                         "status": cell.get("status")}
            if cell.get("status") != "ok" or "flops_per_dev" not in cell:
                row["reason"] = cell.get("reason", cell.get("error", ""))[:60]
                out.append(row)
                continue
            n_dev = cell["n_devices"]
            mf = model_flops(cfg, shape)
            hlo_flops_global = cell["flops_per_dev"] * n_dev
            # memory: HLO bytes-accessed is an upper bound (CPU-backend
            # fusion is weaker than TPU's); resident argument bytes per step
            # (params + caches, which a step must read once) is the lower
            # bound — decode steps sit at the lower bound on real hardware.
            mem_lb = cell["mem"]["argument_bytes"] / HBM_BW
            terms = {
                "compute": cell["flops_per_dev"] / PEAK_FLOPS,
                "memory": cell["bytes_per_dev"] / HBM_BW,
                "collective": cell["wire_bytes_per_dev"] / LINK_BW,
            }
            dominant = max(terms, key=terms.get)
            row.update(
                compute_s=terms["compute"],
                memory_s=terms["memory"],
                memory_lb_s=mem_lb,
                collective_s=terms["collective"],
                dominant=dominant,
                model_flops=mf,
                hlo_over_model=hlo_flops_global / max(mf, 1.0),
                compute_fraction=terms["compute"] / terms[dominant],
                temp_gb=cell["mem"]["temp_bytes"] / 1e9,
                advice=_advice(dominant, cell),
            )
            out.append(row)
    return out


def render(rows: List[Dict]) -> str:
    lines = [
        "| arch | shape | compute (s) | memory ub (s) | memory lb (s) | "
        "collective (s) | dominant | MODEL_FLOPS | HLO/MODEL | compute-frac "
        "| temp GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") != "ok" or "dominant" not in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                f"{r.get('status')}: {r.get('reason', '')} | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['memory_lb_s']:.3e} | "
            f"{r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['model_flops']:.3g} | "
            f"{r['hlo_over_model']:.2f} | {r['compute_fraction']:.2f} | "
            f"{r['temp_gb']:.1f} |")
    ok = [r for r in rows if "dominant" in r]
    if ok:
        worst = min(ok, key=lambda r: r["compute_fraction"])
        coll = max(ok, key=lambda r: r["collective_s"])
        lines.append("")
        lines.append(f"* worst compute fraction: {worst['arch']} x "
                     f"{worst['shape']} ({worst['compute_fraction']:.2f}, "
                     f"dominant {worst['dominant']})")
        lines.append(f"* most collective-bound: {coll['arch']} x "
                     f"{coll['shape']} ({coll['collective_s']:.3e}s on wire)")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=RESULTS_DIR)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = analyze(args.dir)
    md = render(rows)
    print(md)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md + "\n")


if __name__ == "__main__":
    main()
