"""Self-speculative decoding: cheap-mode draft, expensive-mode verify,
exact greedy acceptance.

The engine's bit-exactness invariant — "quant", "quant_tp", and
"pim_sim" all quantize activations *per row* and accumulate the same
integers, so they agree on every logit — is usually stated as a test
property.  This module turns it into throughput.  One round:

1. **Draft**: the cheap mode (``draft_mode``, e.g. ``"quant"``) runs
   ``k - 1`` ordinary single-token decode steps from the batch's current
   tokens, producing a candidate run per slot.  Drafting shares the KV
   pool: its writes land at the run's positions and are overwritten by
   the verify step below, and — for a slot within ``k - 2`` rows of
   capacity — writes past the last reserved row are discarded by the
   model's guarded per-slot write paths (trash-block routing / drop
   semantics, the same guard the verify run applies), never wrapped or
   clamped onto live rows.  Drafting also shares the compiled-artifact
   cache, but executes
   inside :func:`repro.pim.engine.draft_ctx`, whose ``"draft"`` session
   namespace keeps its crossbar-state uploads from LRU-evicting the
   verify path's resident :class:`~repro.pim.engine.ExecutionSession`
   state.
2. **Verify**: the expensive mode (the scheduler's ``cfg.pim_mode``)
   checks all ``k`` positions — current token plus ``k - 1`` drafts — in
   **one** batched :func:`repro.models.model_lib.decode_run_slots` call,
   re-writing every KV row it covers with verify-mode bits.
3. **Accept**: greedy decode makes acceptance a pure integer comparison
   (:func:`accept_length`): the longest prefix of drafts matching the
   verify continuations is committed, plus the verify continuation after
   it — at least one token per round, so even an all-rejected round makes
   forward progress.  Rejected rows hold garbage KV, but every decode
   mask in the stack is position-gated, so the next round's writes land
   on them before any query can see them — rollback is just "don't
   advance ``pos`` past the accepted rows".

Because the committed tokens are, by construction, exactly the greedy
chain the verify mode would have produced alone, speculative decode is
**bit-identical to non-speculative decode** in every mode and for every
draft quality — a bad draft (e.g. an ``"xla"`` float draft against an
integer verify mode) only lowers the acceptance length, never changes a
token.  The speedup comes from amortization: a ``pim_sim`` verify of
``k`` rows costs close to one single-row step (the simulator's per-gate
interpreter overhead dominates its vectorized row math, the same
latency-hiding batching PartitionPIM's partitions buy in hardware), so
``k`` tokens ride one expensive step plus ``k - 1`` cheap ones.

Shapes are pinned: the draft step is the plain ``(B, 1)`` decode jit and
the verify step a single ``(B, k)`` jit, so acceptance-length churn never
recompiles — ``draft_traces`` / ``verify_traces`` count retraces the way
the scheduler's ``decode_traces`` does, and tests pin both to 1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model_lib as M
from repro.models.config import ModelConfig
from repro.pim import engine

__all__ = ["SpeculativeDecoder", "accept_length"]


def accept_length(fed: np.ndarray, verify: np.ndarray) -> int:
    """Tokens committed by one verify run, in ``1..S`` (host-side, exact).

    ``fed`` (S,) is the token run the verify step consumed — the current
    token followed by ``S - 1`` drafts; ``verify`` (S,) the greedy
    continuation it produced at each position.  ``verify[0]`` conditions
    only on already-committed tokens, so it is always accepted;
    ``verify[i]`` is accepted while every draft before it matched its
    verify continuation (``fed[j + 1] == verify[j]`` for ``j < i``) —
    the first mismatch invalidates every later position's prefix.
    """
    n = 1
    s = len(fed)
    while n < s and fed[n] == verify[n - 1]:
        n += 1
    return n


class SpeculativeDecoder:
    """Draft/verify round engine for one scheduler's slot batch.

    Owns the two jitted callables — the ``(B, 1)`` draft step traced
    under ``cfg.scaled(pim_mode=draft_mode)`` inside
    :func:`engine.draft_ctx`, and the ``(B, k)`` verify step traced under
    the scheduler's own ``cfg`` — plus their retrace counters.  Draft and
    verify *should* share the engine's per-row integer quantization
    ("quant"/"quant_tp" drafting for a "pim_sim" or "quant_tp" verify)
    so acceptance stays ~100%; any pairing is still exact, just slower.
    """

    def __init__(self, cfg: ModelConfig, draft_mode: str, draft_k: int):
        if draft_k < 2:
            raise ValueError("SpeculativeDecoder needs draft_k >= 2 "
                             "(draft_k=1 is plain decode; the scheduler "
                             "short-circuits it)")
        self.cfg = cfg
        self.draft_mode = draft_mode
        self.k = draft_k
        self.dcfg = cfg.scaled(pim_mode=draft_mode)
        self.draft_traces = 0
        self.verify_traces = 0

        def _draft_step(p, tokens, pos, active, caches, tables):
            self.draft_traces += 1
            # draft_ctx: trace-time session namespace — the drafting
            # pass's pim_sim callbacks (if any) hit a "draft" session
            # pool and can never evict the verify path's resident state
            with engine.draft_ctx():
                return M.decode_step_slots(p, tokens, pos, active, caches,
                                           self.dcfg, block_tables=tables)

        def _verify_step(p, tokens, pos, active, caches, tables):
            self.verify_traces += 1
            return M.decode_run_slots(p, tokens, pos, active, caches,
                                      self.cfg, block_tables=tables)

        self._draft = jax.jit(_draft_step)
        self._verify = jax.jit(_verify_step)

    def run_round(self, params, tokens: np.ndarray, pos: np.ndarray,
                  active: np.ndarray, caches, tables):
        """One draft + verify round over the whole slot batch.

        ``tokens`` (B, 1) int32 current token per slot, ``pos`` (B,)
        int32 its absolute position, ``active`` (B,) bool the decoding
        mask.  Returns ``(toks_run, verify_tok, new_caches)``: the
        (B, k) run the verify step consumed, its (B, k) greedy
        continuations, and the cache tree with every covered row
        rewritten in verify-mode bits.  The caller commits
        ``verify_tok[slot, :accept_length(...)]`` per slot and advances
        ``pos`` by the (budget/EOS-clipped) emission count.
        """
        b = tokens.shape[0]
        toks_run = np.zeros((b, self.k), np.int32)
        toks_run[:, 0] = tokens[:, 0]
        cur = jnp.asarray(tokens)
        pos_j = jnp.asarray(pos)
        act_j = jnp.asarray(active)
        for i in range(1, self.k):
            cur, _, caches = self._draft(params, cur, pos_j + (i - 1),
                                         act_j, caches, tables)
            toks_run[:, i] = np.asarray(cur)[:, 0]
        vt, _, caches = self._verify(params, jnp.asarray(toks_run), pos_j,
                                     act_j, caches, tables)
        return toks_run, np.asarray(vt), caches
