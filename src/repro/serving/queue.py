"""Request admission: the policy queue feeding the continuous-batching
scheduler (and, one level up, the multi-replica router).

A :class:`Request` is one decode job — a prompt, a generation budget, and
an arrival time — plus the migration bookkeeping the router needs:
``replica_id`` names the engine currently serving it and ``n_migrations``
counts drain-and-requeue hops after replica failures.  Both survive a
requeue untouched except for the migration bump, and ``arrival_time`` is
**never** rewritten: queue-wait and TTFT metrics stay anchored to the
moment the request first entered the system, not to its latest requeue
(a drain must not launder latency).

The :class:`AdmissionQueue` supports two admission policies:

* ``"fifo"`` (default) — strictly submit order; ``pop(now)`` gates on the
  *head's* arrival time only, so a synthetic (e.g. Poisson) trace can be
  loaded up front and replayed against a clock.
* ``"sjf"`` — shortest-prompt-first among the requests that have
  *arrived* by ``now`` (ties break toward the earlier submit).  Prompt
  length is the serving-side proxy for job size: prefill cost is linear
  in it and it is known at admission, unlike the generation length.
  While nothing has arrived yet, ``peek`` reports the earliest-arriving
  request so callers can sleep until it lands.

``requeue`` re-inserts a drained (already-admitted-once) request at the
*front* of the FIFO order — it is, by construction, among the oldest
work in the system — while SJF re-ranks it with everyone else.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Deque, Iterator, List, Optional, Sequence

import numpy as np

__all__ = ["Request", "AdmissionQueue", "synthetic_requests"]

_rid_counter = itertools.count()

QUEUE_POLICIES = ("fifo", "sjf")


@dataclasses.dataclass
class Request:
    """One decode request.

    ``max_new_tokens`` counts every generated token, including the first
    one emitted by prefill.  ``arrival_time`` is on the scheduler's clock
    (``time.monotonic`` unless injected) and is preserved across router
    requeues.  ``n_migrations`` counts drain-and-requeue hops (0 for a
    request that never lost its replica); ``replica_id`` is the serving
    replica currently assigned by the router (-1 outside a router).
    """

    rid: int
    prompt: np.ndarray              # (plen,) int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0
    n_migrations: int = 0
    replica_id: int = -1

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.rid}: max_new_tokens must be >= 1")


def make_request(prompt, max_new_tokens: int, *, rid: Optional[int] = None,
                 arrival_time: float = 0.0) -> Request:
    """Build a request, auto-assigning a process-unique rid if not given."""
    return Request(rid=next(_rid_counter) if rid is None else rid,
                   prompt=prompt, max_new_tokens=max_new_tokens,
                   arrival_time=arrival_time)


class AdmissionQueue:
    """Admission queue with pluggable policy (see module docstring).

    ``peek(now)`` must return exactly the request a ``pop(now)`` would
    remove — the scheduler inspects the head (capacity check, prefix
    match) before committing to the pop, so selection is deterministic:
    FIFO is submit order, SJF is ``(prompt length, submit order)`` over
    the arrived subset.
    """

    def __init__(self, policy: str = "fifo"):
        if policy not in QUEUE_POLICIES:
            raise ValueError(f"unknown queue policy {policy!r} "
                             f"(choose from {QUEUE_POLICIES})")
        self.policy = policy
        self._q: Deque[Request] = collections.deque()

    def submit(self, request: Request) -> None:
        self._q.append(request)

    def requeue(self, request: Request) -> None:
        """Re-insert a drained request at the front of the FIFO order
        (it was admitted once already — among the oldest work alive).
        Its ``arrival_time`` is deliberately left alone; queue-wait /
        TTFT metrics must keep measuring from first arrival."""
        self._q.appendleft(request)

    # ---- selection ---------------------------------------------------

    def _select(self, now: Optional[float]) -> Optional[int]:
        """Index of the request ``pop(now)`` would remove, or None."""
        if not self._q:
            return None
        if self.policy == "fifo":
            if now is not None and self._q[0].arrival_time > now:
                return None
            return 0
        # sjf: shortest arrived prompt; ties to the earlier submit
        best = None
        for i, r in enumerate(self._q):
            if now is not None and r.arrival_time > now:
                continue
            key = (r.prompt.shape[0], i)
            if best is None or key < best[0]:
                best = (key, i)
        return None if best is None else best[1]

    def pop(self, now: Optional[float] = None) -> Optional[Request]:
        """The policy's pick among requests arrived by ``now`` (None:
        ignore arrival times), removed from the queue."""
        i = self._select(now)
        if i is None:
            return None
        r = self._q[i]
        del self._q[i]
        return r

    def peek(self, now: Optional[float] = None) -> Optional[Request]:
        """The request ``pop(now)`` would return; when nothing has
        arrived yet, the earliest-arriving request (so callers can wait
        on its ``arrival_time``)."""
        i = self._select(now)
        if i is not None:
            return self._q[i]
        if not self._q:
            return None
        if self.policy == "fifo":
            return self._q[0]
        return min(self._q, key=lambda r: r.arrival_time)

    def clear(self) -> List[Request]:
        """Remove and return every queued request (drain support)."""
        out = list(self._q)
        self._q.clear()
        return out

    def __len__(self) -> int:
        return len(self._q)

    def __iter__(self) -> Iterator[Request]:
        return iter(self._q)


def synthetic_requests(n: int, *, vocab_size: int, prompt_lens: Sequence[int],
                       max_new_tokens: int = 16, rate: float = 0.0,
                       seed: int = 0, start_time: float = 0.0,
                       shared_prefix_len: int = 0,
                       n_tenants: int = 1) -> List[Request]:
    """A deterministic synthetic trace: random prompts, Poisson arrivals.

    ``rate`` is the arrival rate in requests/second (exponential
    inter-arrival gaps); 0 puts every request at ``start_time`` (a closed
    batch).  Prompt lengths cycle through ``prompt_lens``.

    ``shared_prefix_len`` > 0 prepends one fixed random token run of that
    length to every prompt — a shared system prompt, the prefix-caching
    workload; ``prompt_lens`` then size each request's divergent tail.
    ``n_tenants`` > 1 draws that many *distinct* shared prefixes and
    cycles requests through them (request ``i`` belongs to tenant
    ``i % n_tenants``) — the multi-tenant workload whose per-tenant
    system prompts the router's ``prefix_affinity`` policy keeps pinned
    to one replica's trie.  All shared runs are drawn first, so traces
    built with the same ``seed``/``shared_prefix_len``/``n_tenants``
    share them across calls (warm-up vs measured trace in the
    benchmarks).
    """
    if n_tenants < 1:
        raise ValueError("n_tenants must be >= 1")
    rng = np.random.default_rng(seed)
    shared = (rng.integers(0, vocab_size,
                           size=(n_tenants, shared_prefix_len),
                           dtype=np.int64)
              if shared_prefix_len > 0 else None)
    t = start_time
    out: List[Request] = []
    for i in range(n):
        if rate > 0 and i > 0:
            t += float(rng.exponential(1.0 / rate))
        plen = int(prompt_lens[i % len(prompt_lens)])
        tail = rng.integers(0, vocab_size, size=(plen,), dtype=np.int64)
        out.append(make_request(
            tail if shared is None
            else np.concatenate([shared[i % n_tenants], tail]),
            max_new_tokens, arrival_time=t))
    return out
