"""Request admission: the FIFO queue feeding the continuous-batching
scheduler.

A :class:`Request` is one decode job — a prompt, a generation budget, and
an arrival time.  The :class:`AdmissionQueue` is strictly FIFO in submit
order; ``pop(now)`` additionally respects arrival times, so a synthetic
(e.g. Poisson) trace can be loaded up front and replayed against a clock:
the head request stays queued until its arrival time has passed.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Deque, Iterator, List, Optional, Sequence

import numpy as np

__all__ = ["Request", "AdmissionQueue", "synthetic_requests"]

_rid_counter = itertools.count()


@dataclasses.dataclass
class Request:
    """One decode request.

    ``max_new_tokens`` counts every generated token, including the first
    one emitted by prefill.  ``arrival_time`` is on the scheduler's clock
    (``time.monotonic`` unless injected).
    """

    rid: int
    prompt: np.ndarray              # (plen,) int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.rid}: max_new_tokens must be >= 1")


def make_request(prompt, max_new_tokens: int, *, rid: Optional[int] = None,
                 arrival_time: float = 0.0) -> Request:
    """Build a request, auto-assigning a process-unique rid if not given."""
    return Request(rid=next(_rid_counter) if rid is None else rid,
                   prompt=prompt, max_new_tokens=max_new_tokens,
                   arrival_time=arrival_time)


class AdmissionQueue:
    """FIFO admission queue (submit order; arrival-time gated pops)."""

    def __init__(self):
        self._q: Deque[Request] = collections.deque()

    def submit(self, request: Request) -> None:
        self._q.append(request)

    def pop(self, now: Optional[float] = None) -> Optional[Request]:
        """The head request, if it has arrived by ``now`` (None: always)."""
        if not self._q:
            return None
        if now is not None and self._q[0].arrival_time > now:
            return None
        return self._q.popleft()

    def peek(self) -> Optional[Request]:
        return self._q[0] if self._q else None

    def __len__(self) -> int:
        return len(self._q)

    def __iter__(self) -> Iterator[Request]:
        return iter(self._q)


def synthetic_requests(n: int, *, vocab_size: int, prompt_lens: Sequence[int],
                       max_new_tokens: int = 16, rate: float = 0.0,
                       seed: int = 0, start_time: float = 0.0,
                       shared_prefix_len: int = 0) -> List[Request]:
    """A deterministic synthetic trace: random prompts, Poisson arrivals.

    ``rate`` is the arrival rate in requests/second (exponential
    inter-arrival gaps); 0 puts every request at ``start_time`` (a closed
    batch).  Prompt lengths cycle through ``prompt_lens``.

    ``shared_prefix_len`` > 0 prepends one fixed random token run of that
    length to every prompt — a shared system prompt, the prefix-caching
    workload; ``prompt_lens`` then size each request's divergent tail.
    The shared run is drawn first, so traces built with the same ``seed``
    and ``shared_prefix_len`` share it across calls (warm-up vs measured
    trace in the benchmarks).
    """
    rng = np.random.default_rng(seed)
    shared = (rng.integers(0, vocab_size, size=(shared_prefix_len,),
                           dtype=np.int64)
              if shared_prefix_len > 0 else None)
    t = start_time
    out: List[Request] = []
    for i in range(n):
        if rate > 0 and i > 0:
            t += float(rng.exponential(1.0 / rate))
        plen = int(prompt_lens[i % len(prompt_lens)])
        tail = rng.integers(0, vocab_size, size=(plen,), dtype=np.int64)
        out.append(make_request(
            tail if shared is None else np.concatenate([shared, tail]),
            max_new_tokens, arrival_time=t))
    return out
