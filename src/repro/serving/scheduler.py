"""Continuous-batching decode scheduler.

The runtime keeps one fixed-size decode batch of ``max_batch`` *slots*
stepping together under a single jitted ``decode_step_slots`` — per-slot
positions, per-slot ``cache_len`` masks, and an active-slot mask mean the
step's shapes never change, so steady-state decode **never recompiles** no
matter how requests churn (``decode_traces`` counts retraces; tests pin it
to 1).  Each scheduler step:

1. *resume* — every mid-prefill slot advances by one prompt chunk
   (chunked prefill, below); a prompt whose last chunk lands emits its
   first token and joins the decode batch.
2. *backfill* — every free slot is filled from the admission queue
   (lowest-numbered slot first, FIFO requests): the prompt is right-padded
   to a ``prompt_bucket`` multiple, prefilled in one shot (logits read at
   the true last token via ``prefill(last_index=...)``), the resulting
   cache written into the slot of the persistent :class:`CachePool`, and
   the first token emitted — that's the request's TTFT.
3. *decode* — one batched step advances every decoding slot by one token;
   finished slots (budget exhausted or EOS) are evicted and become
   backfill targets on the next step.

**Chunked prefill** (``ServingConfig(prefill_chunk=, step_token_budget=)``)
splits a long prompt across steps so it never monopolizes a step: the
first chunk admits normally (reserving the request's *full* block need up
front), the slot is marked mid-prefill — occupied but excluded from the
decode batch's active mask — and each later step resumes one more chunk
through the block-aligned ``prefill(prefix=...)`` path, reading the
slot's own already-written blocks back as the prefix.  The last chunk
emits the first token exactly as whole prefill would, so generations are
bit-identical.  ``step_token_budget`` caps the prefill tokens (resumed
chunks + new admissions, real token counts) any single step processes —
the decode step that follows is never delayed by more than one budget's
worth of prefill, which is what bounds TPOT jitter under bursty
long-prompt traffic.  The first work item of a step is always allowed
(progress guarantee).  A drained mid-prefill slot requeues its request
like any other (partial blocks are evicted; the rerun is bit-identical).

**Packed prefill** (``ServingConfig(packed_prefill=True)``) batches a
burst of short queued prompts into *one* ``prefill_packed`` call:
segments ride a single (1, L) token stream with per-segment position
offsets and a block-diagonal segment mask, so one compile-stable call
(one trace per packed length L; the segment count is pinned to
``max_batch``) replaces N prompt-sized prefills while each segment's
logits and KV stay bit-identical to its own unpacked prefill.  Heads are
popped in queue-policy order and packing stops at the first ineligible
head — no skip-ahead, so FIFO fairness and deferral semantics are
untouched.  Both features require the paged pool and the same
KV-separability the prefix cache needs (no recurrent blocks, no MoE);
windowed prompts participate only while they fit inside the window.

Bucketed prefill retraces once per distinct bucket length (a handful of
compiles, amortized over the run) and is exact for attention stacks; for
recurrent blocks (Mamba/xLSTM) set ``prompt_bucket=1`` so prompts run
unpadded.  With ``ServingConfig(paged=True)`` the KV pool is block-paged
(see :mod:`repro.serving.cache`): admits reserve blocks from a free list
and *defer* when it runs short, evictions return blocks, and the decode
step reads through a fixed-shape block table — still exactly one trace.
Sliding-window configs serve as rings over their block lists and enable
paging automatically (prompts bucket only while the padded length stays
inside the window).  ``ServingConfig(prefix_cache=True)`` additionally
attaches the pool's prefix index: admission walks a trie over the prompt
tokens, maps every fully matched block into the slot by reference, and
prefills only the divergent tail (``prefill(prefix=...)`` resumed at the
block-aligned match length) — on shared-system-prompt traces this turns
most of the prompt's TTFT cost into one block-table write.  Shared blocks
are copy-on-write: before each decode step the scheduler upgrades any
slot about to write into one (``ensure_writable``), so trie hits, forks,
and windowed ring wraps never corrupt other referents.  Tail prefill
retraces once per (match length, tail bucket) pair — cheap when prompts
share a few long system prefixes, which is the workload prefix caching
is for.

The scheduler is **single-replica-ignorant**: it admits in whatever
order its :class:`AdmissionQueue` policy picks (``queue_policy=`` —
FIFO or shortest-prompt-first), and the only multi-replica hooks it
exposes are ``validate_request``/``submit_request`` (router-side global
admission), ``drain()`` (evict all in-flight work and return the
unfinished :class:`Request`s for requeue elsewhere) and ``output(rid)``
(harvest finished tokens).  Everything fleet-shaped — dispatch,
health, respawn — lives one level up in :mod:`repro.serving.router`.

Under ``pim_mode="pim_sim"`` the decode step's
crossbar GEMMs
run through the engine's persistent :class:`ExecutionSession` pool:
crossbar state is uploaded once per artifact and only operand columns
stream per token.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model_lib as M
from repro.models.config import ModelConfig
from repro.serving.cache import CachePool, PagedCachePool
from repro.serving.metrics import ServingMetrics
from repro.serving.queue import AdmissionQueue, Request, make_request

__all__ = ["ServingConfig", "Scheduler"]

#: Packed-prefill stream cap: keeping the packed length inside one flash
#: key block (block_k = 512) means every segment's online-softmax pass
#: sees the same single-block reduction as its unpacked prefill, which is
#: what keeps packing bit-exact.  Far above any short-prompt burst worth
#: packing anyway — longer prompts chunk instead.
_PACK_MAX_TOKENS = 512


def _idle_sleep(clock, arrival: float, stalls: int,
                cap: float = 0.25) -> int:
    """Sleep toward ``arrival`` on a real clock; returns the stall count.

    One short (1 ms) probe first distinguishes an advancing wall clock
    from an injected test clock (which never moves while we sleep) — once
    the clock demonstrably advances, the rest of the gap is slept in one
    ``cap``-bounded slice instead of thousands of 1 ms spins.
    """
    before = clock()
    time.sleep(min(max(arrival - before, 0.0), 1e-3))
    now = clock()
    if now == before:
        return stalls + 1
    remaining = arrival - now
    if remaining > 0:
        time.sleep(min(remaining, cap))
    return 0


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Knobs of the continuous-batching runtime.  Per-slot cache capacity
    is ``cfg.max_seq_len`` (prefill emits caches at exactly that capacity,
    so the pool cannot be sized independently).

    ``paged=True`` swaps the slot-contiguous pool for the block-paged
    :class:`PagedCachePool` (``block_size`` tokens per block;
    ``num_blocks`` physical blocks, default full parity + trash block):
    admits reserve exactly the request's block need from a free list and
    defer when it runs short.  Sliding-window configs require paging (a
    windowed slot is a ring over its block list) and enable it
    automatically.

    ``prefill_chunk=N`` splits every long prompt's prefill into N-token
    chunks interleaved with decode steps (N must be a ``block_size``
    multiple — chunk resumes ride the block-aligned prefix-resume path);
    ``step_token_budget=B`` caps the prefill tokens one step may process;
    ``packed_prefill=True`` batches short queued prompts into one
    segment-masked prefill call.  All three imply the paged pool; chunked
    and packed prefill additionally require prefix-separable KV (no
    recurrent blocks, no MoE) — see the module docstring.

    ``autotune=True`` runs the partition autotuner at construction when the
    model decodes on the crossbar simulator (``cfg.pim_mode == "pim_sim"``):
    every distinct linear shape in the parameter tree is planned at the
    decode batch bucket (``pim.autotune.plan_for_params``) and ambient plan
    lookup is switched on, so the decode loop's GEMMs run the tuned
    configuration.  Shapes already in the tuner table (e.g. reloaded via
    ``serve.py --autotune-table``) are warmup hits — no re-search.
    """

    max_batch: int = 4          # decode slots
    prompt_bucket: int = 16     # prompts pad up to a multiple of this
    pad_id: int = 0
    eos_id: Optional[int] = None   # stop early on this token (None: never)
    paged: bool = False         # block-paged KV pool
    block_size: int = 16        # tokens per KV block (paged pool)
    num_blocks: Optional[int] = None   # physical blocks (None: full parity)
    prefix_cache: bool = False  # trie prefix sharing + COW (implies paged)
    queue_policy: str = "fifo"  # admission order: "fifo" | "sjf"
    autotune: bool = False      # plan crossbar GEMMs at warmup (pim_sim)
    autotune_trials: int = 1    # timed trials per candidate during warmup
    prefill_chunk: Optional[int] = None  # split prefill into chunks of this
    #   many tokens (block_size multiple; implies paged)
    step_token_budget: Optional[int] = None  # max prefill tokens per step
    packed_prefill: bool = False  # pack short prompts into one prefill call
    #   (implies paged)
    speculative: bool = False   # self-speculative decode: a cheap draft
    #   mode proposes, the serving mode verifies, greedy acceptance is
    #   exact (see serving.speculative) — tokens stay bit-identical to
    #   plain decode in every mode
    draft_mode: str = "quant"   # the drafting lowering; must share the
    #   engine's per-row integer quantization with the verify mode for
    #   ~100% acceptance (any mode is still exact, just slower)
    draft_k: int = 4            # verify width: tokens fed per verify step
    #   (the draft pass proposes draft_k - 1; draft_k=1 is plain decode)


class Scheduler:
    """Continuous-batching scheduler over a persistent cache pool."""

    def __init__(self, params, cfg: ModelConfig, scfg: ServingConfig, *,
                 mesh=None, clock=time.monotonic):
        if scfg.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        # enc-dec / vision prefill needs frames/patches carried per request
        # and their cross-attention caches pooled; not wired up yet.
        if cfg.is_encoder_decoder or cfg.vision_dim:
            raise NotImplementedError(
                f"{cfg.name}: multimodal serving (frames/patches on the "
                "request) is a ROADMAP follow-on; decoder-only stacks only")
        # recurrent state folds right-padding into the prefix: bucketed
        # prefill would silently change generations
        if cfg.has_recurrent_blocks and scfg.prompt_bucket != 1:
            raise ValueError(
                f"{cfg.name}: SSM/xLSTM blocks require prompt_bucket=1 "
                "(padding folds into the recurrent state)")
        if scfg.prefix_cache:
            # prefix sharing assumes a token's KV depends only on the
            # tokens before it — recurrent state folds the whole prefix
            # into one vector (nothing block-separable to share), and MoE
            # capacity dropping makes each token's output depend on its
            # *batch-mates*, so identical prefixes need not produce
            # identical KV
            if cfg.has_recurrent_blocks:
                raise ValueError(
                    f"{cfg.name}: prefix_cache is incompatible with "
                    "SSM/xLSTM blocks (recurrent state is not "
                    "prefix-separable)")
            if cfg.n_experts:
                raise ValueError(
                    f"{cfg.name}: prefix_cache is incompatible with MoE "
                    "(capacity dropping couples a token's KV to its "
                    "batch-mates)")
        chunked = scfg.prefill_chunk is not None
        if chunked or scfg.packed_prefill:
            # both paths rebuild a slot's KV from per-token caches laid
            # out by absolute position — the same separability the prefix
            # cache needs (recurrent state folds the whole prefix into one
            # vector; MoE capacity dropping couples a token's KV to its
            # batch-mates)
            what = "prefill_chunk" if chunked else "packed_prefill"
            if cfg.has_recurrent_blocks:
                raise ValueError(
                    f"{cfg.name}: {what} is incompatible with SSM/xLSTM "
                    "blocks (recurrent state is not prefix-separable)")
            if cfg.n_experts:
                raise ValueError(
                    f"{cfg.name}: {what} is incompatible with MoE "
                    "(capacity dropping couples a token's KV to its "
                    "batch-mates)")
        if chunked and (scfg.prefill_chunk < 1
                        or scfg.prefill_chunk % scfg.block_size):
            raise ValueError(
                f"prefill_chunk={scfg.prefill_chunk} must be a positive "
                f"multiple of block_size={scfg.block_size} (chunk resumes "
                "are block-aligned)")
        if scfg.step_token_budget is not None:
            if scfg.step_token_budget < 1:
                raise ValueError("step_token_budget must be >= 1")
            if chunked and scfg.step_token_budget < scfg.prefill_chunk:
                raise ValueError(
                    f"step_token_budget={scfg.step_token_budget} below "
                    f"prefill_chunk={scfg.prefill_chunk}: no step could "
                    "ever schedule a chunk")
        if scfg.speculative:
            from repro.pim import engine as _engine

            if scfg.draft_k < 1:
                raise ValueError(f"draft_k={scfg.draft_k} must be >= 1")
            if scfg.draft_mode not in _engine.MODES:
                raise ValueError(
                    f"unknown draft_mode {scfg.draft_mode!r}; expected one "
                    f"of {_engine.MODES}")
            if cfg.sliding_window:
                # a windowed slot is a ring: the verify run's writes at
                # pos..pos+k-1 destroy the rows k steps behind the window
                # edge, which a rejected draft would still need — rollback
                # is only free when rejected rows are strictly *ahead* of
                # every live one
                raise ValueError(
                    f"{cfg.name}: speculative decode is incompatible with "
                    "sliding_window (ring writes destroy rows a rejected "
                    "draft must roll back to)")
            if cfg.has_recurrent_blocks:
                raise ValueError(
                    f"{cfg.name}: speculative decode is incompatible with "
                    "SSM/xLSTM blocks (recurrent state cannot roll back a "
                    "rejected draft)")
            if cfg.n_experts:
                raise ValueError(
                    f"{cfg.name}: speculative decode is incompatible with "
                    "MoE (capacity dropping couples the verify run's "
                    "positions, breaking bit-exact acceptance)")
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.clock = clock
        self.queue = AdmissionQueue(policy=scfg.queue_policy)
        self.metrics = ServingMetrics()
        # autotune warmup: plan every linear shape at the decode batch
        # bucket before the first prefill, so steady-state decode runs the
        # tuned configuration from token one.  Table hits (a reloaded
        # tuning table) make this free.
        self.autotuned_shapes = 0
        if scfg.autotune and cfg.pim_mode == "pim_sim":
            from repro.pim import autotune as _autotune

            _autotune.enable(True)
            self.autotuned_shapes = _autotune.plan_for_params(
                params, scfg.max_batch, trials=scfg.autotune_trials)
        # sliding-window slots are rings over their block list — only the
        # paged pool can size prefill capacity min(prompt, window), so
        # windowed configs page unconditionally; chunked/packed prefill
        # scatter per-chunk/per-segment caches at block offsets, which
        # only the paged layout supports
        if (scfg.paged or scfg.prefix_cache or cfg.sliding_window
                or chunked or scfg.packed_prefill):
            self.pool = PagedCachePool(
                cfg, scfg.max_batch, cfg.max_seq_len,
                block_size=scfg.block_size, num_blocks=scfg.num_blocks,
                mesh=mesh, prefix_cache=scfg.prefix_cache)
        else:
            self.pool = CachePool(cfg, scfg.max_batch, cfg.max_seq_len,
                                  mesh=mesh)
        self._prefix_on = (scfg.prefix_cache
                           and getattr(self.pool, "prefix", None) is not None)

        B = scfg.max_batch
        self._slot_rid = np.full(B, -1, np.int64)
        self._pos = np.zeros(B, np.int32)
        self._tokens = np.zeros((B, 1), np.int32)
        self._remaining = np.zeros(B, np.int64)
        self._outputs: Dict[int, List[int]] = {}
        self._active_req: Dict[int, Request] = {}   # rid -> in-slot request
        # chunked prefill: a slot can be occupied but still mid-prefill —
        # excluded from the decode batch until its last chunk lands
        self._prefilling = np.zeros(B, bool)
        self._prefill_done = np.zeros(B, np.int64)  # prompt tokens cached
        # dedupe: one deferral count per request per wait, tracked as a
        # set — under SJF the head changes identity between steps, so a
        # single "last deferred rid" would recount the original head when
        # it defers again after an interloper
        self._deferred_rids: set = set()
        self._plain_decode_traces = 0   # retraces of the plain (B, 1) jit

        # speculative decode: active only when a round can beat plain
        # decode — draft_k=1 drafts nothing (the verify step *is* plain
        # decode) and a draft mode equal to the verify mode would run the
        # full-price model twice per token; both short-circuit to the
        # plain path below, bit-identical by construction
        self._spec: Optional["SpeculativeDecoder"] = None
        if (scfg.speculative and scfg.draft_k > 1
                and scfg.draft_mode != (cfg.pim_mode or "xla")):
            from repro.serving.speculative import SpeculativeDecoder

            self._spec = SpeculativeDecoder(cfg, scfg.draft_mode,
                                            scfg.draft_k)

        def _step(p, tokens, pos, active, caches, tables):
            # tables is None (an empty pytree to jit) for the contiguous pool
            self._plain_decode_traces += 1
            return M.decode_step_slots(p, tokens, pos, active, caches, cfg,
                                       block_tables=tables)

        self._decode = jax.jit(_step)
        self._prefill = jax.jit(
            lambda p, toks, li: M.prefill(p, {"tokens": toks}, cfg,
                                          last_index=li))
        # tail-resume prefill against a mapped prefix; retraces once per
        # (prefix length, tail bucket) shape pair — chunked prefill rides
        # the same jit (one trace per chunk boundary, covered by warmup)
        self._prefill_resume = jax.jit(
            lambda p, toks, li, px: M.prefill(p, {"tokens": toks}, cfg,
                                              last_index=li, prefix=px))
        # packed prefill: one call covers a burst of short prompts;
        # retraces once per packed stream length (K is pinned to max_batch)
        self._prefill_packed = jax.jit(
            lambda p, toks, pos, seg, li: M.prefill_packed(
                p, toks, pos, seg, li, cfg))

    # ------------------------------------------------------------------

    @property
    def active_slots(self) -> np.ndarray:
        """Occupied slots — including mid-prefill ones (they hold blocks
        and count toward load; the router's least-loaded signal and
        ``drain()`` must see them)."""
        return self._slot_rid >= 0

    @property
    def decoding_slots(self) -> np.ndarray:
        """Occupied slots past prefill: the decode step's active mask."""
        return self.active_slots & ~self._prefilling

    @property
    def n_active(self) -> int:
        return int(self.active_slots.sum())

    @property
    def decode_traces(self) -> int:
        """Retraces of the batched decode step — the plain (B, 1) jit,
        plus, under speculation, the (B, k) verify jit (which *is* the
        decode step there).  Summing keeps both visible: if some future
        path ever mixes plain and speculative rounds, a retrace of either
        jit trips the existing "exactly one trace" assertions instead of
        being masked.  Tests pin this to 1."""
        verify = self._spec.verify_traces if self._spec is not None else 0
        return self._plain_decode_traces + verify

    @property
    def draft_traces(self) -> int:
        """Retraces of the speculative draft step (0 when speculation is
        off or short-circuited); the verify step's retraces land in
        ``decode_traces`` — it *is* the decode step, and tests pin both
        to one."""
        return self._spec.draft_traces if self._spec is not None else 0

    def submit(self, prompt, max_new_tokens: int, *,
               arrival_time: Optional[float] = None) -> int:
        """Enqueue one request; returns its rid."""
        req = make_request(prompt, max_new_tokens,
                           arrival_time=self.clock() if arrival_time is None
                           else arrival_time)
        return self.submit_request(req)

    def validate_request(self, req: Request) -> None:
        """Raise if ``req`` can never be served by this scheduler's pool
        (the router runs the same check once, globally, at submit)."""
        plen = req.prompt.shape[0]
        cap = self.pool.max_tokens      # None: windowed ring, unbounded
        if cap is not None and plen + req.max_new_tokens > cap:
            raise ValueError(
                f"request {req.rid}: prompt {plen} + budget "
                f"{req.max_new_tokens} exceeds cache capacity {cap}")
        if self.pool.paged:
            # a need beyond the whole pool would defer forever, not
            # eventually: back-pressure only works for satisfiable
            # requests.  ``blocks_needed`` is sliding-window-aware: a
            # windowed slot is a ring capped at ceil(window/block_size)
            # blocks (``kv_blocks_for`` clamps to it), so a long windowed
            # request — prompt + budget far past ``num_blocks *
            # block_size`` — budgets only its ring here, never its raw
            # token count (regression-locked in test_serving_chunked)
            need = self.pool.blocks_needed(plen + req.max_new_tokens)
            if need > self.pool.num_blocks - 1:
                raise ValueError(
                    f"request {req.rid}: needs {need} KV blocks but the "
                    f"pool holds {self.pool.num_blocks - 1}")

    def submit_request(self, req: Request) -> int:
        self.validate_request(req)
        self.queue.submit(req)
        self.metrics.on_submit(req.rid, req.arrival_time)
        return req.rid

    # ------------------------------------------------------------------

    def _bucket(self, plen: int) -> int:
        bq = max(1, self.scfg.prompt_bucket)
        b = ((plen + bq - 1) // bq) * bq
        w = self.cfg.sliding_window
        if w:
            # bucket padding past the window would evict real KV from the
            # prefill ring; prompts that can't bucket inside it run unpadded
            return b if b <= w else plen
        return min(b, self.pool.max_len)

    def _bucket_tail(self, tlen: int, m: int) -> int:
        """Bucket for the divergent tail of a trie-hit prompt.  The tail
        prefill emits an unpadded-to-capacity cache and the pool masks pad
        positions out at scatter time, so — unlike cold windowed prefill —
        padding past the window is harmless here; only the slot's logical
        capacity beyond the prefix bounds it."""
        bq = max(1, self.scfg.prompt_bucket)
        b = ((tlen + bq - 1) // bq) * bq
        cap = getattr(self.pool, "lcap", self.pool.max_len) - m
        return max(tlen, min(b, cap))

    def _finish(self, slot: int, now: float) -> None:
        rid = int(self._slot_rid[slot])
        self.metrics.on_finish(rid, now)
        self._active_req.pop(rid, None)
        self._slot_rid[slot] = -1
        self.pool.evict(slot)

    def _dense_prefill_ok(self, plen: int) -> bool:
        """Whether chunked/packed prefill may serve a ``plen`` prompt: a
        windowed slot's ring layout equals the dense layout only while the
        prompt fits inside the window — past it, the cold whole-prefill
        path (which lays the ring out directly) is the only exact one."""
        w = self.cfg.sliding_window
        return not w or plen <= w

    def _packable(self, req: Request, m: int) -> bool:
        """Whether ``req`` may join a packed prefill: trie misses only
        (hits resume, they don't prefill the prompt), short enough to stay
        inside one flash key block, below the chunking threshold (long
        prompts chunk instead), and — windowed — inside the window."""
        if m:
            return False
        plen = req.prompt.shape[0]
        b = self._bucket(plen)
        chunk = self.scfg.prefill_chunk
        if chunk is not None and plen > chunk:
            return False
        if b > _PACK_MAX_TOKENS:
            return False
        w = self.cfg.sliding_window
        return not w or b <= w

    def _collect_pack(self, now: float, n_free: int,
                      spent: int) -> List[Request]:
        """Pop the (pre-validated, packable) head plus every immediately
        following packable head that fits the pack — stopping at the first
        ineligible one (no skip-ahead), at ``n_free`` slots, at the flash
        block cap, at the step budget, or when the free list can't cover
        the *cumulative* reservation (``can_admit(extra_reserved=)``)."""
        budget = self.scfg.step_token_budget
        first = self.queue.pop(now)
        self._deferred_rids.discard(first.rid)
        pack = [first]
        total = self._bucket(first.prompt.shape[0])
        reserved = self.pool.blocks_needed(
            first.prompt.shape[0] + first.max_new_tokens)
        while len(pack) < n_free:
            head = self.queue.peek(now)
            if head is None or head.arrival_time > now:
                break
            if self._prefix_on:
                m = self.pool.prefix_match(head.prompt)[0]
            else:
                m = 0
            if not self._packable(head, m):
                break
            b = self._bucket(head.prompt.shape[0])
            if total + b > _PACK_MAX_TOKENS:
                break
            if budget is not None and spent + total + b > budget:
                break
            n_tok = head.prompt.shape[0] + head.max_new_tokens
            if not self.pool.can_admit(n_tok, extra_reserved=reserved):
                break
            req = self.queue.pop(now)
            assert req is head, "peek/pop selection must agree"
            self._deferred_rids.discard(req.rid)
            pack.append(req)
            total += b
            reserved += self.pool.blocks_needed(n_tok)
        return pack

    def _admit_packed(self, pack: List[Request], free: List[int],
                      emitted: List[Tuple[int, int]]) -> int:
        """One ``prefill_packed`` call for the whole pack; returns the
        packed stream length (the step-budget cost).  Segment ``i``'s
        prompt occupies ``starts[i]..starts[i]+plen-1`` of the stream
        (bucket-aligned widths — matching the shapes unpacked bucketed
        prefill runs keeps the reductions bit-identical), and its cache is
        unpacked by ``PagedCachePool.admit(start=starts[i])``."""
        widths = [self._bucket(r.prompt.shape[0]) for r in pack]
        starts = np.concatenate([[0], np.cumsum(widths[:-1])]).astype(int)
        L = int(sum(widths))
        toks = np.full((1, L), self.scfg.pad_id, np.int32)
        pos = np.zeros(L, np.int32)
        seg = np.full(L, -1, np.int32)   # padding matches no real segment
        last = np.zeros(self.scfg.max_batch, np.int32)  # K pinned: unused
        #   entries read index 0 and are ignored host-side
        for i, (r, s0, w) in enumerate(zip(pack, starts, widths)):
            plen = r.prompt.shape[0]
            toks[0, s0:s0 + plen] = r.prompt
            pos[s0:s0 + w] = np.arange(w)
            seg[s0:s0 + plen] = i
            last[i] = s0 + plen - 1
        logits, cache = self._prefill_packed(
            self.params, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(seg), jnp.asarray(last))
        self.metrics.on_packed_prefill()
        firsts = np.asarray(jnp.argmax(logits, -1))
        now = self.clock()
        for i, r in enumerate(pack):
            plen = r.prompt.shape[0]
            first = int(firsts[i])
            self.metrics.on_admit(r.rid, now)
            self.metrics.on_token(r.rid, now)
            self._outputs[r.rid] = [first]
            emitted.append((r.rid, first))
            if r.max_new_tokens <= 1 or first == self.scfg.eos_id:
                # finished at admit: never touches a slot
                self.metrics.on_finish(r.rid, now)
                continue
            slot = int(free.pop(0))
            self.pool.admit(slot, cache, plen, plen + r.max_new_tokens,
                            prompt=r.prompt if self._prefix_on else None,
                            start=int(starts[i]))
            self._slot_rid[slot] = r.rid
            self._active_req[r.rid] = r
            self._tokens[slot, 0] = first
            self._pos[slot] = plen
            self._remaining[slot] = r.max_new_tokens - 1
        return L

    def _begin_chunked(self, slot: int, req: Request, m: int,
                       pblocks: List[int]) -> None:
        """Admit ``req``'s *first* prefill chunk into ``slot`` and mark it
        mid-prefill.  The pool reserves the request's full block need up
        front (later chunks extend in place, they never allocate), so a
        mid-prefill slot can always finish without deferring."""
        chunk = self.scfg.prefill_chunk
        plen = req.prompt.shape[0]
        n_tok = plen + req.max_new_tokens
        if m:
            bucket = self._bucket_tail(chunk, m)
            toks = np.full((1, bucket), self.scfg.pad_id, np.int32)
            toks[0, :chunk] = req.prompt[m:m + chunk]
            _, cache = self._prefill_resume(
                self.params, jnp.asarray(toks),
                jnp.asarray([chunk - 1], jnp.int32),
                self.pool.read_prefix(pblocks))
            # prompt=None: a half-written prompt must not enter the trie —
            # registration is deferred to the last chunk
            self.pool.admit(slot, cache, m + chunk, n_tok,
                            prefix_blocks=pblocks)
        else:
            bucket = self._bucket(chunk)
            toks = np.full((1, bucket), self.scfg.pad_id, np.int32)
            toks[0, :chunk] = req.prompt[:chunk]
            _, cache = self._prefill(self.params, jnp.asarray(toks),
                                     jnp.asarray([chunk - 1], jnp.int32))
            self.pool.admit(slot, cache, chunk, n_tok)
        self.metrics.on_admit(req.rid, self.clock(), prefix_tokens=m)
        self.metrics.on_prefill_chunk()
        self._slot_rid[slot] = req.rid
        self._active_req[req.rid] = req
        self._prefilling[slot] = True
        self._prefill_done[slot] = m + chunk
        # _pos tracks tokens written; the decode step's garbage write for
        # this (inactive) slot lands at _pos — the exact position the next
        # chunk's extend overwrites with real KV
        self._pos[slot] = m + chunk
        self._tokens[slot, 0] = 0
        self._remaining[slot] = 0

    def _chunk_step(self, slot: int, req: Request, done: int, tlen: int,
                    emitted: List[Tuple[int, int]]) -> None:
        """Resume one more chunk of a mid-prefill slot: the slot's own
        written blocks are read back as the prefix (same jit as trie-hit
        tail resume), the chunk's tail cache extends them in place, and
        the *last* chunk emits the first token — exactly what whole
        prefill would have produced."""
        plen = req.prompt.shape[0]
        new_len = done + tlen
        bucket = self._bucket_tail(tlen, done)
        toks = np.full((1, bucket), self.scfg.pad_id, np.int32)
        toks[0, :tlen] = req.prompt[done:new_len]
        prefix = self.pool.read_prefix(
            self.pool.slot_blocks(slot)[:done // self.pool.block_size])
        logits, cache = self._prefill_resume(
            self.params, jnp.asarray(toks),
            jnp.asarray([tlen - 1], jnp.int32), prefix)
        self.pool.extend(slot, cache, done, new_len)
        self.metrics.on_prefill_chunk()
        self._prefill_done[slot] = new_len
        self._pos[slot] = new_len
        if new_len < plen:
            return
        # prompt fully cached: first token (the request's TTFT) and into
        # the decode batch
        first = int(np.asarray(jnp.argmax(logits, -1))[0])
        now = self.clock()
        self.metrics.on_token(req.rid, now)
        self._outputs[req.rid] = [first]
        emitted.append((req.rid, first))
        self._prefilling[slot] = False
        self._prefill_done[slot] = 0
        if req.max_new_tokens <= 1 or first == self.scfg.eos_id:
            self.metrics.on_finish(req.rid, now)
            self._active_req.pop(req.rid, None)
            self._slot_rid[slot] = -1
            self.pool.evict(slot)
            return
        if self._prefix_on:
            # deferred trie registration (admit passed prompt=None)
            self.pool.register_prefix(slot, req.prompt, plen,
                                      plen + req.max_new_tokens)
        self._tokens[slot, 0] = first
        self._remaining[slot] = req.max_new_tokens - 1

    def _continue_prefills(self, emitted: List[Tuple[int, int]]) -> int:
        """Advance every mid-prefill slot by one chunk (slot order);
        returns the prefill tokens spent.  The first chunk of a step is
        always allowed — further ones only while they fit the step
        budget, so one long prompt cannot starve the decode batch and two
        long prompts cannot starve each other."""
        if not self._prefilling.any():
            return 0
        spent = 0
        budget = self.scfg.step_token_budget
        chunk = self.scfg.prefill_chunk
        for slot in np.flatnonzero(self._prefilling):
            req = self._active_req[int(self._slot_rid[slot])]
            done = int(self._prefill_done[slot])
            tlen = min(chunk, req.prompt.shape[0] - done)
            if budget is not None and spent and spent + tlen > budget:
                break
            self._chunk_step(int(slot), req, done, tlen, emitted)
            spent += tlen
        return spent

    def _admit(self, emitted: List[Tuple[int, int]], spent: int) -> int:
        """Backfill free slots from the queue; appends (rid, token) firsts
        to ``emitted`` and returns the updated prefill-token spend.

        FIFO with back-pressure: when the paged pool's free list cannot
        cover the head request's block reservation, admission *defers*
        (the head stays queued — no skip-ahead, no crash) until evictions
        return enough blocks.  With ``prefix_cache``, the head's prompt is
        first walked through the pool's trie: matched blocks are mapped by
        reference and only the divergent tail is prefilled.  A request
        that finishes at admit (budget 1, or EOS as its first token) never
        occupies a slot, so the *same* slot is retried with the next
        queued request — a burst of one-token requests drains in a single
        scheduler step instead of one per step.

        With ``prefill_chunk``, a prompt whose (post-trie-match) tail
        exceeds the chunk admits its first chunk only and parks the slot
        mid-prefill; with ``packed_prefill``, a run of packable heads is
        popped into one ``prefill_packed`` call.  ``step_token_budget``
        stops further admissions once this step's prefill spend (chunks
        resumed + prompts admitted, real token counts) would exceed it —
        the first work item of a step is always allowed.
        """
        budget = self.scfg.step_token_budget
        free = [int(s) for s in np.flatnonzero(~self.active_slots)]
        while free:
            now = self.clock()
            head = self.queue.peek(now)
            if head is None or head.arrival_time > now:
                break
            plen = head.prompt.shape[0]
            n_tok = plen + head.max_new_tokens
            if self._prefix_on:
                m, pblocks = self.pool.prefix_match(head.prompt)
                ok = self.pool.can_admit(n_tok, prefix_tokens=m)
            else:
                m, pblocks = 0, []
                ok = self.pool.can_admit(n_tok)
            if not ok:
                if head.rid not in self._deferred_rids:  # count requests,
                    self._deferred_rids.add(head.rid)    # not steps waiting
                    self.metrics.on_deferred_admit()
                break
            chunk = self.scfg.prefill_chunk
            chunked = (chunk is not None and plen - m > chunk
                       and self._dense_prefill_ok(plen))
            if chunked:
                cost = chunk
            elif m:
                cost = self._bucket_tail(plen - m, m)
            else:
                cost = self._bucket(plen)
            if budget is not None and spent and spent + cost > budget:
                break
            if (self.scfg.packed_prefill and not chunked
                    and self._packable(head, m)):
                pack = self._collect_pack(now, len(free), spent)
                if len(pack) > 1:
                    spent += self._admit_packed(pack, free, emitted)
                    continue
                req = pack[0]   # a pack of one admits like any other
            else:
                req = self.queue.pop(now)
                assert req is head, "peek/pop selection must agree"
                self._deferred_rids.discard(req.rid)  # admitted: a future
                #   deferral of this rid is a new event
            spent += cost
            if chunked:
                self._begin_chunked(free.pop(0), req, m, pblocks)
                continue
            plen = req.prompt.shape[0]
            if m:
                tlen = plen - m
                bucket = self._bucket_tail(tlen, m)
                toks = np.full((1, bucket), self.scfg.pad_id, np.int32)
                toks[0, :tlen] = req.prompt[m:]
                logits, cache = self._prefill_resume(
                    self.params, jnp.asarray(toks),
                    jnp.asarray([tlen - 1], jnp.int32),
                    self.pool.read_prefix(pblocks))
            else:
                bucket = self._bucket(plen)
                toks = np.full((1, bucket), self.scfg.pad_id, np.int32)
                toks[0, :plen] = req.prompt
                logits, cache = self._prefill(
                    self.params, jnp.asarray(toks),
                    jnp.asarray([plen - 1], jnp.int32))
            first = int(np.asarray(jnp.argmax(logits, -1))[0])
            now = self.clock()
            self.metrics.on_admit(req.rid, now, prefix_tokens=m)
            self.metrics.on_token(req.rid, now)
            self._outputs[req.rid] = [first]
            emitted.append((req.rid, first))
            done = (req.max_new_tokens <= 1
                    or first == self.scfg.eos_id)
            if done:
                # finished at admit: never touches a slot (the cache write
                # would only leave stale KV in a still-free slot); retry
                # the same slot with the next queued request
                self.metrics.on_finish(req.rid, now)
                continue
            slot = free.pop(0)
            if self._prefix_on:
                self.pool.admit(slot, cache, plen, n_tok,
                                prompt=req.prompt, prefix_blocks=pblocks)
            else:
                self.pool.admit(slot, cache, plen, n_tok)
            self._slot_rid[slot] = req.rid
            self._active_req[req.rid] = req
            self._tokens[slot, 0] = first
            self._pos[slot] = plen
            self._remaining[slot] = req.max_new_tokens - 1
        return spent

    def step(self) -> List[Tuple[int, int]]:
        """One scheduler step: resume mid-prefill chunks, backfill, then
        one batched decode step over the decoding slots.

        Returns the (rid, token) pairs emitted this step.
        """
        emitted: List[Tuple[int, int]] = []
        spent = self._continue_prefills(emitted)
        self._admit(emitted, spent)
        active = self.decoding_slots
        if active.any():
            if self.pool.paged and self.pool.has_shared:
                # copy-on-write: each active slot writes its KV at
                # _pos.._pos+width-1 this step (width > 1 under
                # speculation: draft and verify both write the whole run)
                # — upgrade any shared target block to a private copy
                # first so sibling slots / the prefix index keep their
                # bits (cheap host check when nothing is shared;
                # ensure_writable no-ops past the slot's reservation)
                width = self._spec.k if self._spec is not None else 1
                for slot in np.flatnonzero(active):
                    for i in range(width):
                        self.pool.ensure_writable(int(slot),
                                                  int(self._pos[slot]) + i)
            if self._spec is not None:
                self._spec_step(active, emitted)
            else:
                next_tok, _, new_caches = self._decode(
                    self.params, jnp.asarray(self._tokens),
                    jnp.asarray(self._pos), jnp.asarray(active),
                    self.pool.caches, self.pool.block_tables)
                self.pool.caches = new_caches
                toks = np.asarray(next_tok)
                now = self.clock()
                for slot in np.flatnonzero(active):
                    rid = int(self._slot_rid[slot])
                    tok = int(toks[slot, 0])
                    self._outputs[rid].append(tok)
                    self.metrics.on_token(rid, now)
                    emitted.append((rid, tok))
                    self._tokens[slot, 0] = tok
                    self._pos[slot] += 1
                    self._remaining[slot] -= 1
                    if (self._remaining[slot] <= 0
                            or tok == self.scfg.eos_id):
                        self._finish(int(slot), now)
        self.metrics.sample_queue(len(self.queue), self.n_active)
        self.metrics.sample_pool(self.pool.stats(), self._tokens_live())
        return emitted

    def _spec_step(self, active: np.ndarray,
                   emitted: List[Tuple[int, int]]) -> None:
        """One speculative round over the decoding slots: draft ``k - 1``
        tokens cheaply, verify the whole run in one batched step, commit
        the longest exactly-matching prefix per slot (clipped at the
        request's budget and EOS), and roll the rest back by simply not
        advancing ``_pos`` past the accepted rows — the rejected rows'
        garbage KV sits strictly ahead of every live position, where the
        next round's writes land before any masked read can see it.  The
        committed tokens are exactly the greedy chain plain decode would
        emit, so generations stay bit-identical per mode.
        """
        from repro.serving.speculative import accept_length

        spec = self._spec
        toks_run, vt, new_caches = spec.run_round(
            self.params, self._tokens, self._pos, active,
            self.pool.caches, self.pool.block_tables)
        self.pool.caches = new_caches
        now = self.clock()
        for slot in np.flatnonzero(active):
            rid = int(self._slot_rid[slot])
            n_acc = accept_length(toks_run[slot], vt[slot])
            emit = 0
            for i in range(n_acc):
                tok = int(vt[slot, i])
                self._outputs[rid].append(tok)
                self.metrics.on_token(rid, now)
                emitted.append((rid, tok))
                emit = i + 1
                if (self._remaining[slot] - emit <= 0
                        or tok == self.scfg.eos_id):
                    break
            last = int(vt[slot, emit - 1])
            self._tokens[slot, 0] = last
            self._pos[slot] += emit
            self._remaining[slot] -= emit
            self.metrics.on_spec_round(drafted=spec.k - 1, verified=spec.k,
                                       accepted=emit, accept_len=n_acc)
            if self._remaining[slot] <= 0 or last == self.scfg.eos_id:
                self._finish(int(slot), now)

    def output(self, rid: int) -> np.ndarray:
        """Generated tokens recorded so far for ``rid`` (router harvest)."""
        return np.asarray(self._outputs[rid], np.int32)

    def drain(self) -> List[Request]:
        """Evict every in-flight request and empty the queue; returns the
        unfinished :class:`Request`s (original ``arrival_time`` intact) so
        a router can requeue them elsewhere.  Partial outputs are
        discarded — a migrated request restarts from its prompt, and
        greedy decode makes the rerun bit-identical.
        """
        out: List[Request] = []
        for slot in np.flatnonzero(self.active_slots):
            rid = int(self._slot_rid[slot])
            req = self._active_req.pop(rid)
            self._outputs.pop(rid, None)
            self._slot_rid[slot] = -1
            self._remaining[slot] = 0
            self._prefilling[slot] = False   # a mid-prefill slot drains
            self._prefill_done[slot] = 0     # like any other: full restart
            self.pool.evict(int(slot))
            out.append(req)
        self._deferred_rids.clear()
        out.extend(self.queue.clear())
        return out

    def _tokens_live(self) -> float:
        """Positions actually written across active slots (for the
        internal-fragmentation metric; ``_pos`` is the next write index,
        clipped to the per-slot logical capacity for windowed rings)."""
        cap = getattr(self.pool, "lcap", self.pool.max_len)
        return float(np.minimum(self._pos[self.active_slots], cap).sum())

    def run(self) -> Dict[int, np.ndarray]:
        """Step until the queue drains and every slot finishes.

        Returns rid -> generated tokens (prefill's first token included).
        With an injected clock that does not advance on its own, drive
        ``step()`` manually instead of waiting on future arrivals here —
        ``run`` detects a non-advancing clock and raises rather than spin.
        """
        stalls = 0
        while len(self.queue) or self.active_slots.any():
            progressed = bool(self.step())
            if progressed or self.active_slots.any():
                stalls = 0
                continue
            # idle: head request hasn't arrived yet on this clock
            head = self.queue.peek(self.clock())
            if head is None:
                continue
            stalls = _idle_sleep(self.clock, head.arrival_time, stalls)
            if stalls > 1000:
                raise RuntimeError(
                    "run(): clock is not advancing while requests wait "
                    "to arrive; with an injected test clock, advance it "
                    "and call step() yourself")
        return {rid: np.asarray(toks, np.int32)
                for rid, toks in self._outputs.items()}
