"""Continuous-batching decode scheduler.

The runtime keeps one fixed-size decode batch of ``max_batch`` *slots*
stepping together under a single jitted ``decode_step_slots`` — per-slot
positions, per-slot ``cache_len`` masks, and an active-slot mask mean the
step's shapes never change, so steady-state decode **never recompiles** no
matter how requests churn (``decode_traces`` counts retraces; tests pin it
to 1).  Each scheduler step:

1. *backfill* — every free slot is filled from the admission queue
   (lowest-numbered slot first, FIFO requests): the prompt is right-padded
   to a ``prompt_bucket`` multiple, prefilled in one shot (logits read at
   the true last token via ``prefill(last_index=...)``), the resulting
   cache written into the slot of the persistent :class:`CachePool`, and
   the first token emitted — that's the request's TTFT.
2. *decode* — one batched step advances every active slot by one token;
   finished slots (budget exhausted or EOS) are evicted and become
   backfill targets on the next step.

Bucketed prefill retraces once per distinct bucket length (a handful of
compiles, amortized over the run) and is exact for attention stacks; for
recurrent blocks (Mamba/xLSTM) set ``prompt_bucket=1`` so prompts run
unpadded.  With ``ServingConfig(paged=True)`` the KV pool is block-paged
(see :mod:`repro.serving.cache`): admits reserve blocks from a free list
and *defer* when it runs short, evictions return blocks, and the decode
step reads through a fixed-shape block table — still exactly one trace.
Sliding-window configs serve as rings over their block lists and enable
paging automatically (prompts bucket only while the padded length stays
inside the window).  ``ServingConfig(prefix_cache=True)`` additionally
attaches the pool's prefix index: admission walks a trie over the prompt
tokens, maps every fully matched block into the slot by reference, and
prefills only the divergent tail (``prefill(prefix=...)`` resumed at the
block-aligned match length) — on shared-system-prompt traces this turns
most of the prompt's TTFT cost into one block-table write.  Shared blocks
are copy-on-write: before each decode step the scheduler upgrades any
slot about to write into one (``ensure_writable``), so trie hits, forks,
and windowed ring wraps never corrupt other referents.  Tail prefill
retraces once per (match length, tail bucket) pair — cheap when prompts
share a few long system prefixes, which is the workload prefix caching
is for.

The scheduler is **single-replica-ignorant**: it admits in whatever
order its :class:`AdmissionQueue` policy picks (``queue_policy=`` —
FIFO or shortest-prompt-first), and the only multi-replica hooks it
exposes are ``validate_request``/``submit_request`` (router-side global
admission), ``drain()`` (evict all in-flight work and return the
unfinished :class:`Request`s for requeue elsewhere) and ``output(rid)``
(harvest finished tokens).  Everything fleet-shaped — dispatch,
health, respawn — lives one level up in :mod:`repro.serving.router`.

Under ``pim_mode="pim_sim"`` the decode step's
crossbar GEMMs
run through the engine's persistent :class:`ExecutionSession` pool:
crossbar state is uploaded once per artifact and only operand columns
stream per token.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model_lib as M
from repro.models.config import ModelConfig
from repro.serving.cache import CachePool, PagedCachePool
from repro.serving.metrics import ServingMetrics
from repro.serving.queue import AdmissionQueue, Request, make_request

__all__ = ["ServingConfig", "Scheduler"]


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Knobs of the continuous-batching runtime.  Per-slot cache capacity
    is ``cfg.max_seq_len`` (prefill emits caches at exactly that capacity,
    so the pool cannot be sized independently).

    ``paged=True`` swaps the slot-contiguous pool for the block-paged
    :class:`PagedCachePool` (``block_size`` tokens per block;
    ``num_blocks`` physical blocks, default full parity + trash block):
    admits reserve exactly the request's block need from a free list and
    defer when it runs short.  Sliding-window configs require paging (a
    windowed slot is a ring over its block list) and enable it
    automatically.

    ``autotune=True`` runs the partition autotuner at construction when the
    model decodes on the crossbar simulator (``cfg.pim_mode == "pim_sim"``):
    every distinct linear shape in the parameter tree is planned at the
    decode batch bucket (``pim.autotune.plan_for_params``) and ambient plan
    lookup is switched on, so the decode loop's GEMMs run the tuned
    configuration.  Shapes already in the tuner table (e.g. reloaded via
    ``serve.py --autotune-table``) are warmup hits — no re-search.
    """

    max_batch: int = 4          # decode slots
    prompt_bucket: int = 16     # prompts pad up to a multiple of this
    pad_id: int = 0
    eos_id: Optional[int] = None   # stop early on this token (None: never)
    paged: bool = False         # block-paged KV pool
    block_size: int = 16        # tokens per KV block (paged pool)
    num_blocks: Optional[int] = None   # physical blocks (None: full parity)
    prefix_cache: bool = False  # trie prefix sharing + COW (implies paged)
    queue_policy: str = "fifo"  # admission order: "fifo" | "sjf"
    autotune: bool = False      # plan crossbar GEMMs at warmup (pim_sim)
    autotune_trials: int = 1    # timed trials per candidate during warmup


class Scheduler:
    """Continuous-batching scheduler over a persistent cache pool."""

    def __init__(self, params, cfg: ModelConfig, scfg: ServingConfig, *,
                 mesh=None, clock=time.monotonic):
        if scfg.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        # enc-dec / vision prefill needs frames/patches carried per request
        # and their cross-attention caches pooled; not wired up yet.
        if cfg.is_encoder_decoder or cfg.vision_dim:
            raise NotImplementedError(
                f"{cfg.name}: multimodal serving (frames/patches on the "
                "request) is a ROADMAP follow-on; decoder-only stacks only")
        # recurrent state folds right-padding into the prefix: bucketed
        # prefill would silently change generations
        if cfg.has_recurrent_blocks and scfg.prompt_bucket != 1:
            raise ValueError(
                f"{cfg.name}: SSM/xLSTM blocks require prompt_bucket=1 "
                "(padding folds into the recurrent state)")
        if scfg.prefix_cache:
            # prefix sharing assumes a token's KV depends only on the
            # tokens before it — recurrent state folds the whole prefix
            # into one vector (nothing block-separable to share), and MoE
            # capacity dropping makes each token's output depend on its
            # *batch-mates*, so identical prefixes need not produce
            # identical KV
            if cfg.has_recurrent_blocks:
                raise ValueError(
                    f"{cfg.name}: prefix_cache is incompatible with "
                    "SSM/xLSTM blocks (recurrent state is not "
                    "prefix-separable)")
            if cfg.n_experts:
                raise ValueError(
                    f"{cfg.name}: prefix_cache is incompatible with MoE "
                    "(capacity dropping couples a token's KV to its "
                    "batch-mates)")
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.clock = clock
        self.queue = AdmissionQueue(policy=scfg.queue_policy)
        self.metrics = ServingMetrics()
        # autotune warmup: plan every linear shape at the decode batch
        # bucket before the first prefill, so steady-state decode runs the
        # tuned configuration from token one.  Table hits (a reloaded
        # tuning table) make this free.
        self.autotuned_shapes = 0
        if scfg.autotune and cfg.pim_mode == "pim_sim":
            from repro.pim import autotune as _autotune

            _autotune.enable(True)
            self.autotuned_shapes = _autotune.plan_for_params(
                params, scfg.max_batch, trials=scfg.autotune_trials)
        # sliding-window slots are rings over their block list — only the
        # paged pool can size prefill capacity min(prompt, window), so
        # windowed configs page unconditionally
        if scfg.paged or scfg.prefix_cache or cfg.sliding_window:
            self.pool = PagedCachePool(
                cfg, scfg.max_batch, cfg.max_seq_len,
                block_size=scfg.block_size, num_blocks=scfg.num_blocks,
                mesh=mesh, prefix_cache=scfg.prefix_cache)
        else:
            self.pool = CachePool(cfg, scfg.max_batch, cfg.max_seq_len,
                                  mesh=mesh)
        self._prefix_on = (scfg.prefix_cache
                           and getattr(self.pool, "prefix", None) is not None)

        B = scfg.max_batch
        self._slot_rid = np.full(B, -1, np.int64)
        self._pos = np.zeros(B, np.int32)
        self._tokens = np.zeros((B, 1), np.int32)
        self._remaining = np.zeros(B, np.int64)
        self._outputs: Dict[int, List[int]] = {}
        self._active_req: Dict[int, Request] = {}   # rid -> in-slot request
        self._deferred_rid = -1     # dedupe: one deferral count per request
        self.decode_traces = 0      # python-body executions == jit retraces

        def _step(p, tokens, pos, active, caches, tables):
            # tables is None (an empty pytree to jit) for the contiguous pool
            self.decode_traces += 1
            return M.decode_step_slots(p, tokens, pos, active, caches, cfg,
                                       block_tables=tables)

        self._decode = jax.jit(_step)
        self._prefill = jax.jit(
            lambda p, toks, li: M.prefill(p, {"tokens": toks}, cfg,
                                          last_index=li))
        # tail-resume prefill against a mapped prefix; retraces once per
        # (prefix length, tail bucket) shape pair
        self._prefill_resume = jax.jit(
            lambda p, toks, li, px: M.prefill(p, {"tokens": toks}, cfg,
                                              last_index=li, prefix=px))

    # ------------------------------------------------------------------

    @property
    def active_slots(self) -> np.ndarray:
        return self._slot_rid >= 0

    @property
    def n_active(self) -> int:
        return int(self.active_slots.sum())

    def submit(self, prompt, max_new_tokens: int, *,
               arrival_time: Optional[float] = None) -> int:
        """Enqueue one request; returns its rid."""
        req = make_request(prompt, max_new_tokens,
                           arrival_time=self.clock() if arrival_time is None
                           else arrival_time)
        return self.submit_request(req)

    def validate_request(self, req: Request) -> None:
        """Raise if ``req`` can never be served by this scheduler's pool
        (the router runs the same check once, globally, at submit)."""
        plen = req.prompt.shape[0]
        cap = self.pool.max_tokens      # None: windowed ring, unbounded
        if cap is not None and plen + req.max_new_tokens > cap:
            raise ValueError(
                f"request {req.rid}: prompt {plen} + budget "
                f"{req.max_new_tokens} exceeds cache capacity {cap}")
        if self.pool.paged:
            # a need beyond the whole pool would defer forever, not
            # eventually: back-pressure only works for satisfiable requests
            need = self.pool.blocks_needed(plen + req.max_new_tokens)
            if need > self.pool.num_blocks - 1:
                raise ValueError(
                    f"request {req.rid}: needs {need} KV blocks but the "
                    f"pool holds {self.pool.num_blocks - 1}")

    def submit_request(self, req: Request) -> int:
        self.validate_request(req)
        self.queue.submit(req)
        self.metrics.on_submit(req.rid, req.arrival_time)
        return req.rid

    # ------------------------------------------------------------------

    def _bucket(self, plen: int) -> int:
        bq = max(1, self.scfg.prompt_bucket)
        b = ((plen + bq - 1) // bq) * bq
        w = self.cfg.sliding_window
        if w:
            # bucket padding past the window would evict real KV from the
            # prefill ring; prompts that can't bucket inside it run unpadded
            return b if b <= w else plen
        return min(b, self.pool.max_len)

    def _bucket_tail(self, tlen: int, m: int) -> int:
        """Bucket for the divergent tail of a trie-hit prompt.  The tail
        prefill emits an unpadded-to-capacity cache and the pool masks pad
        positions out at scatter time, so — unlike cold windowed prefill —
        padding past the window is harmless here; only the slot's logical
        capacity beyond the prefix bounds it."""
        bq = max(1, self.scfg.prompt_bucket)
        b = ((tlen + bq - 1) // bq) * bq
        cap = getattr(self.pool, "lcap", self.pool.max_len) - m
        return max(tlen, min(b, cap))

    def _finish(self, slot: int, now: float) -> None:
        rid = int(self._slot_rid[slot])
        self.metrics.on_finish(rid, now)
        self._active_req.pop(rid, None)
        self._slot_rid[slot] = -1
        self.pool.evict(slot)

    def _admit(self) -> List[Tuple[int, int]]:
        """Backfill free slots from the queue; returns (rid, token) firsts.

        FIFO with back-pressure: when the paged pool's free list cannot
        cover the head request's block reservation, admission *defers*
        (the head stays queued — no skip-ahead, no crash) until evictions
        return enough blocks.  With ``prefix_cache``, the head's prompt is
        first walked through the pool's trie: matched blocks are mapped by
        reference and only the divergent tail is prefilled.  A request
        that finishes at admit (budget 1, or EOS as its first token) never
        occupies a slot, so the *same* slot is retried with the next
        queued request — a burst of one-token requests drains in a single
        scheduler step instead of one per step.
        """
        emitted: List[Tuple[int, int]] = []
        free = iter(np.flatnonzero(~self.active_slots))
        slot = next(free, None)
        while slot is not None:
            now = self.clock()
            head = self.queue.peek(now)
            if head is None or head.arrival_time > now:
                break
            n_tok = head.prompt.shape[0] + head.max_new_tokens
            if self._prefix_on:
                m, pblocks = self.pool.prefix_match(head.prompt)
                ok = self.pool.can_admit(n_tok, prefix_tokens=m)
            else:
                m, pblocks = 0, []
                ok = self.pool.can_admit(n_tok)
            if not ok:
                if head.rid != self._deferred_rid:   # count requests, not
                    self._deferred_rid = head.rid    # ... steps spent waiting
                    self.metrics.on_deferred_admit()
                break
            req = self.queue.pop(now)
            assert req is head, "peek/pop selection must agree"
            self._deferred_rid = -1    # the deferred head (if any) got in;
            #                            the next deferral is a new event
            plen = req.prompt.shape[0]
            if m:
                tlen = plen - m
                bucket = self._bucket_tail(tlen, m)
                toks = np.full((1, bucket), self.scfg.pad_id, np.int32)
                toks[0, :tlen] = req.prompt[m:]
                logits, cache = self._prefill_resume(
                    self.params, jnp.asarray(toks),
                    jnp.asarray([tlen - 1], jnp.int32),
                    self.pool.read_prefix(pblocks))
            else:
                bucket = self._bucket(plen)
                toks = np.full((1, bucket), self.scfg.pad_id, np.int32)
                toks[0, :plen] = req.prompt
                logits, cache = self._prefill(
                    self.params, jnp.asarray(toks),
                    jnp.asarray([plen - 1], jnp.int32))
            first = int(np.asarray(jnp.argmax(logits, -1))[0])
            now = self.clock()
            self.metrics.on_admit(req.rid, now, prefix_tokens=m)
            self.metrics.on_token(req.rid, now)
            self._outputs[req.rid] = [first]
            emitted.append((req.rid, first))
            done = (req.max_new_tokens <= 1
                    or first == self.scfg.eos_id)
            if done:
                # finished at admit: never touches a slot (the cache write
                # would only leave stale KV in a still-free slot); retry
                # the same slot with the next queued request
                self.metrics.on_finish(req.rid, now)
                continue
            if self._prefix_on:
                self.pool.admit(int(slot), cache, plen, n_tok,
                                prompt=req.prompt, prefix_blocks=pblocks)
            else:
                self.pool.admit(int(slot), cache, plen, n_tok)
            self._slot_rid[slot] = req.rid
            self._active_req[req.rid] = req
            self._tokens[slot, 0] = first
            self._pos[slot] = plen
            self._remaining[slot] = req.max_new_tokens - 1
            slot = next(free, None)
        return emitted

    def step(self) -> List[Tuple[int, int]]:
        """One scheduler step: backfill, then one batched decode step.

        Returns the (rid, token) pairs emitted this step.
        """
        emitted = self._admit()
        active = self.active_slots
        if active.any():
            if self.pool.paged and self.pool.has_shared:
                # copy-on-write: each active slot writes its KV at _pos
                # this step — upgrade any shared target block to a private
                # copy first so sibling slots / the prefix index keep
                # their bits (cheap host check when nothing is shared)
                for slot in np.flatnonzero(active):
                    self.pool.ensure_writable(int(slot),
                                              int(self._pos[slot]))
            next_tok, _, new_caches = self._decode(
                self.params, jnp.asarray(self._tokens),
                jnp.asarray(self._pos), jnp.asarray(active),
                self.pool.caches, self.pool.block_tables)
            self.pool.caches = new_caches
            toks = np.asarray(next_tok)
            now = self.clock()
            for slot in np.flatnonzero(active):
                rid = int(self._slot_rid[slot])
                tok = int(toks[slot, 0])
                self._outputs[rid].append(tok)
                self.metrics.on_token(rid, now)
                emitted.append((rid, tok))
                self._tokens[slot, 0] = tok
                self._pos[slot] += 1
                self._remaining[slot] -= 1
                if (self._remaining[slot] <= 0
                        or tok == self.scfg.eos_id):
                    self._finish(int(slot), now)
        self.metrics.sample_queue(len(self.queue), self.n_active)
        self.metrics.sample_pool(self.pool.stats(), self._tokens_live())
        return emitted

    def output(self, rid: int) -> np.ndarray:
        """Generated tokens recorded so far for ``rid`` (router harvest)."""
        return np.asarray(self._outputs[rid], np.int32)

    def drain(self) -> List[Request]:
        """Evict every in-flight request and empty the queue; returns the
        unfinished :class:`Request`s (original ``arrival_time`` intact) so
        a router can requeue them elsewhere.  Partial outputs are
        discarded — a migrated request restarts from its prompt, and
        greedy decode makes the rerun bit-identical.
        """
        out: List[Request] = []
        for slot in np.flatnonzero(self.active_slots):
            rid = int(self._slot_rid[slot])
            req = self._active_req.pop(rid)
            self._outputs.pop(rid, None)
            self._slot_rid[slot] = -1
            self._remaining[slot] = 0
            self.pool.evict(int(slot))
            out.append(req)
        out.extend(self.queue.clear())
        return out

    def _tokens_live(self) -> float:
        """Positions actually written across active slots (for the
        internal-fragmentation metric; ``_pos`` is the next write index,
        clipped to the per-slot logical capacity for windowed rings)."""
        cap = getattr(self.pool, "lcap", self.pool.max_len)
        return float(np.minimum(self._pos[self.active_slots], cap).sum())

    def run(self) -> Dict[int, np.ndarray]:
        """Step until the queue drains and every slot finishes.

        Returns rid -> generated tokens (prefill's first token included).
        With an injected clock that does not advance on its own, drive
        ``step()`` manually instead of waiting on future arrivals here —
        ``run`` detects a non-advancing clock and raises rather than spin.
        """
        stalls = 0
        while len(self.queue) or self.active_slots.any():
            progressed = bool(self.step())
            if progressed or self.active_slots.any():
                stalls = 0
                continue
            # idle: head request hasn't arrived yet on this clock
            head = self.queue.peek(self.clock())
            if head is None:
                continue
            before = self.clock()
            time.sleep(min(max(head.arrival_time - before, 0.0), 1e-3))
            if self.clock() == before:
                stalls += 1
                if stalls > 1000:
                    raise RuntimeError(
                        "run(): clock is not advancing while requests wait "
                        "to arrive; with an injected test clock, advance it "
                        "and call step() yourself")
        return {rid: np.asarray(toks, np.int32)
                for rid, toks in self._outputs.items()}
