"""Serving metrics: per-request latency and aggregate throughput.

Per request the runtime records the standard serving quantities —
TTFT (arrival to first token, which the scheduler emits at prefill) and
TPOT (mean gap between subsequent tokens) — plus the aggregate
tokens/second over the busy window and queue-depth samples taken once per
scheduler step.  Everything is on the scheduler's injected clock, so tests
drive these deterministically with a fake clock.

Under the multi-replica router each replica's scheduler keeps its own
``ServingMetrics``; :meth:`ServingMetrics.merged` folds them (plus the
metrics stashed from killed replicas) into one fleet view — for a request
recorded by several replicas (drained, then re-served) the *finished*
entry wins, so TTFT/queue-wait stay anchored to the original arrival
while token counts come from the replica that completed it.  The router
stamps the merged object with ``router_policy`` /
``rebalanced_requests`` / ``replica_restarts`` / ``per_replica_tok_s``,
which then appear in :meth:`summary`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

__all__ = ["RequestMetrics", "ServingMetrics"]


@dataclasses.dataclass
class RequestMetrics:
    rid: int
    arrival_time: float
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    last_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    n_tokens: int = 0
    prefix_tokens: int = 0      # prompt tokens served from the prefix index

    @property
    def ttft(self) -> float:
        """Time to first token: arrival -> first emitted token."""
        if self.first_token_time is None:
            return math.nan
        return self.first_token_time - self.arrival_time

    @property
    def tpot(self) -> float:
        """Mean time per output token after the first (decode cadence)."""
        if self.last_token_time is None or self.n_tokens < 2:
            return math.nan
        return ((self.last_token_time - self.first_token_time)
                / (self.n_tokens - 1))

    @property
    def queue_wait(self) -> float:
        if self.admit_time is None:
            return math.nan
        return self.admit_time - self.arrival_time


class ServingMetrics:
    """Aggregates RequestMetrics + queue-depth samples into a summary."""

    def __init__(self):
        self.requests: Dict[int, RequestMetrics] = {}
        self.queue_depth_samples: List[int] = []
        self.active_samples: List[int] = []
        self.pool_samples: List[Dict[str, float]] = []
        self.tpot_samples: List[float] = []   # every inter-token gap
        self.deferred_admits = 0
        self.prefill_chunks = 0     # chunked-prefill calls (first + resumed)
        self.packed_prefills = 0    # multi-segment packed prefill calls
        # speculative decode (one on_spec_round per active slot per round):
        # acceptance lengths (pre-clip verify agreement, 1..draft_k) are
        # accumulated as a bounded counter keyed by length — draft_k is
        # small and fixed, so unlike a per-sample list this never grows
        # with server lifetime; drafted/verified/accepted token counters
        # give the draft hit rate and the per-verify-step yield
        self.accept_len_counts: Dict[int, int] = {}
        self.spec_rounds = 0
        self.drafted_tokens = 0     # tokens the cheap draft mode proposed
        self.verified_tokens = 0    # positions the verify step checked
        self.accepted_tokens = 0    # tokens actually committed (clipped)
        # router-level fields; the router stamps these on the merged
        # fleet metrics (router_policy None => single-scheduler summary)
        self.router_policy: Optional[str] = None
        self.rebalanced_requests = 0
        self.replica_restarts = 0
        self.per_replica_tok_s: Dict[int, float] = {}

    def on_submit(self, rid: int, now: float) -> None:
        self.requests[rid] = RequestMetrics(rid=rid, arrival_time=now)

    def on_admit(self, rid: int, now: float,
                 prefix_tokens: int = 0) -> None:
        r = self.requests[rid]
        r.admit_time = now
        r.prefix_tokens = prefix_tokens

    def on_token(self, rid: int, now: float) -> None:
        r = self.requests[rid]
        if r.first_token_time is None:
            r.first_token_time = now
        else:
            # inter-token gap (the TPOT population p99 is computed over):
            # a decode step stalled behind a long prefill shows up here as
            # one large gap — exactly what chunking is meant to bound
            self.tpot_samples.append(now - r.last_token_time)
        r.last_token_time = now
        r.n_tokens += 1

    def on_finish(self, rid: int, now: float) -> None:
        self.requests[rid].finish_time = now

    def sample_queue(self, depth: int, active: int) -> None:
        self.queue_depth_samples.append(depth)
        self.active_samples.append(active)

    def sample_pool(self, stats: Dict[str, float],
                    tokens_live: float = math.nan) -> None:
        """Record one cache-pool occupancy snapshot (``*Pool.stats()``
        shape: kv_bytes_in_use/reserved, blocks_in_use/total,
        ``tokens_reserved`` — the *logical* per-slot reservation, a shared
        block counted once per referencing slot — and ``tokens_in_use`` —
        physical, each allocated block once; the paged pool adds
        blocks_shared / prefix_blocks / cow_copies).  ``tokens_live`` —
        positions actually written — lets the summary report internal
        fragmentation (reserved-but-unwritten token slots inside allocated
        blocks; the logical reservation is the right denominator, a trie
        hit must not read as fragmentation)."""
        self.pool_samples.append(dict(stats, tokens_live=tokens_live))

    def on_deferred_admit(self) -> None:
        """An arrived request stayed queued because the pool's free list
        could not cover its reservation (paged-pool back-pressure)."""
        self.deferred_admits += 1

    def on_prefill_chunk(self) -> None:
        """One chunked-prefill call ran (first chunk or a resumed one)."""
        self.prefill_chunks += 1

    def on_packed_prefill(self) -> None:
        """One packed prefill call served several queued prompts."""
        self.packed_prefills += 1

    def on_spec_round(self, *, drafted: int, verified: int, accepted: int,
                      accept_len: int) -> None:
        """One slot finished one speculative draft/verify round:
        ``drafted`` cheap-mode proposals, ``verified`` positions checked
        in the batched verify step, ``accepted`` tokens committed (after
        budget/EOS clipping), ``accept_len`` the raw verify agreement
        (1..draft_k — what the acceptance histogram is over)."""
        self.spec_rounds += 1
        self.drafted_tokens += drafted
        self.verified_tokens += verified
        self.accepted_tokens += accepted
        self.accept_len_counts[accept_len] = (
            self.accept_len_counts.get(accept_len, 0) + 1)

    # ------------------------------------------------------------------

    @classmethod
    def merged(cls, parts: Sequence["ServingMetrics"]) -> "ServingMetrics":
        """Fold several per-replica metrics into one fleet view.

        A request drained from a killed replica appears in two parts: an
        unfinished entry on the dead replica and (eventually) a finished
        one on its new home.  The finished entry wins; among unfinished
        duplicates the later-touched one does.  Samples concatenate and
        ``deferred_admits`` sum — fleet-wide totals, not averages.
        """
        out = cls()
        for m in parts:
            for rid, r in m.requests.items():
                cur = out.requests.get(rid)
                if cur is None or (cur.finish_time is None
                                   and r.finish_time is not None):
                    out.requests[rid] = r
            out.queue_depth_samples.extend(m.queue_depth_samples)
            out.active_samples.extend(m.active_samples)
            out.pool_samples.extend(m.pool_samples)
            out.tpot_samples.extend(m.tpot_samples)
            out.deferred_admits += m.deferred_admits
            out.prefill_chunks += m.prefill_chunks
            out.packed_prefills += m.packed_prefills
            for k, v in m.accept_len_counts.items():
                out.accept_len_counts[k] = out.accept_len_counts.get(k, 0) + v
            out.spec_rounds += m.spec_rounds
            out.drafted_tokens += m.drafted_tokens
            out.verified_tokens += m.verified_tokens
            out.accepted_tokens += m.accepted_tokens
        return out

    @staticmethod
    def _mean(xs: List[float]) -> float:
        xs = [x for x in xs if not math.isnan(x)]
        return sum(xs) / len(xs) if xs else math.nan

    @staticmethod
    def _p50(xs: List[float]) -> float:
        xs = sorted(x for x in xs if not math.isnan(x))
        if not xs:
            return math.nan
        n = len(xs)
        mid = n // 2
        return xs[mid] if n % 2 else 0.5 * (xs[mid - 1] + xs[mid])

    @staticmethod
    def _p99(xs: List[float]) -> float:
        """99th percentile (nearest-rank) — the chunked-prefill gate's
        tail-latency view of the inter-token-gap population."""
        xs = sorted(x for x in xs if not math.isnan(x))
        if not xs:
            return math.nan
        return xs[min(len(xs) - 1, math.ceil(0.99 * len(xs)) - 1)]

    def summary(self) -> Dict[str, float]:
        rs = list(self.requests.values())
        done = [r for r in rs if r.finish_time is not None]
        total_tokens = sum(r.n_tokens for r in rs)
        t0 = min((r.admit_time for r in rs if r.admit_time is not None),
                 default=math.nan)
        t1 = max((r.finish_time for r in done), default=math.nan)
        busy = t1 - t0 if not (math.isnan(t0) or math.isnan(t1)) else math.nan
        peak_bytes = max((p["kv_bytes_in_use"] for p in self.pool_samples),
                         default=math.nan)
        peak_blocks = max((p["blocks_in_use"] for p in self.pool_samples),
                          default=math.nan)
        occ = self._mean([p["blocks_in_use"] / p["blocks_total"]
                          for p in self.pool_samples if p["blocks_total"]])
        frag = self._mean(
            [1.0 - p["tokens_live"] / p["tokens_reserved"]
             for p in self.pool_samples
             if p["tokens_reserved"] and not math.isnan(p["tokens_live"])])
        admitted = [r for r in rs if r.admit_time is not None]
        hits = [r for r in admitted if r.prefix_tokens > 0]
        misses = [r for r in admitted if r.prefix_tokens == 0]
        peak_shared = max((p.get("blocks_shared", 0.0)
                           for p in self.pool_samples), default=0.0)
        cow = max((p.get("cow_copies", 0.0)
                   for p in self.pool_samples), default=0.0)
        out = {
            "n_requests": len(rs),
            "n_finished": len(done),
            "total_tokens": total_tokens,
            "tokens_per_s": (total_tokens / busy
                             if busy and not math.isnan(busy) else math.nan),
            "mean_ttft_s": self._mean([r.ttft for r in rs]),
            "mean_tpot_s": self._mean([r.tpot for r in rs]),
            # tail of the raw inter-token-gap population (not per-request
            # means): a decode stall behind a monolithic prefill is one
            # huge gap, so this is what chunked prefill improves
            "p99_tpot_s": self._p99(self.tpot_samples),
            "mean_queue_wait_s": self._mean([r.queue_wait for r in rs]),
            "p50_queue_wait_s": self._p50([r.queue_wait for r in rs]),
            "max_queue_depth": max(self.queue_depth_samples, default=0),
            "mean_active_slots": self._mean(
                [float(a) for a in self.active_samples]),
            # cache-pool occupancy (sampled once per scheduler step):
            # peak bytes is the headline paged-vs-contiguous comparison —
            # the contiguous pool reports its static reservation here.
            "peak_kv_bytes": peak_bytes,
            "peak_pool_blocks": peak_blocks,
            "mean_block_occupancy": occ,
            "mean_internal_frag": frag,
            "deferred_admits": self.deferred_admits,
            "prefill_chunks": self.prefill_chunks,
            "packed_prefills": self.packed_prefills,
            # speculative decode: committed tokens per verify round (the
            # speedup driver — plain decode is exactly 1.0), the mean and
            # histogram of raw verify agreement, and the draft/verify
            # token totals behind them (merged() sums across replicas)
            "spec_rounds": self.spec_rounds,
            "drafted_tokens": self.drafted_tokens,
            "verified_tokens": self.verified_tokens,
            "accepted_tokens": self.accepted_tokens,
            "accepted_per_step": (self.accepted_tokens / self.spec_rounds
                                  if self.spec_rounds else math.nan),
            "mean_accept_len": (
                sum(k * v for k, v in self.accept_len_counts.items())
                / sum(self.accept_len_counts.values())
                if self.accept_len_counts else math.nan),
            "accept_len_hist": {
                k: self.accept_len_counts[k]
                for k in sorted(self.accept_len_counts)},
            # prefix caching: hit rate over admitted requests, prompt
            # tokens served straight from the index (no prefill compute),
            # and the TTFT split that the warm/cold benchmark gate reads
            "prefix_hit_rate": (len(hits) / len(admitted) if admitted
                                else math.nan),
            "prefix_tokens_reused": float(sum(r.prefix_tokens
                                              for r in admitted)),
            "mean_ttft_hit_s": self._mean([r.ttft for r in hits]),
            "mean_ttft_miss_s": self._mean([r.ttft for r in misses]),
            "peak_blocks_shared": peak_shared,
            "cow_copies": cow,
        }
        if self.router_policy is not None:
            out.update({
                "router_policy": self.router_policy,
                "rebalanced_requests": self.rebalanced_requests,
                "replica_restarts": self.replica_restarts,
                "per_replica_tok_s": dict(self.per_replica_tok_s),
            })
        return out
