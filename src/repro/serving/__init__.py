"""repro.serving — continuous-batching decode runtime + replica fleet.

Single engine: a policy admission queue (``queue`` — FIFO or
shortest-prompt-first), a slot-indexed / block-paged persistent KV-cache
pool with prefix-trie COW sharing (``cache``), the continuous-batching
scheduler whose jitted decode step never recompiles as requests churn
(``scheduler``), self-speculative decoding — a cheap engine mode drafts
``draft_k - 1`` tokens, the serving mode verifies the run in one batched
step, greedy acceptance keeps generations bit-identical per mode
(``speculative``) — and per-request/aggregate serving metrics
(``metrics``).

Fleet layer (``router``): N independent engines — each its own
``Scheduler`` over its own device slice, mesh, pool, and prefix trie —
behind one :class:`Router` that owns the global admission queue and
dispatches per request:

* ``round_robin`` — cycle over live replicas;
* ``least_loaded`` — fewest queued+active, ties to most free KV blocks;
* ``prefix_affinity`` — leading block-run hash pins repeat prefixes
  (per-tenant system prompts) to the replica whose trie holds them,
  falling back to least-loaded.

Failure semantics: a replica kill (health-probe strikes from
``StragglerMonitor`` step times, or an injected :class:`FailurePlan`)
drains its in-flight requests back to the *front* of the global queue —
original ``arrival_time`` kept, ``n_migrations`` bumped, partial output
discarded — and respawns the replica via ``ElasticMesh`` over surviving
devices.  Migrated requests restart from their prompt, so greedy-decode
outputs stay bit-identical to an uninterrupted run; a kill costs
latency, never correctness or a lost request.

Fleet metric names (on ``Router.metrics().summary()``, next to the
single-engine fields): ``router_policy``, ``per_replica_tok_s``,
``rebalanced_requests``, ``replica_restarts``; replica wall time is
modeled by :class:`FleetClock` (a round costs its slowest replica — see
``router`` module docstring).

``launch/serve.py`` is a thin CLI over this package
(``--replicas/--router-policy/--kill-replica/--queue-policy``).
"""
from repro.serving.cache import CachePool, PagedCachePool
from repro.serving.metrics import RequestMetrics, ServingMetrics
from repro.serving.queue import (AdmissionQueue, Request, make_request,
                                 synthetic_requests)
from repro.serving.router import (FailurePlan, FleetClock, Replica, Router,
                                  RouterConfig)
from repro.serving.scheduler import Scheduler, ServingConfig
from repro.serving.speculative import SpeculativeDecoder, accept_length

__all__ = [
    "AdmissionQueue",
    "CachePool",
    "FailurePlan",
    "FleetClock",
    "PagedCachePool",
    "Replica",
    "Request",
    "RequestMetrics",
    "Router",
    "RouterConfig",
    "Scheduler",
    "ServingConfig",
    "ServingMetrics",
    "SpeculativeDecoder",
    "accept_length",
    "make_request",
    "synthetic_requests",
]
