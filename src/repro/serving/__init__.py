"""repro.serving — continuous-batching decode runtime.

The serving layer above the model/engine stack: a FIFO admission queue
(``queue``), a slot-indexed persistent KV-cache pool (``cache``), the
continuous-batching scheduler whose jitted decode step never recompiles as
requests churn (``scheduler``), and per-request/aggregate serving metrics
(``metrics``).  ``launch/serve.py`` is a thin CLI over this package.
"""
from repro.serving.cache import CachePool, PagedCachePool
from repro.serving.metrics import RequestMetrics, ServingMetrics
from repro.serving.queue import (AdmissionQueue, Request, make_request,
                                 synthetic_requests)
from repro.serving.scheduler import Scheduler, ServingConfig

__all__ = [
    "AdmissionQueue",
    "CachePool",
    "PagedCachePool",
    "Request",
    "RequestMetrics",
    "Scheduler",
    "ServingConfig",
    "ServingMetrics",
    "make_request",
    "synthetic_requests",
]
