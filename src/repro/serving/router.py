"""Multi-replica serving router: the data-parallel fleet layer.

One :class:`Scheduler` over one mesh is the single-engine capacity
ceiling; this module scales *by replica* instead of by ``max_batch`` —
PartitionPIM's thesis one level up: throughput comes from dividing a
fixed substrate (here, the device fleet) into independent
concurrently-operating units under one cheap shared controller.

:class:`Router` owns the **global** :class:`AdmissionQueue` (same
``fifo``/``sjf`` policies as a single engine) and N :class:`Replica`\\ s,
each a full serving engine — its own ``Scheduler`` over its own device
slice (``dist.partitioning.replica_slices``), its own mesh
(``ElasticMesh`` per slice), its own KV pool and prefix trie.  The
scheduler itself stays single-replica-ignorant; everything fleet-shaped
lives here.

**Dispatch policies** (``RouterConfig.policy``):

* ``round_robin`` — cycle over live replicas; the baseline.
* ``least_loaded`` — fewest ``queued + active`` requests, ties to the
  most free KV blocks (``pool.free_blocks``), then the lowest id.
* ``prefix_affinity`` — hash of the prompt's leading ``block_size``-token
  run → the replica that served that run before (whose trie therefore
  likely holds its blocks), falling back to least-loaded for unseen
  prefixes.  With per-tenant system prompts this pins each tenant to one
  replica's prefix index instead of smearing every tenant's blocks
  across all of them.

**Fault tolerance** is first-class: each replica carries a
:class:`StragglerMonitor` over its per-round step times
(``RouterConfig.health_check`` turns EWMA outlier strikes into kills),
and an injectable :class:`FailurePlan` deterministically kills replica
``r`` at router step ``s``.  A kill **drains** the replica — its
unfinished requests requeue at the *front* of the global queue with
their original ``arrival_time`` and ``n_migrations`` bumped, partial
outputs discarded — and **respawns** it via ``ElasticMesh`` over the
surviving devices (``lose_devices`` models devices dying with it; the
mesh shrinks, degrading model parallelism if needed).  A migrated
request restarts from its prompt on its new replica; greedy decode is
deterministic given (prompt, params), so its final tokens are
bit-identical to an uninterrupted run — the kill costs latency, never
correctness.

**The fleet clock.** Replicas model independent hosts, but this process
steps them one after another.  :class:`FleetClock` reconciles the two:
each replica's step runs inside a clock *segment* whose real elapsed
time is measured, and fleet time advances **once per round by the
maximum segment time** — exactly the wall time a data-parallel fleet of
independent hosts would observe (the round ends when its slowest
replica does; the router's serial dispatch is the cheap shared
controller and costs nothing).  All throughput/TTFT metrics and the
replica-scaling benchmark read this clock.  Any plain callable clock
(e.g. a test ``FakeClock``) also works: the router then measures step
times by consecutive clock reads and never advances time itself.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.dist import context as dctx
from repro.dist.partitioning import replica_slices
from repro.runtime.fault_tolerance import ElasticMesh, StragglerMonitor
from repro.serving.metrics import ServingMetrics
from repro.serving.queue import AdmissionQueue, Request, make_request
from repro.serving.scheduler import Scheduler, ServingConfig, _idle_sleep

__all__ = ["FleetClock", "FailurePlan", "RouterConfig", "Replica",
           "Router", "ROUTER_POLICIES"]

ROUTER_POLICIES = ("round_robin", "least_loaded", "prefix_affinity")


class FleetClock:
    """Virtual fleet time over sequentially-stepped replicas.

    ``start_segment``/``end_segment`` bracket one replica's step; reads
    inside a segment return fleet time plus the segment's real elapsed
    time (so per-token timestamps inside a step stay ordered), reads
    outside return the round's start time.  ``end_round(dts)`` advances
    fleet time by ``max(dts)`` — every replica of a round starts at the
    same instant and the round costs its slowest member, the wall-clock
    law of a data-parallel fleet of independent hosts.  ``advance_to``
    jumps idle time to the next arrival.
    """

    def __init__(self, wall=time.monotonic):
        self._wall = wall
        self._v = 0.0
        self._anchor: Optional[float] = None

    def __call__(self) -> float:
        if self._anchor is not None:
            return self._v + (self._wall() - self._anchor)
        return self._v

    def start_segment(self) -> None:
        self._anchor = self._wall()

    def end_segment(self) -> float:
        dt = self._wall() - self._anchor
        self._anchor = None
        return dt

    def end_round(self, dts: Sequence[float]) -> None:
        if dts:
            self._v += max(dts)

    def advance_to(self, t: float) -> None:
        self._v = max(self._v, t)


@dataclasses.dataclass(frozen=True)
class FailurePlan:
    """Deterministic fault injection: kill ``kill_replica`` when the
    router has completed ``at_step`` rounds.  ``lose_devices`` of its
    slice die with it (the respawn mesh shrinks to the survivors;
    losing all of them, or ``respawn=False``, retires the replica and
    its load redistributes)."""

    kill_replica: int
    at_step: int
    lose_devices: int = 0
    respawn: bool = True


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Fleet shape + dispatch/health policy (per-engine knobs stay in
    :class:`ServingConfig`, including the shared ``queue_policy``)."""

    n_replicas: int = 2
    policy: str = "least_loaded"    # one of ROUTER_POLICIES
    model_parallel: int = 1         # per-replica mesh "model" axis
    health_check: bool = False      # EWMA straggler strikes -> kill
    straggler_patience: int = 3     # consecutive flagged steps to kill
    straggler_threshold: float = 3.0
    straggler_alpha: float = 0.1

    def __post_init__(self):
        if self.policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown router policy {self.policy!r} "
                             f"(choose from {ROUTER_POLICIES})")
        if self.n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")


class Replica:
    """One serving engine over one device slice.

    Wraps a :class:`Scheduler` (own mesh, pool, trie, metrics) with the
    fleet bookkeeping the router needs: the set of in-flight rids, a
    :class:`StragglerMonitor` with a strike counter, and
    ``rebuild`` — the respawn path, which re-derives the mesh over
    whatever devices survive and starts a fresh scheduler (the drained
    requests are already back in the router's global queue)."""

    def __init__(self, rid: int, params, cfg, scfg: ServingConfig,
                 rcfg: RouterConfig, *, devices=None, clock=time.monotonic):
        self.rid = rid
        self.cfg = cfg
        self.scfg = scfg
        self.rcfg = rcfg
        self.clock = clock
        self.alive = True
        self.pending: set = set()       # rids dispatched, not yet harvested
        self.monitor = StragglerMonitor(alpha=rcfg.straggler_alpha,
                                        threshold=rcfg.straggler_threshold)
        self.strikes = 0
        self.rebuild(params, devices)

    def rebuild(self, params, devices) -> None:
        """(Re)build mesh + scheduler over ``devices`` (None: no mesh —
        the single-device case).  Used at construction and at respawn."""
        self.devices = list(devices) if devices is not None else None
        self.mesh = (ElasticMesh(self.rcfg.model_parallel)
                     .make(self.devices) if self.devices else None)
        ctx = dctx.use_mesh(self.mesh) if self.mesh is not None else None
        if ctx is not None:
            with ctx:
                self.sched = Scheduler(params, self.cfg, self.scfg,
                                       mesh=self.mesh, clock=self.clock)
        else:
            self.sched = Scheduler(params, self.cfg, self.scfg,
                                   clock=self.clock)
        self.monitor.reset()
        self.strikes = 0
        self.alive = True
        self.pending.clear()

    def step(self):
        """One scheduler step under this replica's mesh."""
        if self.mesh is not None:
            with dctx.use_mesh(self.mesh):
                return self.sched.step()
        return self.sched.step()

    # ---- load signals (least_loaded dispatch) ------------------------

    @property
    def queue_depth(self) -> int:
        return len(self.sched.queue)

    @property
    def n_active(self) -> int:
        return self.sched.n_active

    @property
    def free_blocks(self) -> int:
        return self.sched.pool.free_blocks

    @property
    def load(self):
        """Sort key: fewest queued+active, then most free KV blocks."""
        return (self.queue_depth + self.n_active, -self.free_blocks,
                self.rid)


class Router:
    """N serving replicas behind one admission queue (module docstring
    has the architecture; drive with ``submit``/``step``/``run``)."""

    def __init__(self, params, cfg, scfg: ServingConfig,
                 rcfg: RouterConfig, *, devices=None,
                 clock: Optional[object] = None,
                 failure_plan: Optional[FailurePlan] = None):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.rcfg = rcfg
        self.clock = clock if clock is not None else FleetClock()
        self._fleet = isinstance(self.clock, FleetClock)
        self.queue = AdmissionQueue(policy=scfg.queue_policy)
        self.plan = failure_plan
        self._plan_fired = False
        if devices is None:
            import jax
            devices = jax.devices() if jax.device_count() > 1 else None
        slices = (replica_slices(rcfg.n_replicas, devices)
                  if devices is not None else [None] * rcfg.n_replicas)
        self.replicas = [
            Replica(i, params, cfg, scfg, rcfg, devices=s, clock=self.clock)
            for i, s in enumerate(slices)]
        self.results: Dict[int, np.ndarray] = {}
        self.step_count = 0
        self.rebalanced_requests = 0
        self.replica_restarts = 0
        self._dead_metrics: List[ServingMetrics] = []
        self._affinity: Dict[bytes, int] = {}   # prefix-run hash -> replica
        self._rr = 0                            # round_robin cursor

    # ---- admission ---------------------------------------------------

    def submit(self, prompt, max_new_tokens: int, *,
               arrival_time: Optional[float] = None) -> int:
        req = make_request(prompt, max_new_tokens,
                           arrival_time=self.clock() if arrival_time is None
                           else arrival_time)
        return self.submit_request(req)

    def submit_request(self, req: Request) -> int:
        """Global admission: validate once (every replica's pool has the
        same capacity), then queue for dispatch."""
        self._any_live().sched.validate_request(req)
        self.queue.submit(req)
        return req.rid

    def _any_live(self) -> Replica:
        for rep in self.replicas:
            if rep.alive:
                return rep
        raise RuntimeError("no live replicas")

    # ---- dispatch ----------------------------------------------------

    def _affinity_key(self, req: Request) -> bytes:
        bs = self.scfg.block_size
        return req.prompt[:bs].tobytes()

    def _pick(self, req: Request) -> Replica:
        live = [r for r in self.replicas if r.alive]
        policy = self.rcfg.policy
        if policy == "round_robin":
            rep = live[self._rr % len(live)]
            self._rr += 1
            return rep
        if policy == "prefix_affinity":
            key = self._affinity_key(req)
            rid = self._affinity.get(key)
            if rid is not None and self.replicas[rid].alive:
                return self.replicas[rid]
            rep = min(live, key=lambda r: r.load)
            self._affinity[key] = rep.rid
            return rep
        return min(live, key=lambda r: r.load)

    def _dispatch(self) -> int:
        """Hand every *arrived* queued request to a replica (the policy's
        pick); replicas admit from their local queues on their next step,
        so least-loaded sees earlier dispatches of the same round."""
        n = 0
        while any(r.alive for r in self.replicas):
            now = self.clock()
            head = self.queue.peek(now)
            if head is None or head.arrival_time > now:
                break
            rep = self._pick(head)
            req = self.queue.pop(now)
            assert req is head, "peek/pop selection must agree"
            req.replica_id = rep.rid
            rep.sched.submit_request(req)
            rep.pending.add(req.rid)
            n += 1
        return n

    # ---- fault path --------------------------------------------------

    def _kill(self, rep: Replica, *, lose_devices: int = 0,
              respawn: bool = True) -> None:
        """Drain-and-requeue ``rep``, then respawn it over the surviving
        devices (or retire it when none survive / respawn is off)."""
        drained = rep.sched.drain()
        self._dead_metrics.append(rep.sched.metrics)
        for req in reversed(drained):    # keep order; front of the queue
            req.n_migrations += 1
            self.queue.requeue(req)
        self.rebalanced_requests += len(drained)
        rep.pending.clear()
        rep.alive = False
        survivors = (rep.devices[lose_devices:]
                     if rep.devices is not None else None)
        if respawn and (rep.devices is None or survivors):
            rep.rebuild(self.params, survivors)
            self.replica_restarts += 1

    def _maybe_plan_kill(self) -> None:
        p = self.plan
        if (p is not None and not self._plan_fired
                and self.step_count >= p.at_step
                and self.replicas[p.kill_replica].alive):
            self._plan_fired = True
            self._kill(self.replicas[p.kill_replica],
                       lose_devices=p.lose_devices, respawn=p.respawn)

    # ---- the round ---------------------------------------------------

    def _harvest(self, rep: Replica) -> None:
        done = [rid for rid in rep.pending
                if rep.sched.metrics.requests[rid].finish_time is not None]
        for rid in done:
            if rid in self.results:
                raise RuntimeError(f"request {rid} completed twice")
            self.results[rid] = rep.sched.output(rid)
            rep.pending.discard(rid)

    def step(self) -> int:
        """One fleet round: injected kills, dispatch, then one scheduler
        step per live replica (each in its own clock segment); fleet
        time advances by the slowest segment.  Returns tokens emitted."""
        self._maybe_plan_kill()
        self._dispatch()
        dts: List[float] = []
        emitted = 0
        to_kill: List[Replica] = []
        for rep in self.replicas:
            if not rep.alive:
                continue
            if self._fleet:
                self.clock.start_segment()
                out = rep.step()
                dt = self.clock.end_segment()
            else:
                t0 = self.clock()
                out = rep.step()
                dt = self.clock() - t0
            dts.append(dt)
            emitted += len(out)
            self._harvest(rep)
            if self.rcfg.health_check:
                rep.strikes = rep.strikes + 1 if rep.monitor.record(dt) else 0
                if rep.strikes >= self.rcfg.straggler_patience:
                    to_kill.append(rep)
        for rep in to_kill:
            self._kill(rep)
        if self._fleet:
            self.clock.end_round(dts)
        self.step_count += 1
        return emitted

    def run(self) -> Dict[int, np.ndarray]:
        """Step until the queue drains and every replica idles; returns
        rid -> generated tokens.  Idle gaps before the next arrival jump
        the fleet clock; with a plain injected clock the same
        stall-guard as ``Scheduler.run`` applies."""
        stalls = 0
        while len(self.queue) or any(r.pending for r in self.replicas):
            if not any(r.alive for r in self.replicas):
                raise RuntimeError(
                    "all replicas dead with requests outstanding")
            progressed = self.step() > 0
            if progressed or any(r.pending for r in self.replicas):
                stalls = 0
                continue
            head = self.queue.peek(self.clock())
            if head is None:
                continue
            if self._fleet:
                self.clock.advance_to(head.arrival_time)
                continue
            stalls = _idle_sleep(self.clock, head.arrival_time, stalls)
            if stalls > 1000:
                raise RuntimeError(
                    "run(): clock is not advancing while requests "
                    "wait to arrive; with an injected test clock, "
                    "advance it and call step() yourself")
        return dict(self.results)

    # ---- fleet metrics -----------------------------------------------

    def metrics(self) -> ServingMetrics:
        """Merged fleet metrics (live + killed replicas), stamped with
        the router fields ``summary()`` reports."""
        live = [r.sched.metrics for r in self.replicas if r.alive]
        m = ServingMetrics.merged(live + self._dead_metrics)
        m.router_policy = self.rcfg.policy
        m.rebalanced_requests = self.rebalanced_requests
        m.replica_restarts = self.replica_restarts
        m.per_replica_tok_s = {
            r.rid: r.sched.metrics.summary()["tokens_per_s"]
            for r in self.replicas if r.alive}
        return m
