"""Decode-cache pools for continuous batching: slot-contiguous and paged.

Two pool layouts share one scheduler-facing API (``can_admit`` /
``admit`` / ``evict`` / ``read_slot`` / ``stats``):

:class:`CachePool` is the naive layout — the full decode-cache tree of
``models.model.cache_specs`` at ``(max_batch, max_len)``, allocated once;
every slot reserves worst-case ``max_len`` KV whether its request needs 10
tokens or 10k.  Admits/evicts are single jitted ``dynamic_update_slice``
writes on the batch dim.

:class:`PagedCachePool` is the PartitionPIM move applied to HBM: just as
the paper divides one fixed crossbar into dynamic partitions so
independent work shares the substrate without worst-case reservation, the
paged pool divides each attention-KV leaf into a ``(num_blocks,
block_size, ...)`` physical store shared by all slots.  A per-slot block
table (``(max_batch, blocks_per_slot)`` int32, sentinel ``0`` pointing at
a reserved trash block) maps logical token blocks to physical ones; a
host-side free-list allocator reserves exactly
``ceil((prompt + budget) / block_size)`` blocks per request at admit time
(admission defers when the free list is short — never a mid-decode OOM),
and evict returns the blocks.  The jitted decode step reads through a
gather on the block table, whose shape is fixed, so block churn never
recompiles anything.

Paging is also what unblocks **sliding-window serving**: a windowed slot
is a *ring* over its block list with capacity ``ceil(window / block) *
block`` — prefill installs the last ``min(prompt, window)`` positions,
decode wraps, and the reservation stops depending on prompt + generation
length entirely.  Recurrent state (ssm/conv, xLSTM c/n/m) and
cross-attention memory are fixed-size per slot and stay slot-indexed in
both pools (``models.model.PAGED_KV_KEYS`` names what pages).

**Prefix caching** (``prefix_cache=True``) lets *requests* share the
paged substrate the way the paper's partitions share the crossbar: a
:class:`PrefixIndex` — a trie over block-sized token runs — maps fully
matched prompt blocks of a new request straight into its block table
(refcount bump, no prefill, no copy), and only the divergent tail is
prefilled (``models.model.prefill(prefix=...)`` resumes at the
block-aligned offset).  Every physical block carries a refcount; a block
returns to the free list — and is zeroed — only when the last reference
drops.  A block's lifetime is therefore::

    free -> private (ref 1, one slot)
         -> shared  (ref > 1: other slots via trie hits/forks, or the
                     index itself, which holds one reference per entry)
         -> COW     (first write into a shared block copies it into a
                     fresh private block first; see ensure_writable)

The COW path fires on the two writes that can land in a shared block: a
fork's divergent continuation entering the partially filled boundary
block (``fork`` is the parallel-sampling n>1 primitive — siblings share
every content block), and a sliding-window ring wrapping onto mapped
prefix blocks.  Unwindowed trie hits never COW: the divergent tail always
starts on a fresh block (matches are block-aligned and capped at
``plen - 1``).  Admission budgets outstanding COW copies against the free
list (``_cow_debt``) so a copy never finds it empty; under pressure,
``can_admit`` reclaims LRU index-only blocks (ref held solely by the
trie) before deferring.

``stats()`` keys (consumed by ``ServingMetrics.sample_pool`` and gated
indirectly through ``benchmarks/check.py``): ``tokens_reserved`` is the
*logical* per-slot reservation (each slot's block-list length x
block_size — a shared block counts once per referencing slot, i.e. what
every request would have allocated privately), while ``tokens_in_use`` is
the *physical* occupancy (allocated blocks x block_size, each block
once).  ``mean_internal_frag`` divides live tokens by the logical
reservation; the physical/logical gap is exactly the prefix-sharing win,
reported via ``blocks_shared``.

Under a mesh both pools are placed by ``dist.cache_pspecs(...,
batch_over_dp=False)``: heads shard over "model", but the slot dim — and
for paged leaves the *block* dim in its place — stays replicated:
continuous batching touches arbitrary slots/blocks every step, and a
sharded dim 1 would make each admit a cross-device scatter.  Block tables
are tiny int32 and replicated.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import partitioning as dpart
from repro.models import model_lib as M
from repro.models.config import ModelConfig

__all__ = ["CachePool", "PagedCachePool", "PrefixIndex"]


def _kv_leaf_bytes(tree) -> int:
    """Bytes of the attention-KV (pageable) leaves of a cache tree."""
    total = 0
    for c in tree.values():
        for key in M.PAGED_KV_KEYS:
            if key in c:
                leaf = c[key]
                total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype
                                                              ).itemsize
    return total


class PrefixIndex:
    """Trie over block-sized token runs -> physical KV blocks.

    Each node is one *full* block of ``block_size`` token ids (the edge
    key) holding the physical block where that run's KV lives; a path
    from the root spells a block-aligned prompt prefix.  The index holds
    its own reference on every registered block (the pool bumps the
    refcount on ``insert``'s adoptions), so shared prefixes survive the
    eviction of the slots that minted them.  ``match`` touches nodes for
    LRU; ``pop_lru_blocks`` releases least-recently-used leaves whose
    block the pool can actually free (index-only references) when the
    free list runs short.
    """

    def __init__(self, block_size: int):
        self.block_size = block_size
        # node: [block_id, children dict keyed by token tuple, lru stamp]
        self._root: Dict[tuple, list] = {}
        self._clock = 0
        self.n_blocks = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, tokens) -> List[int]:
        """Blocks covering the longest fully-block-aligned prefix of
        ``tokens`` present in the index (touches matched nodes)."""
        bs = self.block_size
        out: List[int] = []
        children = self._root
        for i in range(len(tokens) // bs):
            key = tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
            node = children.get(key)
            if node is None:
                break
            node[2] = self._tick()
            out.append(node[0])
            children = node[1]
        return out

    def insert(self, tokens, blocks: Sequence[int]) -> List[int]:
        """Register ``blocks[i]`` as holding ``tokens[i*bs:(i+1)*bs]``.

        Existing nodes keep their canonical block (the new request was
        mapped onto it anyway if it matched); returns the block ids newly
        adopted — the caller owns bumping their refcount.
        """
        bs = self.block_size
        new: List[int] = []
        children = self._root
        for i, b in enumerate(blocks):
            key = tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
            node = children.get(key)
            if node is None:
                node = [int(b), {}, 0]
                children[key] = node
                new.append(int(b))
                self.n_blocks += 1
            node[2] = self._tick()
            children = node[1]
        return new

    def blocks(self) -> List[int]:
        """Every registered block id (tests / invariant checks)."""
        out: List[int] = []
        stack = [self._root]
        while stack:
            for node in stack.pop().values():
                out.append(node[0])
                stack.append(node[1])
        return out

    def pop_lru_blocks(self, want: int, reclaimable) -> List[int]:
        """Drop least-recently-used *leaf* entries whose block satisfies
        ``reclaimable(block_id)`` until ``want`` blocks were released (or
        none remain); returns the released ids.  Dropping a leaf may
        expose its parent as the next candidate."""
        released: List[int] = []
        while len(released) < want:
            best = None
            stack = [self._root]
            while stack:
                children = stack.pop()
                for key, node in children.items():
                    if node[1]:
                        stack.append(node[1])
                    elif reclaimable(node[0]) and (best is None
                                                   or node[2] < best[0]):
                        best = (node[2], children, key, node)
            if best is None:
                break
            _, children, key, node = best
            del children[key]
            self.n_blocks -= 1
            released.append(node[0])
        return released

    def drop_all(self) -> List[int]:
        """Forget every entry; returns all previously held block ids."""
        out = self.blocks()
        self._root = {}
        self.n_blocks = 0
        return out


class CachePool:
    """Decode caches for ``max_batch`` slots of up to ``max_len`` tokens.

    ``caches`` is the live cache tree threaded through the jitted decode
    step; the scheduler re-binds it after every step.  ``assign`` expects a
    single-request prefill cache (batch dim 1) produced at the pool's
    ``max_len`` capacity (i.e. with ``cfg.max_seq_len == max_len``).
    """

    paged = False
    block_tables = None            # uniform scheduler interface

    def __init__(self, cfg: ModelConfig, max_batch: int,
                 max_len: Optional[int] = None, *, mesh=None):
        self.max_batch = max_batch
        self.max_len = max_len or cfg.max_seq_len
        self.max_tokens = self.max_len          # per-slot token capacity
        specs = M.cache_specs(cfg, max_batch, self.max_len)
        self.kv_reserved_bytes = _kv_leaf_bytes(specs)
        self.caches: Dict[str, Any] = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        if mesh is not None:
            self.caches = jax.device_put(self.caches, dpart.tree_shardings(
                dpart.cache_pspecs(self.caches, mesh, batch_over_dp=False),
                mesh))
        self._assigned: set = set()   # occupied slots (stats only)

        def assign(pool, request_cache, slot):
            return jax.tree.map(
                lambda c, r: jax.lax.dynamic_update_slice_in_dim(
                    c, r.astype(c.dtype), slot, axis=1),
                pool, request_cache)

        def evict(pool, slot):
            return jax.tree.map(
                lambda c: jax.lax.dynamic_update_slice_in_dim(
                    c, jnp.zeros(c.shape[:1] + (1,) + c.shape[2:], c.dtype),
                    slot, axis=1),
                pool)

        def read(pool, slot):
            return jax.tree.map(
                lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1),
                pool)

        self._assign = jax.jit(assign)
        self._evict = jax.jit(evict)
        self._read = jax.jit(read)

    def assign(self, slot: int, request_cache) -> None:
        """Install a (batch-1) prefill cache into ``slot``."""
        self.caches = self._assign(self.caches, request_cache,
                                   jnp.int32(slot))
        self._assigned.add(int(slot))

    def evict(self, slot: int) -> None:
        """Zero ``slot`` (logical free; keeps stale KV out of the pool)."""
        self.caches = self._evict(self.caches, jnp.int32(slot))
        self._assigned.discard(int(slot))

    def read_slot(self, slot: int):
        """The (batch-1) cache view of ``slot`` — tests/inspection."""
        return self._read(self.caches, jnp.int32(slot))

    # ---- uniform pool interface -------------------------------------

    def can_admit(self, n_tokens: int) -> bool:
        """Contiguous slots always fit (capacity was reserved up front)."""
        return True

    @property
    def free_blocks(self) -> int:
        """Free capacity in slot units (the router's least-loaded signal;
        the contiguous pool's allocation granularity is one slot)."""
        return self.max_batch - len(self._assigned)

    def admit(self, slot: int, request_cache, plen: int, n_tokens: int,
              *, prompt=None, prefix_blocks=None) -> None:
        self.assign(slot, request_cache)

    def stats(self) -> Dict[str, float]:
        """Occupancy snapshot.  The contiguous pool's KV bytes are its
        static worst-case reservation — that constant is exactly what the
        paged pool's ``bytes_in_use`` undercuts on long-tail traces.
        ``tokens_reserved`` is the static reservation; ``tokens_in_use``
        counts only occupied slots (each at full ``max_len`` capacity —
        slot-contiguous rows have no finer granularity)."""
        return {
            "kv_bytes_in_use": float(self.kv_reserved_bytes),
            "kv_bytes_reserved": float(self.kv_reserved_bytes),
            "blocks_in_use": float(self.max_batch),
            "blocks_total": float(self.max_batch),
            "tokens_reserved": float(self.max_batch * self.max_len),
            "tokens_in_use": float(len(self._assigned) * self.max_len),
        }


class PagedCachePool:
    """Block-paged decode caches: attention KV in shared physical blocks.

    ``block_size`` tokens per block; ``num_blocks`` physical blocks per KV
    leaf (default: full parity with the contiguous pool — every slot can
    hold ``blocks_per_slot`` blocks — plus the reserved trash block; pass
    something smaller to actually oversubscribe).  Block 0 is never
    allocated: it is the sentinel target of unassigned block-table entries,
    absorbing the garbage writes of inactive decode slots.

    ``admit`` expects a (batch-1) prefill cache and the request's true
    prompt length: the paged leaves are *converted* — gathered from the
    prefill layout (dense, or the windowed ring) into position-ordered
    logical blocks, invalid positions zeroed — and scattered to the slot's
    physical blocks in one jitted op per prefill bucket shape.  With
    ``prefix_blocks`` (a trie hit from :meth:`prefix_match`) the matched
    blocks are mapped by reference and only the tail cache — emitted by
    the resumed prefill, positions ``m..plen-1`` — is scattered, at block
    offset ``m``.

    ``prefix_cache=True`` attaches the :class:`PrefixIndex` and enables
    per-block refcounting/COW (see the module docstring for the block
    lifetime).  The caller is responsible for gating it to stacks whose
    KV is position-independent (no recurrent blocks, no MoE token
    dropping); windowed prompts participate only while ``plen <= window``
    — up to there the ring layout is the dense layout.
    """

    paged = True

    def __init__(self, cfg: ModelConfig, max_batch: int,
                 max_len: Optional[int] = None, *, block_size: int = 16,
                 num_blocks: Optional[int] = None, mesh=None,
                 prefix_cache: bool = False):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len or cfg.max_seq_len
        self.block_size = block_size
        ring = cfg.window_ring_blocks(block_size)
        self.blocks_per_slot = (ring if ring is not None
                                else -(-self.max_len // block_size))
        self.lcap = self.blocks_per_slot * block_size   # logical tokens/slot
        # windowed slots can generate forever (the ring wraps); unwindowed
        # ones are bounded by the configured horizon, NOT the block-rounded
        # lcap — the layout must never admit a request the contiguous pool
        # would reject (positions past max_len are outside the declared
        # context even when rounding leaves physical room)
        self.max_tokens = (None if cfg.sliding_window else self.max_len)
        self.num_blocks = (num_blocks if num_blocks is not None
                           else max_batch * self.blocks_per_slot + 1)
        if self.num_blocks < 2:
            raise ValueError("num_blocks must be >= 2 (block 0 is the "
                             "reserved sentinel/trash block)")
        self._mesh = mesh

        specs = M.paged_cache_specs(cfg, max_batch, self.max_len,
                                    self.num_blocks, block_size)
        per_pool = _kv_leaf_bytes(specs)
        self.block_bytes = per_pool // self.num_blocks  # all leaves/layers
        self._has_paged_leaves = per_pool > 0
        self.caches: Dict[str, Any] = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        if mesh is not None:
            self.caches = jax.device_put(self.caches, dpart.tree_shardings(
                dpart.cache_pspecs(self.caches, mesh, batch_over_dp=False),
                mesh))

        # host allocator state: free-list (LIFO keeps reuse warm), per-slot
        # block lists, per-block refcounts, and the sentinel-padded table
        # mirrored to device
        self._free: List[int] = list(range(self.num_blocks - 1, 0, -1))
        self._slot_blocks: List[List[int]] = [[] for _ in range(max_batch)]
        self._table = np.zeros((max_batch, self.blocks_per_slot), np.int32)
        self._table_dev = None
        self._ref = np.zeros(self.num_blocks, np.int64)
        # blocks each slot may yet overwrite while shared (boundary block
        # of a fork, mapped prefix under a wrapping ring): the free list
        # keeps this many blocks in reserve so COW never underflows
        self._cow_debt = np.zeros(max_batch, np.int64)
        self.cow_copies = 0
        self.peak_blocks_in_use = 0
        self.prefix = (PrefixIndex(block_size)
                       if prefix_cache and self._has_paged_leaves else None)

        window = cfg.sliding_window
        lcap, bs = self.lcap, block_size

        def assign(pool, request_cache, table_row, slot, plen, start):
            def paged_leaf(c, rleaf):
                # rleaf (ns, 1, cap_p, ...): dense positions 0..cap_p-1, or
                # — windowed — position p at ring index p % cap_p.  ``start``
                # offsets the source read: a packed prefill emits several
                # segments in one (1, L) stream, and each segment's admit
                # reads its own span ``start..start+plen-1`` of it.
                cap_p = rleaf.shape[2]
                r = jnp.arange(lcap)
                if window:
                    # same congruence the paged decode read applies (the
                    # windowed pool's lcap IS the ring capacity)
                    p_r, valid = M.ring_slot_positions(plen - 1, r, lcap,
                                                       window)
                else:
                    p_r = r
                    valid = r < plen
                src = (start + p_r) % cap_p
                logical = jnp.take(rleaf[:, 0], src, axis=1)  # (ns, lcap,...)
                vshape = (1, lcap) + (1,) * (logical.ndim - 2)
                logical = jnp.where(valid.reshape(vshape), logical, 0)
                blocks = logical.reshape(
                    (logical.shape[0], self.blocks_per_slot, bs)
                    + logical.shape[2:]).astype(c.dtype)
                # sentinel-padded rows scatter their tail into trash block 0
                return c.at[:, table_row].set(blocks)

            out = {}
            for li, c in pool.items():
                oc = {}
                for key, leaf in c.items():
                    if key in M.PAGED_KV_KEYS:
                        oc[key] = paged_leaf(leaf, request_cache[li][key])
                    else:
                        oc[key] = jax.lax.dynamic_update_slice_in_dim(
                            leaf, request_cache[li][key].astype(leaf.dtype),
                            slot, axis=1)
                out[li] = oc
            return out

        def assign_tail(pool, request_cache, table_row, slot, plen, m):
            # tail-resume install: rleaf (ns, 1, cap_t, ...) holds dense
            # positions m..plen-1 at indices 0..plen-m-1 (the resumed
            # prefill emits the tail only, unpadded to capacity); the
            # mapped prefix entries of table_row are sentinel 0, routing
            # their (masked-to-zero) writes into the trash block while the
            # shared prefix blocks stay untouched.  Valid for windowed
            # slots too: prefix mapping requires plen <= window, where the
            # ring layout IS the dense layout.
            def tail_leaf(c, rleaf):
                cap_t = rleaf.shape[2]
                r = jnp.arange(lcap)
                src = jnp.clip(r - m, 0, cap_t - 1)
                valid = (r >= m) & (r < plen)
                logical = jnp.take(rleaf[:, 0], src, axis=1)
                vshape = (1, lcap) + (1,) * (logical.ndim - 2)
                logical = jnp.where(valid.reshape(vshape), logical, 0)
                blocks = logical.reshape(
                    (logical.shape[0], self.blocks_per_slot, bs)
                    + logical.shape[2:]).astype(c.dtype)
                return c.at[:, table_row].set(blocks)

            out = {}
            for li, c in pool.items():
                oc = {}
                for key, leaf in c.items():
                    if key in M.PAGED_KV_KEYS:
                        oc[key] = tail_leaf(leaf, request_cache[li][key])
                    else:
                        oc[key] = jax.lax.dynamic_update_slice_in_dim(
                            leaf, request_cache[li][key].astype(leaf.dtype),
                            slot, axis=1)
                out[li] = oc
            return out

        def evict(pool, table_row, slot):
            out = {}
            for li, c in pool.items():
                oc = {}
                for key, leaf in c.items():
                    if key in M.PAGED_KV_KEYS:
                        z = jnp.zeros((leaf.shape[0], self.blocks_per_slot,
                                       bs) + leaf.shape[3:], leaf.dtype)
                        oc[key] = leaf.at[:, table_row].set(z)
                    else:
                        oc[key] = jax.lax.dynamic_update_slice_in_dim(
                            leaf, jnp.zeros(
                                leaf.shape[:1] + (1,) + leaf.shape[2:],
                                leaf.dtype), slot, axis=1)
                out[li] = oc
            return out

        def read(pool, table_row, slot):
            out = {}
            for li, c in pool.items():
                oc = {}
                for key, leaf in c.items():
                    if key in M.PAGED_KV_KEYS:
                        g = leaf[:, table_row]          # (ns, bps, bs, ...)
                        oc[key] = g.reshape((g.shape[0], 1, lcap)
                                            + g.shape[3:])
                    else:
                        oc[key] = jax.lax.dynamic_slice_in_dim(
                            leaf, slot, 1, axis=1)
                out[li] = oc
            return out

        def read_prefix(pool, blocks):
            # dense (ns, 1, nb*bs, ...) gather of the mapped prefix — the
            # ``prefix`` operand of the tail-resume prefill (one trace per
            # distinct prefix block count)
            nb = blocks.shape[0]
            out = {}
            for li, c in pool.items():
                oc = {}
                for key, leaf in c.items():
                    if key in M.PAGED_KV_KEYS:
                        g = leaf[:, blocks]             # (ns, nb, bs, ...)
                        oc[key] = g.reshape((g.shape[0], 1, nb * bs)
                                            + g.shape[3:])
                if oc:
                    out[li] = oc
            return out

        def cow(pool, src, dst):
            out = {}
            for li, c in pool.items():
                oc = {}
                for key, leaf in c.items():
                    if key in M.PAGED_KV_KEYS:
                        oc[key] = leaf.at[:, dst].set(
                            jax.lax.dynamic_index_in_dim(leaf, src, axis=1,
                                                         keepdims=False))
                    else:
                        oc[key] = leaf
                out[li] = oc
            return out

        def zero_block(pool, b):
            out = {}
            for li, c in pool.items():
                oc = {}
                for key, leaf in c.items():
                    if key in M.PAGED_KV_KEYS:
                        oc[key] = leaf.at[:, b].set(
                            jnp.zeros((leaf.shape[0],) + leaf.shape[2:],
                                      leaf.dtype))
                    else:
                        oc[key] = leaf
                out[li] = oc
            return out

        def copy_state(pool, src, dst):
            # fork: duplicate the slot-indexed (non-paged) leaves of
            # ``src`` into ``dst``; paged leaves are shared by reference
            out = {}
            for li, c in pool.items():
                oc = {}
                for key, leaf in c.items():
                    if key in M.PAGED_KV_KEYS:
                        oc[key] = leaf
                    else:
                        row = jax.lax.dynamic_slice_in_dim(leaf, src, 1,
                                                           axis=1)
                        oc[key] = jax.lax.dynamic_update_slice_in_dim(
                            leaf, row, dst, axis=1)
                out[li] = oc
            return out

        self._assign = jax.jit(assign)
        self._assign_tail = jax.jit(assign_tail)
        self._evict = jax.jit(evict)
        self._read = jax.jit(read)
        self._read_prefix = jax.jit(read_prefix)
        self._cow = jax.jit(cow)
        self._zero_block = jax.jit(zero_block)
        self._copy_state = jax.jit(copy_state)

    # ---- allocator ---------------------------------------------------

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - 1 - len(self._free)

    @property
    def free_blocks(self) -> int:
        """Blocks on the free list (the router's least-loaded signal;
        trie-held ref==1 blocks are reclaimable but not counted — they
        are *cache*, and a router should prefer a replica with genuinely
        idle capacity over one that must evict its prefix index)."""
        return len(self._free)

    @property
    def has_shared(self) -> bool:
        """Any block referenced more than once (COW checks are needed)."""
        return bool((self._ref > 1).any())

    def blocks_needed(self, n_tokens: int) -> int:
        if not self._has_paged_leaves:   # pure-recurrent stack: nothing pages
            return 0
        return min(self.cfg.kv_blocks_for(n_tokens, self.block_size),
                   self.blocks_per_slot)

    def _will_wrap(self, n_tokens: int) -> bool:
        """Whether a windowed request writing ``n_tokens`` wraps its ring
        (and may therefore overwrite mapped/registered prefix blocks)."""
        return bool(self.cfg.sliding_window) and n_tokens > self.lcap

    def _reclaim(self, want: int) -> None:
        """Free up to ``want`` index-only blocks (held solely by the
        prefix trie, LRU first) back to the free list."""
        if self.prefix is None:
            return
        dropped = self.prefix.pop_lru_blocks(
            want, lambda b: self._ref[b] == 1)
        for b in dropped:
            self._ref[b] = 0
            self.caches = self._zero_block(self.caches, jnp.int32(b))
        self._free.extend(reversed(dropped))

    def can_admit(self, n_tokens: int, prefix_tokens: int = 0,
                  extra_reserved: int = 0) -> bool:
        """Whether the free list covers a request writing ``n_tokens``
        positions, of which the leading ``prefix_tokens`` arrive mapped
        from the prefix index (no allocation).  Budgets the request's own
        worst-case COW copies plus every outstanding debt, reclaiming
        LRU index-only blocks under pressure before giving up.

        ``extra_reserved``: blocks already spoken for by earlier members
        of the same batch (packed prefill collects several admits before
        allocating any — each check must budget its predecessors)."""
        need = self.blocks_needed(n_tokens)
        mapped = min(prefix_tokens // self.block_size, need)
        fresh = need - mapped
        debt = 0
        if self._will_wrap(n_tokens):
            # the wrapping ring may COW every mapped block and (with the
            # index attached) every own block it registers
            debt = mapped + (fresh if self.prefix is not None else 0)
        want = fresh + debt + int(self._cow_debt.sum()) + extra_reserved
        if want > len(self._free):
            self._reclaim(want - len(self._free))
        return want <= len(self._free)

    @property
    def block_tables(self) -> jnp.ndarray:
        """Device copy of the (max_batch, blocks_per_slot) table,
        replicated under the pool's mesh."""
        if self._table_dev is None:
            t = jnp.asarray(self._table)
            if self._mesh is not None:
                t = jax.device_put(t, jax.sharding.NamedSharding(
                    self._mesh, jax.sharding.PartitionSpec()))
            self._table_dev = t
        return self._table_dev

    # ---- prefix index ------------------------------------------------

    def prefix_match(self, prompt) -> Tuple[int, List[int]]:
        """``(m, blocks)``: the longest block-aligned trie prefix of
        ``prompt``, capped at ``plen - 1`` so the divergent tail always
        holds at least one token (the resumed prefill must produce the
        request's first-token logits).  Windowed prompts match only while
        ``plen <= window`` — past it the ring layout diverges from the
        dense one the index describes."""
        if self.prefix is None:
            return 0, []
        plen = len(prompt)
        w = self.cfg.sliding_window
        if w and plen > w:
            return 0, []
        blocks = self.prefix.match(prompt)
        while blocks and len(blocks) * self.block_size > plen - 1:
            blocks.pop()
        return len(blocks) * self.block_size, blocks

    def read_prefix(self, blocks: Sequence[int]):
        """Dense ``(ns, 1, m, ...)`` view of a mapped prefix's paged
        leaves — the ``prefix`` operand of ``models.model.prefill``."""
        return self._read_prefix(self.caches,
                                 jnp.asarray(list(blocks), jnp.int32))

    def _register(self, slot: int, prompt, plen: int, wrap: bool) -> None:
        """Adopt the slot's fully covered prompt blocks into the index
        (the index holds one reference per adopted block)."""
        w = self.cfg.sliding_window
        if w and plen > w:
            return          # ring layout != dense past the window
        nfull = min(plen // self.block_size, len(self._slot_blocks[slot]))
        if nfull <= 0:
            return
        new = self.prefix.insert(prompt, self._slot_blocks[slot][:nfull])
        for b in new:
            self._ref[b] += 1
        if wrap:
            # its own registered blocks are now shared with the index and
            # in the overwrite path of the wrapping ring
            self._cow_debt[slot] += len(new)

    def clear_prefix(self) -> int:
        """Drop every prefix-index reference (a block returns to the free
        list when that was its last one); returns blocks freed."""
        if self.prefix is None:
            return 0
        freed = 0
        for b in self.prefix.drop_all():
            self._ref[b] -= 1
            if self._ref[b] <= 0:
                self._ref[b] = 0
                self.caches = self._zero_block(self.caches, jnp.int32(b))
                self._free.append(int(b))
                freed += 1
        return freed

    # ---- pool ops ----------------------------------------------------

    def admit(self, slot: int, request_cache, plen: int, n_tokens: int,
              *, prompt=None, prefix_blocks=None, start: int = 0) -> None:
        """Reserve blocks for ``n_tokens`` total positions and install the
        (batch-1) prefill cache of a ``plen``-token prompt into ``slot``.

        ``prefix_blocks`` (from :meth:`prefix_match`) maps the matched
        blocks by reference — ``request_cache`` is then the *tail* cache
        of the resumed prefill (positions ``m..plen-1``), scattered at
        block offset ``m``.  ``prompt`` (when the prefix index is
        attached) registers the request's fully covered blocks for future
        hits.  ``start`` offsets the source read into ``request_cache``
        — a packed prefill emits several segments in one stream and each
        segment admits from its own span (incompatible with
        ``prefix_blocks``; packed segments are trie misses by
        construction).  Callers must check :meth:`can_admit` first; an
        insufficient free list here is a scheduler bug, not back-pressure.

        A *chunked* admit passes ``plen < plen_total`` with ``n_tokens``
        covering the whole request — the full reservation happens up
        front (mid-prefill block reservation), later chunks land via
        :meth:`extend`, and ``prompt=None`` defers trie registration to
        :meth:`register_prefix` at completion.
        """
        if self._slot_blocks[slot]:
            raise RuntimeError(f"slot {slot} already holds blocks")
        need = self.blocks_needed(n_tokens)
        mapped = [int(b) for b in (prefix_blocks or [])]
        if len(mapped) > need:
            raise RuntimeError(
                f"slot {slot}: prefix of {len(mapped)} blocks exceeds the "
                f"reservation of {need}")
        fresh_n = need - len(mapped)
        if fresh_n > len(self._free):
            raise RuntimeError(
                f"free list underflow: slot {slot} needs {fresh_n} blocks, "
                f"{len(self._free)} free — check can_admit() before admit")
        for b in mapped:
            self._ref[b] += 1
        fresh = [self._free.pop() for _ in range(fresh_n)]
        for b in fresh:
            self._ref[b] = 1
        blocks = mapped + fresh
        self._slot_blocks[slot] = blocks
        self._table[slot] = 0
        self._table[slot, :need] = blocks
        self._table_dev = None
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.blocks_in_use)
        if mapped:
            m = len(mapped) * self.block_size
            tail_row = self._table[slot].copy()
            tail_row[:len(mapped)] = 0  # prefix blocks are never re-written
            self.caches = self._assign_tail(
                self.caches, request_cache, jnp.asarray(tail_row),
                jnp.int32(slot), jnp.int32(plen), jnp.int32(m))
        else:
            self.caches = self._assign(self.caches, request_cache,
                                       jnp.asarray(self._table[slot]),
                                       jnp.int32(slot), jnp.int32(plen),
                                       jnp.int32(start))
        wrap = self._will_wrap(n_tokens)
        if wrap:
            self._cow_debt[slot] += len(mapped)
        if self.prefix is not None and prompt is not None:
            self._register(slot, prompt, plen, wrap)

    def extend(self, slot: int, request_cache, m: int, new_len: int) -> None:
        """Install a prefill continuation chunk: ``request_cache`` holds
        positions ``m..new_len-1`` of ``slot``'s prompt (the tail cache a
        resumed prefill emits), scattered at block offset ``m`` into the
        slot's *already reserved* blocks (see the chunked note on
        :meth:`admit`).  ``m`` must be block-aligned; the first ``m //
        block_size`` table entries are sentinel'd so the chunk's writes
        never touch blocks earlier chunks filled."""
        if not self._slot_blocks[slot]:
            raise RuntimeError(f"slot {slot} holds no blocks to extend")
        if m % self.block_size:
            raise ValueError(f"extend offset {m} not block-aligned")
        row = self._table[slot].copy()
        row[:m // self.block_size] = 0
        self.caches = self._assign_tail(
            self.caches, request_cache, jnp.asarray(row),
            jnp.int32(slot), jnp.int32(new_len), jnp.int32(m))

    def slot_blocks(self, slot: int) -> List[int]:
        """The slot's physical block ids in logical order (a copy) — the
        chunked scheduler reads back the leading ``done // block_size``
        of these as the prefix operand of the next chunk's resume."""
        return list(self._slot_blocks[slot])

    def register_prefix(self, slot: int, prompt, plen: int,
                        n_tokens: int) -> None:
        """Adopt a completed chunked prefill's prompt blocks into the
        prefix index — the deferred ``prompt=`` leg of :meth:`admit`
        (chunked admits pass ``prompt=None``; registering a half-written
        prompt would serve bogus hits)."""
        if self.prefix is None or prompt is None:
            return
        self._register(slot, prompt, plen, self._will_wrap(n_tokens))

    def fork(self, src: int, dst: int, pos: int, n_tokens: int) -> None:
        """Map ``src``'s content blocks (positions ``< pos``) into ``dst``
        by reference — the parallel-sampling (n>1) primitive: no prefill,
        no copy.  Fresh private blocks cover the rest of ``dst``'s
        reservation; the slot-indexed (non-paged) leaves are copied.  The
        first divergent write into the shared boundary block — the
        partially filled one when ``pos % block_size != 0`` — copies on
        write (:meth:`ensure_writable`)."""
        if self._slot_blocks[dst]:
            raise RuntimeError(f"slot {dst} already holds blocks")
        need = self.blocks_needed(n_tokens)
        content = min(-(-int(pos) // self.block_size), need)
        shared = [int(b) for b in self._slot_blocks[src][:content]]
        fresh_n = need - len(shared)
        if fresh_n > len(self._free):
            raise RuntimeError(
                f"free list underflow: fork into slot {dst} needs "
                f"{fresh_n} blocks, {len(self._free)} free")
        for b in shared:
            self._ref[b] += 1
        fresh = [self._free.pop() for _ in range(fresh_n)]
        for b in fresh:
            self._ref[b] = 1
        blocks = shared + fresh
        self._slot_blocks[dst] = blocks
        self._table[dst] = 0
        self._table[dst, :need] = blocks
        self._table_dev = None
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.blocks_in_use)
        if shared:
            if self._will_wrap(n_tokens):
                self._cow_debt[dst] += len(shared)
            elif int(pos) % self.block_size:
                self._cow_debt[dst] += 1   # the shared boundary block
        self.caches = self._copy_state(self.caches, jnp.int32(src),
                                       jnp.int32(dst))

    def ensure_writable(self, slot: int, pos: int) -> int:
        """Guarantee the block receiving ``slot``'s write at ``pos`` is
        private: a shared target is copied-on-write into a fresh block
        first (the other referents — sibling slots, the prefix index —
        keep the original bits).  Returns copies made (0 or 1).  The
        scheduler calls this for every active slot before each decode
        step; ``has_shared`` short-circuits the common all-private case.
        """
        blocks = self._slot_blocks[slot]
        if not blocks:
            return 0
        p = int(pos) % self.lcap if self.cfg.sliding_window else int(pos)
        bi = p // self.block_size
        if bi >= len(blocks):
            return 0
        b = blocks[bi]
        if self._ref[b] <= 1:
            return 0
        if not self._free:
            self._reclaim(1)
        if not self._free:
            raise RuntimeError(
                f"free list underflow on COW for slot {slot} (block {b}) "
                f"— admission under-budgeted its _cow_debt")
        new = self._free.pop()
        self._ref[new] = 1
        self._ref[b] -= 1
        blocks[bi] = new
        self._table[slot, bi] = new
        self._table_dev = None
        self.caches = self._cow(self.caches, jnp.int32(b), jnp.int32(new))
        self.cow_copies += 1
        if self._cow_debt[slot] > 0:
            self._cow_debt[slot] -= 1
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.blocks_in_use)
        return 1

    def evict(self, slot: int) -> None:
        """Drop the slot's block references: a block is zeroed and
        returned to the free list only when its refcount hits zero —
        blocks still shared with other slots or held by the prefix index
        survive untouched (the zeroing scatter routes their table entries
        to the trash block)."""
        blocks = self._slot_blocks[slot]
        if blocks:
            row = self._table[slot].copy()
            freed = []
            for i, b in enumerate(blocks):
                self._ref[b] -= 1
                if self._ref[b] <= 0:
                    self._ref[b] = 0
                    freed.append(b)
                else:
                    row[i] = 0   # still referenced: zero the trash instead
            self.caches = self._evict(self.caches, jnp.asarray(row),
                                      jnp.int32(slot))
            self._free.extend(reversed(freed))
        self._slot_blocks[slot] = []
        self._table[slot] = 0
        self._cow_debt[slot] = 0
        self._table_dev = None

    def read_slot(self, slot: int):
        """The (batch-1) *logical* cache view of ``slot``: paged leaves are
        gathered back to position-ordered ``(ns, 1, lcap, ...)`` arrays
        (sentinel blocks read the trash block — callers mask by length),
        slot-state leaves sliced as-is.  Tests/inspection."""
        return self._read(self.caches, jnp.asarray(self._table[slot]),
                          jnp.int32(slot))

    def stats(self) -> Dict[str, float]:
        """Occupancy snapshot for ``ServingMetrics.sample_pool`` (see the
        module docstring for the tokens_reserved / tokens_in_use
        contract)."""
        used = self.blocks_in_use
        reserved = sum(len(b) for b in self._slot_blocks)
        return {
            "kv_bytes_in_use": float(used * self.block_bytes),
            "kv_bytes_reserved": float((self.num_blocks - 1)
                                       * self.block_bytes),
            "blocks_in_use": float(used),
            "blocks_total": float(self.num_blocks - 1),
            # logical per-slot reservation: a shared block counts once per
            # referencing slot (what each request would own privately)
            "tokens_reserved": float(reserved * self.block_size),
            # physical occupancy: every allocated block exactly once
            "tokens_in_use": float(used * self.block_size),
            "blocks_shared": float(int((self._ref > 1).sum())),
            "prefix_blocks": float(self.prefix.n_blocks
                                   if self.prefix is not None else 0),
            "cow_copies": float(self.cow_copies),
        }
