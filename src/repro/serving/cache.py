"""Slot-indexed decode-cache pool for continuous batching.

The pool is the full decode-cache tree of ``models.model.cache_specs`` at
``(max_batch, max_len)`` — allocated **once**, never reshaped.  Requests
come and go by *slot index*: admit writes a prefill cache into slot ``s``
with ``lax.dynamic_update_slice_in_dim`` on the batch dim, evict zeroes it
the same way.  Both are jitted once with the slot as a traced scalar, so a
churning request mix never recompiles anything.

Under a mesh the pool is placed by ``dist.cache_pspecs(...,
batch_over_dp=False)``: heads shard over "model", but the slot dim stays
replicated — continuous batching touches arbitrary slots every step, and a
DP-sharded slot dim would make each admit a cross-device scatter.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.dist import partitioning as dpart
from repro.models import model_lib as M
from repro.models.config import ModelConfig

__all__ = ["CachePool"]


class CachePool:
    """Decode caches for ``max_batch`` slots of up to ``max_len`` tokens.

    ``caches`` is the live cache tree threaded through the jitted decode
    step; the scheduler re-binds it after every step.  ``assign`` expects a
    single-request prefill cache (batch dim 1) produced at the pool's
    ``max_len`` capacity (i.e. with ``cfg.max_seq_len == max_len``).
    """

    def __init__(self, cfg: ModelConfig, max_batch: int,
                 max_len: Optional[int] = None, *, mesh=None):
        self.max_batch = max_batch
        self.max_len = max_len or cfg.max_seq_len
        specs = M.cache_specs(cfg, max_batch, self.max_len)
        self.caches: Dict[str, Any] = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        if mesh is not None:
            self.caches = jax.device_put(self.caches, dpart.tree_shardings(
                dpart.cache_pspecs(self.caches, mesh, batch_over_dp=False),
                mesh))

        def assign(pool, request_cache, slot):
            return jax.tree.map(
                lambda c, r: jax.lax.dynamic_update_slice_in_dim(
                    c, r.astype(c.dtype), slot, axis=1),
                pool, request_cache)

        def evict(pool, slot):
            return jax.tree.map(
                lambda c: jax.lax.dynamic_update_slice_in_dim(
                    c, jnp.zeros(c.shape[:1] + (1,) + c.shape[2:], c.dtype),
                    slot, axis=1),
                pool)

        def read(pool, slot):
            return jax.tree.map(
                lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1),
                pool)

        self._assign = jax.jit(assign)
        self._evict = jax.jit(evict)
        self._read = jax.jit(read)

    def assign(self, slot: int, request_cache) -> None:
        """Install a (batch-1) prefill cache into ``slot``."""
        self.caches = self._assign(self.caches, request_cache,
                                   jnp.int32(slot))

    def evict(self, slot: int) -> None:
        """Zero ``slot`` (logical free; keeps stale KV out of the pool)."""
        self.caches = self._evict(self.caches, jnp.int32(slot))

    def read_slot(self, slot: int):
        """The (batch-1) cache view of ``slot`` — tests/inspection."""
        return self._read(self.caches, jnp.int32(slot))
