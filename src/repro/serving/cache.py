"""Decode-cache pools for continuous batching: slot-contiguous and paged.

Two pool layouts share one scheduler-facing API (``can_admit`` /
``admit`` / ``evict`` / ``read_slot`` / ``stats``):

:class:`CachePool` is the naive layout — the full decode-cache tree of
``models.model.cache_specs`` at ``(max_batch, max_len)``, allocated once;
every slot reserves worst-case ``max_len`` KV whether its request needs 10
tokens or 10k.  Admits/evicts are single jitted ``dynamic_update_slice``
writes on the batch dim.

:class:`PagedCachePool` is the PartitionPIM move applied to HBM: just as
the paper divides one fixed crossbar into dynamic partitions so
independent work shares the substrate without worst-case reservation, the
paged pool divides each attention-KV leaf into a ``(num_blocks,
block_size, ...)`` physical store shared by all slots.  A per-slot block
table (``(max_batch, blocks_per_slot)`` int32, sentinel ``0`` pointing at
a reserved trash block) maps logical token blocks to physical ones; a
host-side free-list allocator reserves exactly
``ceil((prompt + budget) / block_size)`` blocks per request at admit time
(admission defers when the free list is short — never a mid-decode OOM),
and evict returns the blocks.  The jitted decode step reads through a
gather on the block table, whose shape is fixed, so block churn never
recompiles anything.

Paging is also what unblocks **sliding-window serving**: a windowed slot
is a *ring* over its block list with capacity ``ceil(window / block) *
block`` — prefill installs the last ``min(prompt, window)`` positions,
decode wraps, and the reservation stops depending on prompt + generation
length entirely.  Recurrent state (ssm/conv, xLSTM c/n/m) and
cross-attention memory are fixed-size per slot and stay slot-indexed in
both pools (``models.model.PAGED_KV_KEYS`` names what pages).

Under a mesh both pools are placed by ``dist.cache_pspecs(...,
batch_over_dp=False)``: heads shard over "model", but the slot dim — and
for paged leaves the *block* dim in its place — stays replicated:
continuous batching touches arbitrary slots/blocks every step, and a
sharded dim 1 would make each admit a cross-device scatter.  Block tables
are tiny int32 and replicated.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import partitioning as dpart
from repro.models import model_lib as M
from repro.models.config import ModelConfig

__all__ = ["CachePool", "PagedCachePool"]


def _kv_leaf_bytes(tree) -> int:
    """Bytes of the attention-KV (pageable) leaves of a cache tree."""
    total = 0
    for c in tree.values():
        for key in M.PAGED_KV_KEYS:
            if key in c:
                leaf = c[key]
                total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype
                                                              ).itemsize
    return total


class CachePool:
    """Decode caches for ``max_batch`` slots of up to ``max_len`` tokens.

    ``caches`` is the live cache tree threaded through the jitted decode
    step; the scheduler re-binds it after every step.  ``assign`` expects a
    single-request prefill cache (batch dim 1) produced at the pool's
    ``max_len`` capacity (i.e. with ``cfg.max_seq_len == max_len``).
    """

    paged = False
    block_tables = None            # uniform scheduler interface

    def __init__(self, cfg: ModelConfig, max_batch: int,
                 max_len: Optional[int] = None, *, mesh=None):
        self.max_batch = max_batch
        self.max_len = max_len or cfg.max_seq_len
        self.max_tokens = self.max_len          # per-slot token capacity
        specs = M.cache_specs(cfg, max_batch, self.max_len)
        self.kv_reserved_bytes = _kv_leaf_bytes(specs)
        self.caches: Dict[str, Any] = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        if mesh is not None:
            self.caches = jax.device_put(self.caches, dpart.tree_shardings(
                dpart.cache_pspecs(self.caches, mesh, batch_over_dp=False),
                mesh))

        def assign(pool, request_cache, slot):
            return jax.tree.map(
                lambda c, r: jax.lax.dynamic_update_slice_in_dim(
                    c, r.astype(c.dtype), slot, axis=1),
                pool, request_cache)

        def evict(pool, slot):
            return jax.tree.map(
                lambda c: jax.lax.dynamic_update_slice_in_dim(
                    c, jnp.zeros(c.shape[:1] + (1,) + c.shape[2:], c.dtype),
                    slot, axis=1),
                pool)

        def read(pool, slot):
            return jax.tree.map(
                lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1),
                pool)

        self._assign = jax.jit(assign)
        self._evict = jax.jit(evict)
        self._read = jax.jit(read)

    def assign(self, slot: int, request_cache) -> None:
        """Install a (batch-1) prefill cache into ``slot``."""
        self.caches = self._assign(self.caches, request_cache,
                                   jnp.int32(slot))

    def evict(self, slot: int) -> None:
        """Zero ``slot`` (logical free; keeps stale KV out of the pool)."""
        self.caches = self._evict(self.caches, jnp.int32(slot))

    def read_slot(self, slot: int):
        """The (batch-1) cache view of ``slot`` — tests/inspection."""
        return self._read(self.caches, jnp.int32(slot))

    # ---- uniform pool interface -------------------------------------

    def can_admit(self, n_tokens: int) -> bool:
        """Contiguous slots always fit (capacity was reserved up front)."""
        return True

    def admit(self, slot: int, request_cache, plen: int,
              n_tokens: int) -> None:
        self.assign(slot, request_cache)

    def stats(self) -> Dict[str, float]:
        """Occupancy snapshot.  The contiguous pool's KV bytes are its
        static worst-case reservation — that constant is exactly what the
        paged pool's ``bytes_in_use`` undercuts on long-tail traces."""
        return {
            "kv_bytes_in_use": float(self.kv_reserved_bytes),
            "kv_bytes_reserved": float(self.kv_reserved_bytes),
            "blocks_in_use": float(self.max_batch),
            "blocks_total": float(self.max_batch),
            "tokens_reserved": float(self.max_batch * self.max_len),
        }


class PagedCachePool:
    """Block-paged decode caches: attention KV in shared physical blocks.

    ``block_size`` tokens per block; ``num_blocks`` physical blocks per KV
    leaf (default: full parity with the contiguous pool — every slot can
    hold ``blocks_per_slot`` blocks — plus the reserved trash block; pass
    something smaller to actually oversubscribe).  Block 0 is never
    allocated: it is the sentinel target of unassigned block-table entries,
    absorbing the garbage writes of inactive decode slots.

    ``admit`` expects a (batch-1) prefill cache and the request's true
    prompt length: the paged leaves are *converted* — gathered from the
    prefill layout (dense, or the windowed ring) into position-ordered
    logical blocks, invalid positions zeroed — and scattered to the slot's
    physical blocks in one jitted op per prefill bucket shape.
    """

    paged = True

    def __init__(self, cfg: ModelConfig, max_batch: int,
                 max_len: Optional[int] = None, *, block_size: int = 16,
                 num_blocks: Optional[int] = None, mesh=None):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len or cfg.max_seq_len
        self.block_size = block_size
        ring = cfg.window_ring_blocks(block_size)
        self.blocks_per_slot = (ring if ring is not None
                                else -(-self.max_len // block_size))
        self.lcap = self.blocks_per_slot * block_size   # logical tokens/slot
        # windowed slots can generate forever (the ring wraps); unwindowed
        # ones are bounded by the configured horizon, NOT the block-rounded
        # lcap — the layout must never admit a request the contiguous pool
        # would reject (positions past max_len are outside the declared
        # context even when rounding leaves physical room)
        self.max_tokens = (None if cfg.sliding_window else self.max_len)
        self.num_blocks = (num_blocks if num_blocks is not None
                           else max_batch * self.blocks_per_slot + 1)
        if self.num_blocks < 2:
            raise ValueError("num_blocks must be >= 2 (block 0 is the "
                             "reserved sentinel/trash block)")
        self._mesh = mesh

        specs = M.paged_cache_specs(cfg, max_batch, self.max_len,
                                    self.num_blocks, block_size)
        per_pool = _kv_leaf_bytes(specs)
        self.block_bytes = per_pool // self.num_blocks  # all leaves/layers
        self._has_paged_leaves = per_pool > 0
        self.caches: Dict[str, Any] = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        if mesh is not None:
            self.caches = jax.device_put(self.caches, dpart.tree_shardings(
                dpart.cache_pspecs(self.caches, mesh, batch_over_dp=False),
                mesh))

        # host allocator state: free-list (LIFO keeps reuse warm), per-slot
        # block lists, and the sentinel-padded table mirrored to device
        self._free: List[int] = list(range(self.num_blocks - 1, 0, -1))
        self._slot_blocks: List[List[int]] = [[] for _ in range(max_batch)]
        self._table = np.zeros((max_batch, self.blocks_per_slot), np.int32)
        self._table_dev = None
        self.peak_blocks_in_use = 0

        window = cfg.sliding_window
        lcap, bs = self.lcap, block_size

        def assign(pool, request_cache, table_row, slot, plen):
            def paged_leaf(c, rleaf):
                # rleaf (ns, 1, cap_p, ...): dense positions 0..cap_p-1, or
                # — windowed — position p at ring index p % cap_p.
                cap_p = rleaf.shape[2]
                r = jnp.arange(lcap)
                if window:
                    # same congruence the paged decode read applies (the
                    # windowed pool's lcap IS the ring capacity)
                    p_r, valid = M.ring_slot_positions(plen - 1, r, lcap,
                                                       window)
                else:
                    p_r = r
                    valid = r < plen
                src = p_r % cap_p
                logical = jnp.take(rleaf[:, 0], src, axis=1)  # (ns, lcap,...)
                vshape = (1, lcap) + (1,) * (logical.ndim - 2)
                logical = jnp.where(valid.reshape(vshape), logical, 0)
                blocks = logical.reshape(
                    (logical.shape[0], self.blocks_per_slot, bs)
                    + logical.shape[2:]).astype(c.dtype)
                # sentinel-padded rows scatter their tail into trash block 0
                return c.at[:, table_row].set(blocks)

            out = {}
            for li, c in pool.items():
                oc = {}
                for key, leaf in c.items():
                    if key in M.PAGED_KV_KEYS:
                        oc[key] = paged_leaf(leaf, request_cache[li][key])
                    else:
                        oc[key] = jax.lax.dynamic_update_slice_in_dim(
                            leaf, request_cache[li][key].astype(leaf.dtype),
                            slot, axis=1)
                out[li] = oc
            return out

        def evict(pool, table_row, slot):
            out = {}
            for li, c in pool.items():
                oc = {}
                for key, leaf in c.items():
                    if key in M.PAGED_KV_KEYS:
                        z = jnp.zeros((leaf.shape[0], self.blocks_per_slot,
                                       bs) + leaf.shape[3:], leaf.dtype)
                        oc[key] = leaf.at[:, table_row].set(z)
                    else:
                        oc[key] = jax.lax.dynamic_update_slice_in_dim(
                            leaf, jnp.zeros(
                                leaf.shape[:1] + (1,) + leaf.shape[2:],
                                leaf.dtype), slot, axis=1)
                out[li] = oc
            return out

        def read(pool, table_row, slot):
            out = {}
            for li, c in pool.items():
                oc = {}
                for key, leaf in c.items():
                    if key in M.PAGED_KV_KEYS:
                        g = leaf[:, table_row]          # (ns, bps, bs, ...)
                        oc[key] = g.reshape((g.shape[0], 1, lcap)
                                            + g.shape[3:])
                    else:
                        oc[key] = jax.lax.dynamic_slice_in_dim(
                            leaf, slot, 1, axis=1)
                out[li] = oc
            return out

        self._assign = jax.jit(assign)
        self._evict = jax.jit(evict)
        self._read = jax.jit(read)

    # ---- allocator ---------------------------------------------------

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - 1 - len(self._free)

    def blocks_needed(self, n_tokens: int) -> int:
        if not self._has_paged_leaves:   # pure-recurrent stack: nothing pages
            return 0
        return min(self.cfg.kv_blocks_for(n_tokens, self.block_size),
                   self.blocks_per_slot)

    def can_admit(self, n_tokens: int) -> bool:
        """Whether the free list covers a request writing ``n_tokens``."""
        return self.blocks_needed(n_tokens) <= len(self._free)

    @property
    def block_tables(self) -> jnp.ndarray:
        """Device copy of the (max_batch, blocks_per_slot) table,
        replicated under the pool's mesh."""
        if self._table_dev is None:
            t = jnp.asarray(self._table)
            if self._mesh is not None:
                t = jax.device_put(t, jax.sharding.NamedSharding(
                    self._mesh, jax.sharding.PartitionSpec()))
            self._table_dev = t
        return self._table_dev

    # ---- pool ops ----------------------------------------------------

    def admit(self, slot: int, request_cache, plen: int,
              n_tokens: int) -> None:
        """Reserve blocks for ``n_tokens`` total positions and install the
        (batch-1) prefill cache of a ``plen``-token prompt into ``slot``.

        Callers must check :meth:`can_admit` first; an insufficient free
        list here is a scheduler bug, not back-pressure.
        """
        if self._slot_blocks[slot]:
            raise RuntimeError(f"slot {slot} already holds blocks")
        need = self.blocks_needed(n_tokens)
        if need > len(self._free):
            raise RuntimeError(
                f"free list underflow: slot {slot} needs {need} blocks, "
                f"{len(self._free)} free — check can_admit() before admit")
        blocks = [self._free.pop() for _ in range(need)]
        self._slot_blocks[slot] = blocks
        self._table[slot] = 0
        self._table[slot, :need] = blocks
        self._table_dev = None
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.blocks_in_use)
        self.caches = self._assign(self.caches, request_cache,
                                   jnp.asarray(self._table[slot]),
                                   jnp.int32(slot), jnp.int32(plen))

    def evict(self, slot: int) -> None:
        """Zero the slot's physical blocks and return them to the free
        list (stale KV never leaks into the next tenant)."""
        if self._slot_blocks[slot]:
            self.caches = self._evict(self.caches,
                                      jnp.asarray(self._table[slot]),
                                      jnp.int32(slot))
        self._free.extend(reversed(self._slot_blocks[slot]))
        self._slot_blocks[slot] = []
        self._table[slot] = 0
        self._table_dev = None

    def read_slot(self, slot: int):
        """The (batch-1) *logical* cache view of ``slot``: paged leaves are
        gathered back to position-ordered ``(ns, 1, lcap, ...)`` arrays
        (sentinel blocks read the trash block — callers mask by length),
        slot-state leaves sliced as-is.  Tests/inspection."""
        return self._read(self.caches, jnp.asarray(self._table[slot]),
                          jnp.int32(slot))

    def stats(self) -> Dict[str, float]:
        """Occupancy snapshot for ``ServingMetrics.sample_pool``."""
        used = self.blocks_in_use
        return {
            "kv_bytes_in_use": float(used * self.block_bytes),
            "kv_bytes_reserved": float((self.num_blocks - 1)
                                       * self.block_bytes),
            "blocks_in_use": float(used),
            "blocks_total": float(self.num_blocks - 1),
            "tokens_reserved": float(used * self.block_size),
        }
