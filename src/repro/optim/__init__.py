from repro.optim.adamw import AdamWConfig, apply_updates, cosine_schedule, init_state

__all__ = ["AdamWConfig", "apply_updates", "cosine_schedule", "init_state"]
