"""AdamW in pure JAX, with the distributed-memory options the big configs
need: configurable moment dtypes and an Adafactor-style factored second
moment (rank-1 row/col statistics for >=2D tensors) that cuts optimizer
state from 8 bytes/param to ~2 — the difference between arctic-480b fitting
a 256-chip pod or not (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_state", "apply_updates", "cosine_schedule",
           "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"      # "float32" | "bfloat16"
    factored: bool = False             # factored second moment (>=2D leaves)
    factored_min_dim: int = 128


def cosine_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return warm * cos


def _mdt(cfg: AdamWConfig):
    return jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32


def _is_factored(cfg: AdamWConfig, shape) -> bool:
    return (cfg.factored and len(shape) >= 2
            and shape[-1] >= cfg.factored_min_dim
            and shape[-2] >= cfg.factored_min_dim)


def init_state(cfg: AdamWConfig, params) -> Dict[str, Any]:
    mdt = _mdt(cfg)

    def leaf_state(p):
        st = {"m": jnp.zeros(p.shape, mdt)}
        if _is_factored(cfg, p.shape):
            st["vr"] = jnp.zeros(p.shape[:-1], jnp.float32)        # row stats
            st["vc"] = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        else:
            st["v"] = jnp.zeros(p.shape, jnp.float32)
        return st

    return {"step": jnp.zeros((), jnp.int32),
            "leaves": jax.tree.map(leaf_state, params)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = _mdt(cfg)

    def upd(p, g, st):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * st["m"].astype(jnp.float32) + (1 - cfg.b1) * g
        if "v" in st:
            v = cfg.b2 * st["v"] + (1 - cfg.b2) * g * g
            denom = jnp.sqrt(v / bc2) + cfg.eps
            new_v = {"v": v}
        else:
            g2 = g * g + 1e-30
            vr = cfg.b2 * st["vr"] + (1 - cfg.b2) * g2.mean(axis=-1)
            vc = cfg.b2 * st["vc"] + (1 - cfg.b2) * g2.mean(axis=-2)
            # rank-1 reconstruction: v ~ vr vc / mean(vr)
            mean_r = vr.mean(axis=-1, keepdims=True)
            v_hat = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(mean_r[..., None], 1e-30))
            denom = jnp.sqrt(v_hat / bc2) + cfg.eps
            new_v = {"vr": vr, "vc": vc}
        update = (m / bc1) / denom + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return new_p, {"m": m.astype(mdt), **new_v}

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state["leaves"])
    new_p, new_s = [], []
    for p, g, st in zip(flat_p, flat_g, flat_s):
        np_, ns = upd(p, g, st)
        new_p.append(np_)
        new_s.append(ns)
    return (jax.tree.unflatten(tdef, new_p),
            {"step": step, "leaves": jax.tree.unflatten(tdef, new_s)},
            {"lr": lr, "grad_norm": gnorm})
