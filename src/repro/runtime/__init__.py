from repro.runtime.fault_tolerance import (CheckpointManager, ElasticMesh,
                                           StragglerMonitor, run_with_restarts)
