from repro.runtime.fault_tolerance import (CheckpointManager, ElasticMesh,
                                           StragglerMonitor, run_with_restarts)

__all__ = ["CheckpointManager", "ElasticMesh", "StragglerMonitor",
           "run_with_restarts"]
