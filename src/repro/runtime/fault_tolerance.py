"""Fault tolerance & elasticity for long-running jobs (DESIGN.md §4).

* :class:`CheckpointManager` — cadence + retention + auto-resume around
  ``repro.checkpoint``; the data pipeline is stateless-indexed, so resume is
  "load params/opt, continue at manifest step".
* :func:`run_with_restarts` — supervisor loop: on worker failure, restore the
  latest checkpoint and continue (bounded retries).  On a real cluster the
  restart comes from the scheduler re-launching the job; the logic is the
  same because all state lives in (checkpoint, step).
* :class:`ElasticMesh` — re-derive a (pod, data, model) mesh from however
  many devices survive, preferring to shrink the data axis (model shards
  must stay intact to reshard checkpoints cheaply).
* :class:`StragglerMonitor` — EWMA step-time outlier detection; on a real
  deployment this feeds the backup-replica promotion hook.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax

from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                         save_checkpoint, available_steps)

__all__ = ["CheckpointManager", "run_with_restarts", "ElasticMesh",
           "StragglerMonitor"]


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    every_steps: int = 100
    keep: int = 3
    shard_count: int = 1

    def maybe_save(self, step: int, tree, metadata: Optional[Dict] = None):
        if step % self.every_steps:
            return None
        path = save_checkpoint(self.directory, step, tree,
                               metadata=metadata, shard_count=self.shard_count)
        self._gc()
        return path

    def save(self, step: int, tree, metadata: Optional[Dict] = None):
        path = save_checkpoint(self.directory, step, tree,
                               metadata=metadata, shard_count=self.shard_count)
        self._gc()
        return path

    def _gc(self):
        import shutil

        steps = available_steps(self.directory)
        for s in steps[:-self.keep]:
            shutil.rmtree(f"{self.directory}/step_{s:08d}", ignore_errors=True)

    def resume(self, like) -> Tuple[Optional[int], Optional[object], Dict]:
        step = latest_step(self.directory)
        if step is None:
            return None, None, {}
        tree, meta = restore_checkpoint(self.directory, step, like)
        return step, tree, meta


def run_with_restarts(worker: Callable[[Optional[int]], int],
                      manager: CheckpointManager,
                      max_restarts: int = 3) -> int:
    """Run ``worker(resume_step)``; on failure restore and retry.

    ``worker`` must checkpoint through ``manager`` and return the final step.
    Used by the fault-injection test: the worker raises mid-run, the
    supervisor resumes from the last durable step, and training completes
    with bit-identical data order (stateless pipeline indexing).
    """
    restarts = 0
    while True:
        resume_at = latest_step(manager.directory)
        try:
            return worker(resume_at)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            time.sleep(0.01)


class ElasticMesh:
    """Build the largest valid (pod, data, model) mesh from live devices."""

    def __init__(self, model_parallel: int, pods: int = 1):
        self.model_parallel = model_parallel
        self.pods = pods

    def make(self, devices: Optional[Sequence] = None):
        devices = list(devices if devices is not None else jax.devices())
        n = len(devices)
        tp = self.model_parallel
        while tp > 1 and n % tp:
            tp //= 2  # degrade model parallelism if devices don't divide
        dp_total = n // tp
        pods = self.pods if dp_total % self.pods == 0 else 1
        data = dp_total // pods
        import numpy as np

        dev_arr = np.array(devices[:pods * data * tp]).reshape(pods, data, tp)
        return jax.sharding.Mesh(dev_arr, ("pod", "data", "model"))


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.1
    threshold: float = 2.0
    _ewma: float = 0.0
    _n: int = 0
    flagged: int = 0

    def record(self, step_time_s: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        if self._n == 0:
            self._ewma = step_time_s
        slow = self._n > 3 and step_time_s > self.threshold * self._ewma
        self._ewma = (1 - self.alpha) * self._ewma + self.alpha * step_time_s
        self._n += 1
        if slow:
            self.flagged += 1
        return slow

    def reset(self) -> None:
        """Forget history — e.g. after a replica respawn, whose first
        steps re-pay compilation and must not inherit the dead replica's
        EWMA baseline."""
        self._ewma = 0.0
        self._n = 0
        self.flagged = 0
