"""Pre-jax-import XLA flag plumbing (import must never initialize jax).

The test suite (tests/conftest.py) forces 8 host CPU devices; the
standalone dry-run CLI forces 512.  Both go through this helper so the
"first writer wins" handshake lives in exactly one place.
"""
from __future__ import annotations

import os

_COUNT_FLAG = "--xla_force_host_platform_device_count"

__all__ = ["ensure_host_device_count"]


def ensure_host_device_count(n: int) -> bool:
    """Prepend ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS
    unless a device count is already forced (the earlier writer wins).
    Only effective before jax initializes.  Returns True if it wrote."""
    flags = os.environ.get("XLA_FLAGS", "")
    if _COUNT_FLAG in flags:
        return False
    os.environ["XLA_FLAGS"] = f"{_COUNT_FLAG}={n} {flags}"
    return True
