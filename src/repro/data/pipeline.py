"""Deterministic synthetic LM data pipeline.

Stateless indexing: ``batch_at(step)`` is a pure function of
``(seed, step, shard)``, so resume-after-failure needs only the step number
from the checkpoint manifest — no iterator state to persist, no skip-ahead
replay cost.  Each host materializes only its shard's rows.

The stream is learnable (so smoke-training shows loss decrease): a seeded
token-bigram chain over the vocabulary with periodic copy spans.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

__all__ = ["SyntheticLM", "AudioStub", "VisionStub"]


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard_index: int = 0
    shard_count: int = 1
    copy_span: int = 8

    def __post_init__(self):
        assert self.global_batch % self.shard_count == 0
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # sparse bigram successor table: each token has 4 likely successors
        self._succ = rng.integers(0, v, size=(v, 4), dtype=np.int32)

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.shard_count

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        b, s, v = self.local_batch, self.seq_len, self.vocab_size
        rng = np.random.default_rng(
            (self.seed, step, self.shard_index, 0xD5EED))
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, size=b)
        choices = rng.integers(0, 4, size=(b, s))
        jumps = rng.random((b, s)) < 0.05
        jump_to = rng.integers(0, v, size=(b, s))
        for t in range(s):
            nxt = self._succ[toks[:, t], choices[:, t]]
            toks[:, t + 1] = np.where(jumps[:, t], jump_to[:, t], nxt)
        # periodic copy spans to give the model an easy sub-task
        span = self.copy_span
        if s >= 4 * span:
            start = rng.integers(span, s - 2 * span, size=b)
            for i in range(b):
                st = start[i]
                toks[i, st + span:st + 2 * span] = toks[i, st:st + span]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


@dataclasses.dataclass
class AudioStub:
    """Precomputed frame-embedding stub for the audio frontend (DESIGN.md §3)."""

    d_model: int
    frames: int

    def batch_at(self, step: int, batch: int, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng((seed, step, 0xA0D10))
        return rng.normal(size=(batch, self.frames, self.d_model)).astype(
            np.float32) * 0.02


@dataclasses.dataclass
class VisionStub:
    """Precomputed patch-embedding stub for the vision tower (DESIGN.md §3)."""

    vision_dim: int
    n_patches: int

    def batch_at(self, step: int, batch: int, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng((seed, step, 0x5EE1))
        return rng.normal(size=(batch, self.n_patches, self.vision_dim)
                          ).astype(np.float32) * 0.02
