from repro.data.pipeline import AudioStub, SyntheticLM, VisionStub
