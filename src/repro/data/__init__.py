from repro.data.pipeline import AudioStub, SyntheticLM, VisionStub

__all__ = ["AudioStub", "SyntheticLM", "VisionStub"]
