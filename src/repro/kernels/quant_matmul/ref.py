"""Pure-jnp oracle for the fixed-point (int8) matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def quant_matmul_ref(x: jnp.ndarray, w: jnp.ndarray,
                     x_scale: jnp.ndarray, w_scale: jnp.ndarray) -> jnp.ndarray:
    """x: (M, K) int8, w: (K, N) int8, scales per row/col -> (M, N) f32."""
    acc = jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32))
    return acc.astype(jnp.float32) * x_scale[:, None] * w_scale[None, :]


def quant_matmul_int_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Exact int32 accumulation oracle."""
    return jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32))
