"""Pallas TPU kernel: int8 x int8 -> int32 blocked matmul.

The TPU-side analogue of PartitionPIM's fixed-point arithmetic: ``PIMLinear``
quantizes weights/activations to N-bit integers exactly as the crossbar
stores them, and this kernel is the MXU path for that representation
(``mode="quant"``), with per-row/per-column scales applied by the wrapper.

Block geometry: (bm, bk) x (bk, bn) -> (bm, bn), all MXU-aligned multiples
of 128 (int8 native on v5e).  K is the innermost grid axis; the int32
accumulator lives in the output block, zeroed at k==0 — the canonical
revisiting-output pattern, VMEM footprint bm*bk + bk*bn (int8) + bm*bn
(int32) = 160 KiB at the default 128/512/128 blocking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quant_matmul_int"]


def _kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul_int(x: jnp.ndarray, w: jnp.ndarray, bm: int = 128,
                     bn: int = 128, bk: int = 512,
                     interpret: bool = True) -> jnp.ndarray:
    """(M, K) int8 @ (K, N) int8 -> (M, N) int32, zero-padded to blocks."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    mp, kp = x.shape
    _, np_ = w.shape
    out = pl.pallas_call(
        _kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=interpret,
    )(x, w)
    return out[:m, :n]
