from repro.kernels.quant_matmul.ops import (
    quant_linear, quant_matmul_int, quant_matmul_int_ref, quant_matmul_ref, quantize_sym)
from repro.kernels.quant_matmul.tp import (
    tp_quant_linear, tp_split, tp_tile_shape)

__all__ = ["quant_linear", "quant_matmul_int", "quant_matmul_int_ref",
           "quant_matmul_ref", "quantize_sym", "tp_quant_linear", "tp_split",
           "tp_tile_shape"]
