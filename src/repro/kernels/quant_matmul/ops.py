"""Quantized linear op: symmetric per-channel int8, PIM-faithful rounding."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.quant_matmul.quant_matmul import quant_matmul_int
from repro.kernels.quant_matmul.ref import quant_matmul_int_ref, quant_matmul_ref

__all__ = ["quantize_sym", "quant_linear", "quant_matmul_int",
           "quant_matmul_ref", "quant_matmul_int_ref"]


def quantize_sym(x: jnp.ndarray, axis: int, bits: int = 8, amax=None):
    """Symmetric per-channel quantization -> (int8 values, f32 scales).

    ``amax`` overrides the per-channel abs-max (keepdims shape) — the
    tensor-parallel tiles pass a cross-shard ``pmax`` here so every rank
    quantizes against the *global* range while this function stays the
    single source of truth for the eps/round/clip convention.
    """
    qmax = 2 ** (bits - 1) - 1
    if amax is None:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, jnp.squeeze(scale, axis=axis).astype(jnp.float32)


def quant_linear(x: jnp.ndarray, w: jnp.ndarray, bits: int = 8,
                 backend: str = "pallas") -> jnp.ndarray:
    """y = x @ w via int8 fixed point. x: (..., K) f32/bf16, w: (K, N)."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k).astype(jnp.float32)
    xq, xs = quantize_sym(x2, axis=1, bits=bits)
    wq, ws = quantize_sym(w.astype(jnp.float32), axis=0, bits=bits)
    if backend == "pallas":
        acc = quant_matmul_int(xq, wq)
    else:
        acc = quant_matmul_int_ref(xq, wq)
    y = acc.astype(jnp.float32) * xs[:, None] * ws[None, :]
    return y.reshape(*lead, w.shape[1]).astype(x.dtype)
