"""Tensor-parallel int8 quantized linear: shard_map tiles over "model".

The mesh-level analogue of PartitionPIM's crossbar partitioning: one
logical GEMM is split into per-rank int8 tiles, each rank driving its own
Pallas ``quant_matmul_int`` over only its weight shard — partitions
multiply parallelism, exactly the paper's move, with the JAX mesh's
"model" axis as the partition dimension.

Split selection mirrors ``dist.partitioning.param_pspecs`` (via
:func:`dist.partitioning.tp_shard_dim`), so the tile split always matches
the layout the weight already lives in:

* **column-parallel** (output dim sharded) — each rank quantizes its own
  ``(K, N/R)`` shard per output column and emits its slice of the result;
  no collective.  Per-column weight scales make this *bit-identical* to
  the single-rank "quant" path: sharding columns cannot change any
  column's scale.  Non-divisible output dims zero-pad to ``R`` columns
  (padding can't perturb any real column's quantization) and slice back.
* **row-parallel** (inner dim sharded) — activation rows and weight
  columns are quantized against *global* amax (per-shard max + an exact
  ``pmax`` over "model", bit-identical to the single-rank reduction), each
  rank computes an int32 partial GEMM over its ``K/R`` slice, and a
  ``psum`` combines them.  Integer accumulation is associative, so the
  cross-rank reduce is bit-deterministic — the whole row-parallel path is
  bit-identical to single-rank "quant" too.  (Row-parallel is only ever
  *chosen* when K divides R — a non-divisible weight always routes to the
  column split, whose N-pad is always possible.)

On meshes that also carry data-parallel axes, the token (row) dim of the
activations shards over them whenever it divides — each data rank's tile
runs only its slice of the batch — and falls back to replication when it
doesn't (the tiny-decode case), mirroring ``moe_ffn``'s policy.

Each rank's tile clamps the Pallas block geometry to its (padded) shard —
the per-rank kernel iterates a grid sized for ``1/R`` of the weight, not
for the full matrix — while keeping the MXU-default caps, so shrinking
shards actually shrink per-rank work.

Differentiation is a straight-through ``custom_vjp`` (forward: the
sharded int8 tiles; backward: the ideal float matmul), the same QAT
convention as ``engine.sim_linear`` — so ``pim_mode="quant_tp"`` trains,
and the backward einsums are plain GSPMD ops that reduce-scatter /
all-reduce as the sharding dictates.

Outside any mesh (or at model=1) :func:`tp_quant_linear` degrades to the
single-rank ``quant_linear`` exactly, so "quant_tp" is always safe to pin
in a config.
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import context as dctx
from repro.dist.context import SM_CHECK_KW, shard_map
from repro.dist.partitioning import tp_shard_dim
from repro.kernels.quant_matmul.ops import quant_linear, quantize_sym
from repro.kernels.quant_matmul.quant_matmul import quant_matmul_int

__all__ = ["tp_quant_linear", "tp_split", "tp_tile_shape", "tile_summary"]


def _block(dim: int, cap: int) -> int:
    """Per-rank Pallas block edge: the shard dim padded to the int8 lane
    multiple (8), capped at the MXU-default block edge."""
    return min(cap, -(-dim // 8) * 8)


def tp_split(w_shape: Tuple[int, int], r: int) -> str:
    """``"col"`` | ``"row"``: which dim of ``(K, N)`` the tile shards.

    Follows ``partitioning.tp_shard_dim`` (largest divisible dim, ties to
    the later = column-parallel) so the split matches where
    ``param_pspecs`` put the weight.  When neither dim divides ``r`` the
    tile goes column-parallel and zero-pads N — always possible."""
    return "row" if tp_shard_dim(w_shape, r) == 0 else "col"


def tp_tile_shape(w_shape: Tuple[int, int], r: int) -> Tuple[int, int]:
    """The per-rank weight tile ``(K_loc, N_loc)`` (after pad) for ``r``
    ranks — what each rank's Pallas kernel actually sees."""
    k, n = w_shape
    if tp_split(w_shape, r) == "row":
        return (-(-k // r), n)
    return (k, -(-(n + (-n) % r) // r))


def tile_summary(cfg, r: int) -> List[str]:
    """Human-readable per-rank tile lines for a config's core projections.

    One source of truth for the shapes the tiles actually shard — the
    serving CLI's ``[tp]`` echo and the benchmark's tile rows both render
    from here, so they can never drift from :func:`tp_split` /
    :func:`tp_tile_shape`."""
    d, ff, h = cfg.d_model, cfg.d_ff, cfg.n_heads * cfg.hd
    return [
        f"{nm} {shp}->{tp_split(shp, r)} {tp_tile_shape(shp, r)}"
        for nm, shp in (("wq", (d, h)), ("w_in", (d, ff)),
                        ("w_out", (ff, d)))
    ]


def _dp_split(mesh, m: int):
    """(dp spec entry for the token dim, local token count): the data axes
    when they divide ``m`` (each data rank tiles only its batch slice),
    else replicate — the same fallback ``moe_ffn`` uses for tiny decodes."""
    dp = tuple(a for a in dctx.dp_axes() if a in mesh.axis_names
               and mesh.shape[a] > 1)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if not dp or m % dp_size:
        return None, m
    return (dp if len(dp) > 1 else dp[0]), m // dp_size


def _tp_forward(split: str, bits: int, x2, w):
    mesh = dctx.current_mesh()
    ax = dctx.tp_axis()
    r = mesh.shape[ax]
    m, k = x2.shape
    n = w.shape[1]
    dp, m_loc = _dp_split(mesh, m)

    if split == "col":
        pn = (-n) % r
        wp = jnp.pad(w, ((0, 0), (0, pn))) if pn else w
        bm, bk, bn = (_block(m_loc, 128), _block(k, 512),
                      _block((n + pn) // r, 128))

        def tile(xl, wl):
            # per-shard scales: quantize_sym's weight scales are per output
            # column, so each rank's local scales ARE the global ones (and
            # activation rows quantize independently, so a dp token split
            # changes nothing either)
            xq, xs = quantize_sym(xl, axis=1, bits=bits)
            wq, ws = quantize_sym(wl, axis=0, bits=bits)
            acc = quant_matmul_int(xq, wq, bm=bm, bn=bn, bk=bk)
            return acc.astype(jnp.float32) * xs[:, None] * ws[None, :]

        y = shard_map(tile, mesh=mesh, in_specs=(P(dp, None), P(None, ax)),
                      out_specs=P(dp, ax), **{SM_CHECK_KW: False})(x2, wp)
        return y[:, :n] if pn else y

    # row-parallel: only chosen when K % r == 0 (see tp_split), so the
    # inner dim never needs padding here
    bm, bk, bn = (_block(m_loc, 128), _block(k // r, 512), _block(n, 128))

    def tile(xl, wl):
        # global ranges from per-shard amax: max is exact, so pmax yields
        # bit-identical scales to the single-rank full-axis reduction
        # (activation rows are local to their dp rank; only K is pmax'd)
        xa = jax.lax.pmax(jnp.max(jnp.abs(xl), axis=1, keepdims=True), ax)
        wa = jax.lax.pmax(jnp.max(jnp.abs(wl), axis=0, keepdims=True), ax)
        xq, xs = quantize_sym(xl, axis=1, bits=bits, amax=xa)
        wq, ws = quantize_sym(wl, axis=0, bits=bits, amax=wa)
        # int32 partial tiles; integer psum is associative => exact
        acc = jax.lax.psum(quant_matmul_int(xq, wq, bm=bm, bn=bn, bk=bk), ax)
        return acc.astype(jnp.float32) * xs[:, None] * ws[None, :]

    return shard_map(tile, mesh=mesh, in_specs=(P(dp, ax), P(ax, None)),
                     out_specs=P(dp, None), **{SM_CHECK_KW: False})(x2, w)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _tp_mm(split: str, bits: int, x2, w):
    return _tp_forward(split, bits, x2, w)


def _tp_mm_fwd(split, bits, x2, w):
    return _tp_forward(split, bits, x2, w), (x2, w)


def _tp_mm_bwd(split, bits, res, g):
    # straight-through estimator: forward is the sharded int8 tiles,
    # backward differentiates the ideal float matmul (QAT convention,
    # matching engine.sim_linear); GSPMD shards the einsums
    x2, w = res
    gx = jnp.einsum("mn,kn->mk", g, w.astype(g.dtype)).astype(x2.dtype)
    gw = jnp.einsum("mk,mn->kn", x2.astype(g.dtype), g).astype(w.dtype)
    return gx, gw


_tp_mm.defvjp(_tp_mm_fwd, _tp_mm_bwd)


def tp_quant_linear(x, w, bits: int = 8):
    """``x @ w`` via per-rank int8 Pallas tiles over the "model" axis.

    ``x``: (..., K) float; ``w``: (K, N).  Reads the active mesh at trace
    time (like every ``dist`` helper); outside a mesh — or when the mesh
    has no tensor axis, or it has size 1 — this is exactly the single-rank
    ``quant_linear``, and *with* a mesh the result is bit-identical to it
    (see module docstring for why both splits preserve the quantization).
    """
    mesh = dctx.current_mesh()
    ax = dctx.tp_axis()
    if mesh is None or ax is None or mesh.shape[ax] <= 1:
        return quant_linear(x, w.astype(jnp.float32), bits=bits)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    split = tp_split(w.shape, mesh.shape[ax])
    y = _tp_mm(split, bits, x2, w.astype(jnp.float32))
    return y.reshape(*lead, w.shape[-1]).astype(x.dtype)
