"""Pure-jnp oracle for the crossbar microcode executor kernel.

Delegates to ``repro.pim.executor.execute`` (the lax.scan implementation) —
the same function the system uses as its jnp backend, so kernel == backend
== simulator semantics.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.pim.executor import execute as _execute


def crossbar_exec_ref(state: jnp.ndarray, microcode: jnp.ndarray) -> jnp.ndarray:
    """state: (C, n, W) uint32; microcode: (G, 4) int32 -> (C, n, W)."""
    return _execute(jnp.array(state), jnp.asarray(microcode, jnp.int32))
