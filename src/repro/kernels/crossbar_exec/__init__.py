from repro.kernels.crossbar_exec.ops import (crossbar_exec, crossbar_exec_ref,
                                              run_program)

__all__ = ["crossbar_exec", "crossbar_exec_ref", "run_program"]
