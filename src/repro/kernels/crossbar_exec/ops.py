"""Public entry point for the crossbar executor kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.crossbar_exec.crossbar_exec import crossbar_exec
from repro.kernels.crossbar_exec.ref import crossbar_exec_ref

__all__ = ["run_program", "crossbar_exec", "crossbar_exec_ref"]


def run_program(state: jnp.ndarray, microcode, backend: str = "jnp",
                w_tile: int = 128) -> jnp.ndarray:
    """Execute a Program's microcode on crossbar state.

    Thin shim over the ``repro.pim.engine`` backend registry — ``"jnp"``
    (alias ``"scan"``, the lax.scan oracle), ``"unrolled"`` (static-index
    variant), or ``"pallas"`` (interpret-mode TPU kernel on CPU; compiled
    VMEM-tiled kernel on real TPU); ``engine.register_backend`` extends the
    set without touching call sites.
    """
    from repro.pim import engine

    return engine.execute_state(state, microcode, backend=backend,
                                w_tile=w_tile)
