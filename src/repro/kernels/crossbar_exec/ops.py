"""Public entry point for the crossbar executor kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.crossbar_exec.crossbar_exec import crossbar_exec
from repro.kernels.crossbar_exec.ref import crossbar_exec_ref

__all__ = ["run_program", "crossbar_exec", "crossbar_exec_ref"]


def run_program(state: jnp.ndarray, microcode, backend: str = "jnp",
                w_tile: int = 128) -> jnp.ndarray:
    """Execute a Program's microcode on crossbar state.

    backend: "jnp" (lax.scan oracle) or "pallas" (interpret-mode TPU kernel
    on CPU; compiled VMEM-tiled kernel on real TPU).
    """
    mc = jnp.asarray(microcode, jnp.int32)
    if backend == "jnp":
        return crossbar_exec_ref(state, mc)
    if backend == "pallas":
        return crossbar_exec(state, mc, w_tile=w_tile)
    raise ValueError(f"unknown backend {backend!r}")
