"""Pallas TPU kernel: crossbar microcode executor.

TPU adaptation of stateful-logic simulation (DESIGN.md §2): crossbar state is
``(C, n, W)`` uint32 (n bitlines x W row-words); the kernel tiles
``(crossbar, row-word)`` blocks into VMEM and streams the *entire* microcode
program over the resident tile.  Arithmetic intensity therefore scales with
program length G: HBM traffic is one read + one write of the state per
program, instead of per gate — the same insight that makes partitions pay on
the memristive side (amortize the expensive resource over many gates).

Block geometry: (1, n, Wt).  The row-word axis (last, 128-lane) is the
vector axis; bitlines live on the sublane axis, so a gate's column gather /
scatter is a sublane-dynamic, lane-contiguous VMEM access.  For n=1024,
Wt=128: 512 KiB per tile + G*16 B microcode — comfortably inside VMEM, MXU
unused (pure VPU kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["crossbar_exec_kernel", "crossbar_exec"]

_ONES = jnp.uint32(0xFFFFFFFF)


def _kernel(mc_ref, state_ref, out_ref):
    out_ref[...] = state_ref[...]
    n_ops = mc_ref.shape[0]

    def body(g, _):
        # All-Slice indexing: python-int indices break the interpret-mode
        # discharge rule on jax 0.4.x (they carry no .shape attribute).
        op = pl.load(mc_ref, (pl.dslice(g, 1), slice(None)))
        code, ia, ib, dst = op[0, 0], op[0, 1], op[0, 2], op[0, 3]
        a = pl.load(out_ref, (pl.dslice(0, 1), pl.dslice(ia, 1), slice(None)))
        b = pl.load(out_ref, (pl.dslice(0, 1), pl.dslice(ib, 1), slice(None)))
        nor = ~(a | b)
        res = jnp.where(
            code == 0, ~jnp.zeros_like(a),
            jnp.where(code == 1, ~a,
                      jnp.where(code == 2, nor,
                                jnp.where(code == 3, a | b,
                                          jnp.where(code == 4, ~(a & b),
                                                    a & b)))))
        pl.store(out_ref, (pl.dslice(0, 1), pl.dslice(dst, 1), slice(None)),
                 res)
        return ()

    jax.lax.fori_loop(0, n_ops, body, ())


@functools.partial(jax.jit, static_argnames=("w_tile", "interpret"))
def crossbar_exec(state: jnp.ndarray, microcode: jnp.ndarray,
                  w_tile: int = 128, interpret: bool = True) -> jnp.ndarray:
    """Run microcode (G, 4) over state (C, n, W); tiles (1, n, w_tile)."""
    c, n, w = state.shape
    pad = (-w) % w_tile
    if pad:
        state = jnp.pad(state, ((0, 0), (0, 0), (0, pad)))
    wp = state.shape[2]
    grid = (c, wp // w_tile)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(microcode.shape, lambda i, j: (0, 0)),
            pl.BlockSpec((1, n, w_tile), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, n, w_tile), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((c, n, wp), jnp.uint32),
        interpret=interpret,
    )(jnp.asarray(microcode, jnp.int32), state)
    return out[:, :, :w] if pad else out
