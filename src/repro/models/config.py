"""Unified model configuration covering the 10 assigned architectures.

A model is a stack of *super-blocks*: ``pattern`` lists the layer kinds of
one period; the stack is ``n_layers / len(pattern)`` periods scanned with
``lax.scan`` (stacked params keep HLO size O(pattern), not O(layers)).

Layer kinds:
    "ad"   self-attention + dense MLP
    "ae"   self-attention + MoE
    "ar"   self-attention + MoE with parallel dense-residual MLP (arctic)
    "adx"  self-attention + cross-attention + dense MLP (VLM / enc-dec)
    "md"   Mamba mixer + dense MLP
    "me"   Mamba mixer + MoE
    "xm"   xLSTM mLSTM block (up-proj / matrix-memory / down-proj)
    "xs"   xLSTM sLSTM block
Encoder stacks (enc-dec models) are uniform "enc" self-attention blocks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "SHAPES", "ShapeSpec"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[str, ...] = ("ad",)
    head_dim: Optional[int] = None
    activation: str = "silu"         # silu => SwiGLU, gelu => GeGLU
    gated_mlp: bool = True
    qkv_bias: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_fsdp_gather: bool = False    # ZeRO-3 experts: gather inside
                                     # shard_map (bwd = reduce-scatter)
    router_dtype: str = "float32"
    # Mamba
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_d_conv: int = 4
    mamba_dt_rank: int = 0           # 0 => d_model // 16
    # xLSTM
    xlstm_proj_factor: float = 2.0
    # encoder-decoder (audio)
    n_encoder_layers: int = 0
    audio_frames_div: int = 4        # encoder frames = seq_len // div (stub)
    # VLM
    vision_dim: int = 0
    n_patches: int = 0
    # numerics / memory
    dtype: str = "bfloat16"
    pad_vocab_multiple: int = 256
    remat: bool = True
    scan_layers: bool = True
    flash_attention: bool = True     # False: materialized scores (exact
                                     # HLO flop accounting, dry-run only)
    kv_cache_dtype: str = "bf16"     # "int8": quantized KV cache
    loss_chunk: int = 8192
    unembed_chunk: int = 0           # vocab-axis chunk for the loss-path
                                     # unembed (0: single full-width einsum)
    # PIM lowering for every linear in the stack: None inherits the ambient
    # repro.pim.engine.mode(...) context; "xla" | "quant" | "quant_tp" |
    # "pim_sim" pin it (MaxText-style quantization-config threading).
    # "quant_tp" runs per-rank int8 Pallas tiles shard_mapped over the mesh
    # "model" axis (falls back to "quant" outside a mesh).
    pim_mode: Optional[str] = None
    # training
    max_seq_len: int = 8_192

    # ------------------------------------------------------------------
    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def n_super(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: {self.n_layers} layers not divisible by "
            f"pattern of {len(self.pattern)}")
        return self.n_layers // len(self.pattern)

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or max(1, self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def is_encoder_decoder(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def has_recurrent_blocks(self) -> bool:
        """Any SSM/xLSTM block in the stack (state folds the whole prefix,
        so e.g. right-padded prompts are not admissible)."""
        return any(k in ("md", "me", "xm", "xs") for k in self.pattern)

    def window_ring_blocks(self, block_size: int) -> Optional[int]:
        """Blocks in a sliding-window decode ring (None when unwindowed).

        The ring capacity is the window rounded up to a whole number of
        blocks: a windowed slot never holds more than this many blocks, no
        matter how long the prompt or the generation runs."""
        if not self.sliding_window:
            return None
        return -(-self.sliding_window // block_size)

    def kv_blocks_for(self, n_tokens: int, block_size: int) -> int:
        """KV-cache blocks a request writing ``n_tokens`` positions needs.

        Unwindowed requests page linearly (``ceil(n_tokens / block)``);
        windowed ones are clamped to the ring capacity, which is the whole
        point of sliding-window serving: generation length stops mattering
        to the reservation."""
        nb = -(-max(int(n_tokens), 1) // block_size)
        ring = self.window_ring_blocks(block_size)
        return nb if ring is None else min(nb, ring)

    @property
    def sub_quadratic(self) -> bool:
        """Whether long-context decode is admissible (DESIGN.md §3):
        sliding-window attention bounds the cache; SSM/hybrid blocks keep
        O(1)/O(S) per-token state.  Pure full-attention stacks are skipped."""
        if self.sliding_window:
            return True
        return self.has_recurrent_blocks

    def runnable(self, shape: ShapeSpec) -> Tuple[bool, str]:
        """Whether an assigned (arch x shape) cell runs, and why not if not."""
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False, "pure full attention: 500k decode needs sub-quadratic"
        if shape.name == "long_500k" and self.is_encoder_decoder:
            return False, "enc-dec full attention (and out of domain at 500k)"
        return True, ""

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=len(self.pattern), d_model=64,
            n_heads=4, n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            d_ff=128 if self.d_ff else 0, vocab_size=277,
            head_dim=16, sliding_window=min(self.sliding_window, 16)
            if self.sliding_window else None,
            pad_vocab_multiple=8, loss_chunk=64, max_seq_len=64,
            dtype="float32", remat=False,
        )
        if self.n_experts:
            kw.update(n_experts=4, top_k=min(2, self.top_k), moe_d_ff=32)
        if self.n_encoder_layers:
            kw.update(n_encoder_layers=2)
        if self.vision_dim:
            kw.update(vision_dim=24, n_patches=9)
        if self.family == "ssm":
            kw.update(n_heads=2, n_kv_heads=2, head_dim=32)
        return dataclasses.replace(self, **kw)
