"""Top-k MoE with expert parallelism over the "model" mesh axis.

Design (DESIGN.md §4): activations are data-parallel over ("pod","data") and
*replicated* along "model"; experts are sharded over "model".  Inside a
``shard_map`` each model-rank processes only the token-assignments that
route to its local experts (gather into fixed-capacity buffers -> dense
expert FFN -> scatter-add), then one ``psum`` over "model" combines the
per-rank partial outputs — the same collective volume as a tensor-parallel
FFN all-reduce, with zero dispatch FLOPs (no one-hot einsum: dispatch is a
gather/scatter, so HLO FLOPs stay at 6*N_active*D and the roofline
MODEL_FLOPS/HLO_FLOPs ratio stays honest).

Capacity: each expert accepts ``ceil(T*k/E * capacity_factor)`` tokens per
rank-shard; overflow tokens are dropped for that expert (standard practice;
the router's other choices still serve them).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import context as dctx
from repro.dist.context import SM_CHECK_KW as _SM_CHECK_KW
from repro.dist.context import shard_map
from repro.models.layers import activation


def _local_moe(x, top_ids, top_w, w1, w2, w3, *, n_experts_global: int,
               e_base: int, capacity: int, act_name: str):
    """Per-rank expert compute. x: (T, d); w1: (E_loc, d, f) ...

    Returns this rank's partial output (T, d) (sum over its experts).
    """
    t, d = x.shape
    e_loc = w1.shape[0]
    out = jnp.zeros((t + 1, d), jnp.float32)  # +1 trash row for drops
    act = activation(act_name)
    for e in range(e_loc):
        ge = e_base + e
        hit = (top_ids == ge)                      # (T, k)
        tok_w = (hit * top_w).sum(-1)              # (T,)
        any_hit = hit.any(-1)
        slot = jnp.cumsum(any_hit) - 1             # (T,) position per hit
        slot = jnp.where(any_hit & (slot < capacity), slot, capacity)
        buf = jnp.zeros((capacity + 1, d), x.dtype).at[slot].set(
            jnp.where(any_hit[:, None], x, 0))
        tok_of_slot = jnp.full((capacity + 1,), t, jnp.int32).at[slot].set(
            jnp.arange(t, dtype=jnp.int32))
        h = act(buf @ w1[e].astype(x.dtype))
        if w3 is not None:
            h = h * (buf @ w3[e].astype(x.dtype))
        y = (h @ w2[e].astype(x.dtype)).astype(jnp.float32)
        gathered_w = jnp.where(tok_of_slot < t, tok_w[jnp.minimum(tok_of_slot,
                                                                  t - 1)], 0.0)
        out = out.at[tok_of_slot].add(y * gathered_w[:, None])
    return out[:t]


def moe_ffn(x, params: Dict, cfg) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d). params: router (d, E), w1/w2/w3 (E, d, f)."""
    b, s, d = x.shape
    e = cfg.n_experts
    k = cfg.top_k
    xf = x.reshape(b * s, d)
    logits = (xf.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    top_w, top_ids = jax.lax.top_k(logits, k)
    top_w = jax.nn.softmax(top_w, axis=-1)

    mesh = dctx.current_mesh()
    gated = "w3" in params

    def _cap(t_tokens):
        # capacity per expert; floor of 8 (and never above t) so tiny decode
        # batches are never dropped
        return min(t_tokens,
                   max(int(-(-t_tokens * k * cfg.capacity_factor // e)), 8))

    tp_ax = dctx.mesh_axes(mesh)[1] if mesh is not None else None
    # ZeRO-3 expert weights keep the shard_map path relevant even at
    # model=1: the weights stay 'data'-sharded and gather on use.
    zero3 = (mesh is not None and cfg.moe_fsdp_gather
             and "data" in mesh.axis_names and mesh.shape["data"] > 1)
    if mesh is None or tp_ax is None or (mesh.shape[tp_ax] == 1 and not zero3):
        cap = _cap(b * s)
        out = _local_moe(xf, top_ids, top_w, params["w1"], params["w2"],
                         params.get("w3"), n_experts_global=e, e_base=0,
                         capacity=cap, act_name=cfg.activation)
        return out.astype(x.dtype).reshape(b, s, d)

    dp, tp = dctx.mesh_axes(mesh)
    tp_size = mesh.shape[tp]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if (b * s) % dp_size:
        dp = ()          # batch-1 decode: replicate tokens across data axes
        dp_size = 1
    t_loc = (b * s) // dp_size
    e_loc = e // tp_size
    cap = _cap(t_loc)

    # ZeRO-3 expert weights: keep them 'data'-sharded inside the shard_map
    # and all_gather on use — the gather's transpose is a reduce-scatter of
    # the expert grads (vs a full all-reduce when experts enter replicated).
    fsdp_gather = zero3

    def ranked(xl, idl, wl, w1, w2, w3):
        rank = jax.lax.axis_index(tp)
        if fsdp_gather:
            w1 = jax.lax.all_gather(w1, "data", axis=1, tiled=True)
            w3 = jax.lax.all_gather(w3, "data", axis=1, tiled=True)
            w2 = jax.lax.all_gather(w2, "data", axis=2, tiled=True)
        part = _local_moe(xl, idl, wl, w1, w2, w3, n_experts_global=e,
                          e_base=rank * e_loc, capacity=cap,
                          act_name=cfg.activation)
        # psum in the compute dtype: halves the dominant wire term (≤16
        # partials; the f32 accumulation inside _local_moe already absorbed
        # the long sums)
        return jax.lax.psum(part.astype(xl.dtype), tp)

    if not gated:
        raise ValueError("MoE experts are gated (SwiGLU) in all configs")
    w13_spec = P(tp, "data", None) if fsdp_gather else P(tp, None, None)
    w2_spec = P(tp, None, "data") if fsdp_gather else P(tp, None, None)
    out = shard_map(
        ranked, mesh=mesh,
        in_specs=(P(dp, None), P(dp, None), P(dp, None),
                  w13_spec, w2_spec, w13_spec),
        out_specs=P(dp, None),
        **{_SM_CHECK_KW: False},
    )(xf, top_ids, top_w, params["w1"], params["w2"], params["w3"])
    return out.astype(x.dtype).reshape(b, s, d)
