"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM: pre-up-projection block — up-project to ``pf*d``, q/k/v heads over the
inner dim, exponential input/forget gating with the max-state stabilizer,
matrix memory C (B, NH, dh, dh), normalizer n (B, NH, dh).  Recurrent scan
for training (chunkwise-parallel forms are a §Perf note), O(1) state decode —
the canonical long-context architecture (long_500k runs).

sLSTM: scalar-memory variant with exponential gating (simplified: gates from
the current input only; the paper's recurrent gate connections are noted in
DESIGN.md as a deviation), followed by the same up/down projection.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def _heads(x, nh):
    b, s, p = x.shape
    return x.reshape(b, s, nh, p // nh)


def mlstm_block(x, params: Dict, cfg, state=None):
    """x: (B, S, d) -> (y, new_state).

    state: (C (B,NH,dh,dh), n (B,NH,dh), m (B,NH)) or None.
    """
    b, s, d = x.shape
    nh = cfg.n_heads
    cdt = x.dtype
    up = x @ params["up_proj"].astype(cdt)            # (B, S, 2p)
    xm, z = jnp.split(up, 2, axis=-1)                 # (B, S, p)
    p = xm.shape[-1]
    dh = p // nh

    xh = _heads(xm, nh)                               # (B, S, NH, dh)
    q = jnp.einsum("bshd,hde->bshe", xh, params["wq"].astype(cdt))
    k = jnp.einsum("bshd,hde->bshe", xh, params["wk"].astype(cdt)) / jnp.sqrt(
        jnp.asarray(dh, cdt))
    v = jnp.einsum("bshd,hde->bshe", xh, params["wv"].astype(cdt))
    gates = xm @ params["w_gates"].astype(cdt)        # (B, S, 2*NH)
    ig, fg = jnp.split(gates.astype(jnp.float32), 2, axis=-1)  # (B, S, NH)

    if state is None:
        c0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, nh, dh), jnp.float32)
        m0 = jnp.full((b, nh), -1e30, jnp.float32)
    else:
        c0, n0, m0 = [t.astype(jnp.float32) for t in state]

    def step(carry, ins):
        c, n, m = carry
        qt, kt, vt, it, ft = ins  # (B,NH,dh) x3, (B,NH) x2
        m_new = jnp.maximum(ft + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(ft + m - m_new)
        c = f_p[..., None, None] * c + i_p[..., None, None] * (
            vt[..., :, None] * kt[..., None, :])      # (B,NH,dh,dh)
        n = f_p[..., None] * n + i_p[..., None] * kt
        num = jnp.einsum("bhij,bhj->bhi", c, qt.astype(jnp.float32))
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhj,bhj->bh", n, qt.astype(jnp.float32))),
            1.0)
        h = num / den[..., None]
        return (c, n, m_new), h.astype(cdt)

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), ig.transpose(1, 0, 2),
          fg.transpose(1, 0, 2))
    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, p)
    h = h * jax.nn.silu(z)
    y = h @ params["down_proj"].astype(cdt)
    return y, (c, n, m)


def slstm_block(x, params: Dict, cfg, state=None):
    """Scalar-memory sLSTM with exponential gating; state (c, n, m)."""
    b, s, d = x.shape
    nh = cfg.n_heads
    cdt = x.dtype
    up = x @ params["up_proj"].astype(cdt)
    xm, zg = jnp.split(up, 2, axis=-1)
    p = xm.shape[-1]
    dh = p // nh

    zt = jnp.tanh(jnp.einsum("bshd,hde->bshe", _heads(xm, nh),
                             params["wz"].astype(cdt)))       # (B,S,NH,dh)
    gates = (xm @ params["w_gates"].astype(cdt)).astype(jnp.float32)
    ig, fg, og = jnp.split(gates, 3, axis=-1)                 # (B,S,NH)

    if state is None:
        c0 = jnp.zeros((b, nh, dh), jnp.float32)
        n0 = jnp.zeros((b, nh), jnp.float32)
        m0 = jnp.full((b, nh), -1e30, jnp.float32)
    else:
        c0, n0, m0 = [t.astype(jnp.float32) for t in state]

    def step(carry, ins):
        c, n, m = carry
        z_t, i_t, f_t, o_t = ins
        m_new = jnp.maximum(f_t + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(f_t + m - m_new)
        c = f_p[..., None] * c + i_p[..., None] * z_t.astype(jnp.float32)
        n = f_p * n + i_p
        h = jax.nn.sigmoid(o_t)[..., None] * c / jnp.maximum(n, 1.0)[..., None]
        return (c, n, m_new), h.astype(cdt)

    xs = (zt.transpose(1, 0, 2, 3), ig.transpose(1, 0, 2),
          fg.transpose(1, 0, 2), og.transpose(1, 0, 2))
    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, p)
    h = h * jax.nn.silu(zg)
    y = h @ params["down_proj"].astype(cdt)
    return y, (c, n, m)
