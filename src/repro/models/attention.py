"""Attention: GQA/MQA, sliding windows, cross-attention, KV-cache decode.

Training/prefill use a pure-jnp flash implementation (two-level ``lax.scan``
over query/key blocks with an online softmax): memory is O(Bq*Bk) per step
instead of O(S^2), which is what lets the 32k-prefill cells fit the dry-run
memory budget; XLA counts the same FLOPs as monolithic attention.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist import context as dctx

NEG_INF = -1e30


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def direct_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                     q_offset: int = 0,
                     segment_ids: Optional[jnp.ndarray] = None,
                     positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Materialized-scores attention (exact HLO flop accounting; used by the
    dry-run cost lowering — memory comes from the flash lowering).

    ``positions``/``segment_ids``: packed-prefill support.  ``positions``
    (S,) replaces the arange-derived q/k positions (requires Sq == Sk —
    q and k cover the same packed token stream); ``segment_ids`` (S,)
    adds a block-diagonal mask so tokens never attend across segments.
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(d)
    if positions is not None:
        if sq != sk:
            raise ValueError("positions requires Sq == Sk (packed prefill)")
        q_pos = k_pos = jnp.asarray(positions, jnp.int32)
    else:
        q_pos = q_offset + jnp.arange(sq)
        k_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    if segment_ids is not None:
        seg = jnp.asarray(segment_ids, jnp.int32)
        mask &= seg[:, None] == seg[None, :]
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def flash_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                    q_offset: int = 0, block_q: int = 512,
                    block_k: int = 512,
                    segment_ids: Optional[jnp.ndarray] = None,
                    positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """q: (B, Sq, H, D), k/v: (B, Sk, Hkv, D) -> (B, Sq, H, D).

    ``q_offset``: absolute position of q[0] (for prefill continuation).
    ``window``: sliding-window radius (attend to keys in (pos-window, pos]).
    ``positions``/``segment_ids``: packed-prefill support — ``positions``
    (S,) replaces the arange-derived positions for both q and k (requires
    Sq == Sk), ``segment_ids`` (S,) masks cross-segment pairs.
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    n_rep = h // hkv
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)

    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sq_p, sk_p = q.shape[1], k.shape[1]
    nq, nk = sq_p // block_q, sk_p // block_k

    # (nq, B, H, Bq, D) etc — scan over leading axis; batch on DP, heads on TP
    dp = dctx.dp_axes()
    qb = q.reshape(b, nq, block_q, h, d).transpose(1, 0, 3, 2, 4)
    kb = k.reshape(b, nk, block_k, h, d).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, block_k, h, d).transpose(1, 0, 3, 2, 4)
    tp = dctx.tp_axis()
    qb = dctx.shard(qb, None, dp, tp, None, None)
    kb = dctx.shard(kb, None, dp, tp, None, None)
    vb = dctx.shard(vb, None, dp, tp, None, None)
    scale = 1.0 / math.sqrt(d)

    # Per-block position/segment vectors.  Default path derives positions
    # from block indices (identical masks to an arange over the stream);
    # the packed path scans explicit per-token vectors instead.
    if positions is not None:
        if sq != sk:
            raise ValueError("positions requires Sq == Sk (packed prefill)")
        posv = jnp.asarray(positions, jnp.int32)
        q_posb = jnp.pad(posv, (0, pad_q)).reshape(nq, block_q)
        k_posb = jnp.pad(posv, (0, pad_k)).reshape(nk, block_k)
    else:
        q_posb = (q_offset + jnp.arange(sq_p, dtype=jnp.int32)
                  ).reshape(nq, block_q)
        k_posb = jnp.arange(sk_p, dtype=jnp.int32).reshape(nk, block_k)
    use_seg = segment_ids is not None
    if use_seg:
        segv = jnp.asarray(segment_ids, jnp.int32)
        q_segb = jnp.pad(segv, (0, pad_q), constant_values=-1
                         ).reshape(nq, block_q)
        k_segb = jnp.pad(segv, (0, pad_k), constant_values=-1
                         ).reshape(nk, block_k)

    k_idx_base = jnp.arange(block_k)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def q_step(_, qi_q):
        if use_seg:
            qblk, q_pos, q_seg = qi_q
        else:
            qblk, q_pos = qi_q
            q_seg = None

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def k_step(carry, ki_kv):
            m, l, acc = carry
            if use_seg:
                ki, kblk, vblk, k_pos, k_seg = ki_kv
            else:
                ki, kblk, vblk, k_pos = ki_kv
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            if use_seg:
                mask &= q_seg[:, None] == k_seg[None, :]
            mask &= (ki * block_k + k_idx_base < sk)[None, :]  # kv padding
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        a0 = jnp.zeros((b, h, block_q, d), jnp.float32)
        k_xs = ((jnp.arange(nk), kb, vb, k_posb, k_segb) if use_seg
                else (jnp.arange(nk), kb, vb, k_posb))
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0), k_xs)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    q_xs = (qb, q_posb, q_segb) if use_seg else (qb, q_posb)
    _, ob = jax.lax.scan(q_step, None, q_xs)
    out = ob.transpose(1, 0, 3, 2, 4).reshape(b, sq_p, h, d)
    return out[:, :sq]


def decode_attention(q, k_cache, v_cache, cache_len=None, *,
                     window: Optional[int] = None,
                     valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Decode-step attention: q (B, Sq, H, D) over cache (B, S, Hkv, D).

    The key mask comes from ``cache_len`` (prefix semantics: indices below
    it are live, optionally window-clipped) or, for non-contiguous cache
    layouts, from an explicit ``valid`` (B, S) boolean mask — the paged
    pool's gather path computes per-logical-index validity (ring wraparound,
    unallocated sentinel blocks) that a single prefix length can't express.

    ``Sq`` is 1 for the plain decode step.  A speculative verify run feeds
    ``Sq > 1`` consecutive positions with a per-query ``valid`` (B, Sq, S)
    mask — query ``i`` may only see cache rows at positions ``<= pos + i``,
    which keeps the run causal and masks the rows the run itself just wrote
    for *later* queries.  The ``Sq = 1`` trace is unchanged by the
    generalization (same reshapes, same einsums, same broadcast mask).
    """
    b, sq, h, d = q.shape
    _, s, hkv, _ = k_cache.shape
    n_rep = h // hkv
    qg = q.reshape(b, sq, hkv, n_rep, d)
    scores = jnp.einsum("bqhgd,bshd->bhgqs", qg, k_cache,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(d)
    if valid is not None:
        mask = valid
    else:
        pos = jnp.arange(s)
        mask = pos[None, :] < cache_len  # (B?, S) — cache_len scalar or (B,)
        if window is not None:
            mask = mask & (pos[None, :] > cache_len - 1 - window)
    if mask.ndim == 3:          # per-query validity (B, Sq, S)
        mask5 = mask[:, None, None, :, :]
    else:
        mask5 = jnp.broadcast_to(mask, (b, s))[:, None, None, None, :]
    scores = jnp.where(mask5, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def cross_attention(q, k, v) -> jnp.ndarray:
    """Full (non-causal) attention onto a small memory (patches / frames)."""
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(d)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v,
                      preferred_element_type=jnp.float32).astype(q.dtype)
