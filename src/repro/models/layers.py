"""Primitive layers: norms, rotary embeddings, activations, linears, embed.

Parameters are plain nested dicts of jnp arrays.  Every layer has a
``*_specs`` companion producing ShapeDtypeStructs so the full-size configs
can be lowered without allocating (the dry-run path), and ``init_*``
initializers used by the smoke tests / real training.

``PIMLinear`` is the paper integration point: mode "xla" is a plain matmul,
"quant" routes through the int8 Pallas kernel (fixed-point arithmetic, the
TPU analogue of the crossbar's integer representation), and "pim_sim"
executes the actual MultPIM gate programs on the bit-accurate simulator
(tiny shapes; used in examples/tests).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# spec / init plumbing
# --------------------------------------------------------------------------

class Spec(jax.ShapeDtypeStruct):
    """ShapeDtypeStruct + init kind ('normal', 'zeros', 'ones', 'scaled')."""

    def __init__(self, shape, dtype, init: str = "normal", scale: float = 1.0):
        super().__init__(shape, dtype)
        self.init = init
        self.scale = scale


def materialize(specs, key) -> Params:
    """Instantiate a spec tree into real parameters."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        init = getattr(s, "init", "normal")
        scale = getattr(s, "scale", 1.0)
        if init == "zeros":
            out.append(jnp.zeros(s.shape, s.dtype))
        elif init == "ones":
            out.append(jnp.ones(s.shape, s.dtype))
        elif init == "alog":
            # S4/Mamba A initialization: A = -(1..d_state) per channel
            ds = s.shape[-1]
            a = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                 s.shape)
            out.append(jnp.log(a).astype(s.dtype))
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            std = scale / math.sqrt(max(1, fan_in))
            out.append((jax.random.normal(k, s.shape, jnp.float32) * std
                        ).astype(s.dtype))
    return jax.tree.unflatten(treedef, out)


def as_shapes(specs):
    """Strip init metadata -> plain ShapeDtypeStructs (for jit.lower)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# --------------------------------------------------------------------------
# norms / activations / rope
# --------------------------------------------------------------------------

def rms_norm(x, weight, eps: float):
    # statistics in f32; the (big) elementwise multiply stays in x.dtype so a
    # pending TP all-reduce on x is materialized in bf16, not pushed past an
    # f32 upcast (halves the collective wire bytes — see EXPERIMENTS §Perf)
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return x * (scale.astype(x.dtype) * weight.astype(x.dtype))


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, Dh), positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]  # (B, S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# linear / embedding (with PIM modes)
# --------------------------------------------------------------------------

PIM_MODE: Dict[str, str] = {"mode": "xla"}  # process-wide switch for examples


def linear(x, w, b=None):
    mode = PIM_MODE["mode"]
    if mode == "quant":
        from repro.kernels.quant_matmul import quant_linear

        y = quant_linear(x, w.astype(jnp.float32))
    elif mode == "pim_sim":
        y = _pim_sim_linear(x, w)
    else:
        y = jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def _pim_sim_linear(x, w, bits: int = 7):
    """Bit-exact crossbar execution of the matmul (tiny shapes only).

    7-bit symmetric quantization so the offset-shifted unsigned operands fit
    the 8-bit (power-of-two partition count) MultPIM multiplier.
    """
    from repro.pim.matmul import pim_matmul_int

    xf = np.asarray(jax.device_get(x), np.float32)
    wf = np.asarray(jax.device_get(w), np.float32)
    lead = xf.shape[:-1]
    xf = xf.reshape(-1, xf.shape[-1])
    qmax = 2 ** (bits - 1) - 1
    xs = np.maximum(np.abs(xf).max(axis=1, keepdims=True), 1e-8) / qmax
    ws = np.maximum(np.abs(wf).max(axis=0, keepdims=True), 1e-8) / qmax
    xq = np.clip(np.round(xf / xs), -qmax, qmax).astype(np.int64)
    wq = np.clip(np.round(wf / ws), -qmax, qmax).astype(np.int64)
    # crossbars store magnitudes; signs handled by 2's-complement offset:
    # shift into unsigned, multiply, correct. (offset trick: (a+128)(b+128))
    off = qmax + 1
    acc = pim_matmul_int((xq + off).astype(np.uint64), (wq.T + off).astype(np.uint64),
                         n_bits=bits + 1, model="minimal")
    acc = acc.astype(np.int64)
    corr = (off * (wq.sum(axis=0, keepdims=True) + off * xq.shape[1])
            + off * xq.sum(axis=1, keepdims=True))
    y = (acc - corr) * (xs * ws)
    return jnp.asarray(y.reshape(*lead, wf.shape[1]), x.dtype)


def embed_lookup(table, ids):
    return jnp.take(table, ids, axis=0)


def unembed(x, table, chunk: Optional[int] = None):
    """Logits = x @ table.T (table: (V, d))."""
    return jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))
