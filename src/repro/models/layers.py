"""Primitive layers: norms, rotary embeddings, activations, linears, embed.

Parameters are plain nested dicts of jnp arrays.  Every layer has a
``*_specs`` companion producing ShapeDtypeStructs so the full-size configs
can be lowered without allocating (the dry-run path), and ``init_*``
initializers used by the smoke tests / real training.

:func:`linear` is the paper integration point.  How it lowers is selected
through ``repro.pim.engine`` — there is no process-wide global:

* ``"xla"``      — plain einsum (default);
* ``"quant"``    — the int8 Pallas kernel (fixed-point arithmetic, the TPU
  analogue of the crossbar's integer representation);
* ``"quant_tp"`` — the same int8 arithmetic as per-rank Pallas tiles
  ``shard_map``-ped over the mesh "model" axis (the paper's partition
  parallelism at mesh level; ``engine.get_backend("quant_tp")``) — falls
  back to (and is bit-identical with) ``"quant"`` when no tensor axis is
  active;
* ``"pim_sim"``  — the actual MultPIM gate programs on the bit-accurate
  crossbar simulator, via ``engine.sim_linear``'s ``jax.pure_callback``
  route, so it traces under ``jax.jit`` (tiny shapes; examples/tests).

Selection is either ambient — ``with pim.engine.mode("quant"): ...`` wrapped
around the *trace* — or threaded explicitly: ``linear(x, w, mode=...)``,
normally fed from ``ModelConfig.pim_mode`` by the model stack.  An explicit
mode wins over the ambient context.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# spec / init plumbing
# --------------------------------------------------------------------------

class Spec(jax.ShapeDtypeStruct):
    """ShapeDtypeStruct + init kind ('normal', 'zeros', 'ones', 'scaled')."""

    def __init__(self, shape, dtype, init: str = "normal", scale: float = 1.0):
        super().__init__(shape, dtype)
        self.init = init
        self.scale = scale


def materialize(specs, key) -> Params:
    """Instantiate a spec tree into real parameters."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        init = getattr(s, "init", "normal")
        scale = getattr(s, "scale", 1.0)
        if init == "zeros":
            out.append(jnp.zeros(s.shape, s.dtype))
        elif init == "ones":
            out.append(jnp.ones(s.shape, s.dtype))
        elif init == "alog":
            # S4/Mamba A initialization: A = -(1..d_state) per channel
            ds = s.shape[-1]
            a = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                 s.shape)
            out.append(jnp.log(a).astype(s.dtype))
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            std = scale / math.sqrt(max(1, fan_in))
            out.append((jax.random.normal(k, s.shape, jnp.float32) * std
                        ).astype(s.dtype))
    return jax.tree.unflatten(treedef, out)


def as_shapes(specs):
    """Strip init metadata -> plain ShapeDtypeStructs (for jit.lower)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# --------------------------------------------------------------------------
# norms / activations / rope
# --------------------------------------------------------------------------

def rms_norm(x, weight, eps: float):
    # statistics in f32; the (big) elementwise multiply stays in x.dtype so a
    # pending TP all-reduce on x is materialized in bf16, not pushed past an
    # f32 upcast (halves the collective wire bytes — see EXPERIMENTS §Perf)
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return x * (scale.astype(x.dtype) * weight.astype(x.dtype))


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, Dh), positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]  # (B, S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# linear / embedding (with PIM modes)
# --------------------------------------------------------------------------

def linear(x, w, b=None, *, mode: Optional[str] = None):
    """``x @ w (+ b)`` lowered per the active PIM mode.

    ``mode=None`` reads the ambient ``pim.engine.mode(...)`` context at
    trace time; an explicit ``mode`` (e.g. ``ModelConfig.pim_mode`` threaded
    by the model stack) takes precedence.
    """
    from repro.pim import engine

    mode = engine.resolve_mode(mode)
    if mode == "quant":
        from repro.kernels.quant_matmul import quant_linear

        y = quant_linear(x, w.astype(jnp.float32))
    elif mode == "quant_tp":
        y = engine.get_backend("quant_tp")(x, w.astype(jnp.float32))
    elif mode == "pim_sim":
        y = engine.sim_linear(x, w)
    else:
        y = jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def embed_lookup(table, ids):
    return jnp.take(table, ids, axis=0)


def unembed(x, table, chunk: Optional[int] = None):
    """Logits = x @ table.T (table: (V, d)).

    ``chunk`` bounds the vocab-axis working set: the table is consumed in
    ``chunk``-row slices, so the compute-dtype upcast of the table (and the
    einsum intermediate) peaks at ``chunk x d`` instead of ``V x d``.  The
    loss path threads ``ModelConfig.unembed_chunk`` here.  ``None`` (or a
    chunk >= V) is the single full-width einsum.
    """
    V = table.shape[0]
    if chunk is None or chunk <= 0 or chunk >= V:
        return jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))
    parts = [
        jnp.einsum("...d,vd->...v", x, table[v:v + chunk].astype(x.dtype))
        for v in range(0, V, chunk)
    ]
    return jnp.concatenate(parts, axis=-1)
