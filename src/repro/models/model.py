"""Model assembly: parameter specs, train forward, prefill, decode.

The layer stack is ``lax.scan`` over super-blocks (stacked params) so HLO
size is O(|pattern|), not O(n_layers) — this is what keeps the 480B-config
dry-run compiles tractable.  Each block kind returns ``(x, cache_out)``;
caches are scanned alongside (prefill emits them, decode threads them).
"""
from __future__ import annotations

import contextlib
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.dist import context as dctx
from repro.models import attention as attn
from repro.models.config import ModelConfig
from repro.models.layers import (Spec, activation, apply_rope, embed_lookup,
                                 linear, materialize, rms_norm, unembed)
from repro.models.moe import moe_ffn
from repro.models.ssm import mamba_mixer
from repro.models.xlstm import mlstm_block, slstm_block

Params = Dict[str, Any]


# ==========================================================================
# parameter specs
# ==========================================================================

def _attn_specs(cfg: ModelConfig, dt) -> Dict[str, Spec]:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = {
        "norm1": Spec((d,), jnp.float32, "ones"),
        "wq": Spec((d, h * hd), dt),
        "wk": Spec((d, hkv * hd), dt),
        "wv": Spec((d, hkv * hd), dt),
        "wo": Spec((h * hd, d), dt),
    }
    if cfg.qkv_bias:
        s.update(bq=Spec((h * hd,), jnp.float32, "zeros"),
                 bk=Spec((hkv * hd,), jnp.float32, "zeros"),
                 bv=Spec((hkv * hd,), jnp.float32, "zeros"))
    return s


def _mlp_specs(cfg: ModelConfig, dt) -> Dict[str, Spec]:
    d, ff = cfg.d_model, cfg.d_ff
    s = {
        "norm2": Spec((d,), jnp.float32, "ones"),
        "w_in": Spec((d, ff), dt),
        "w_out": Spec((ff, d), dt),
    }
    if cfg.gated_mlp:
        s["w_gate"] = Spec((d, ff), dt)
    return s


def _moe_specs(cfg: ModelConfig, dt) -> Dict[str, Spec]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    return {
        "norm_moe": Spec((d,), jnp.float32, "ones"),
        "router": Spec((d, e), jnp.float32),
        "w1": Spec((e, d, f), dt),
        "w2": Spec((e, f, d), dt),
        "w3": Spec((e, d, f), dt),
    }


def _xattn_specs(cfg: ModelConfig, dt) -> Dict[str, Spec]:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "normx": Spec((d,), jnp.float32, "ones"),
        "xwq": Spec((d, h * hd), dt),
        "xwk": Spec((d, hkv * hd), dt),
        "xwv": Spec((d, hkv * hd), dt),
        "xwo": Spec((h * hd, d), dt),
    }


def _mamba_specs(cfg: ModelConfig, dt) -> Dict[str, Spec]:
    d, di, ds, dc, dtr = (cfg.d_model, cfg.d_inner, cfg.mamba_d_state,
                          cfg.mamba_d_conv, cfg.dt_rank)
    return {
        "norm_m": Spec((d,), jnp.float32, "ones"),
        "in_proj": Spec((d, 2 * di), dt),
        "conv_w": Spec((dc, di), jnp.float32, "normal", 0.5),
        "conv_b": Spec((di,), jnp.float32, "zeros"),
        "x_proj": Spec((di, dtr + 2 * ds), dt),
        "dt_proj": Spec((dtr, di), jnp.float32, "normal", 0.5),
        "dt_bias": Spec((di,), jnp.float32, "zeros"),
        "a_log": Spec((di, ds), jnp.float32, "alog"),
        "d_skip": Spec((di,), jnp.float32, "ones"),
        "out_proj": Spec((di, d), dt),
    }


def _mlstm_specs(cfg: ModelConfig, dt) -> Dict[str, Spec]:
    d = cfg.d_model
    nh = cfg.n_heads
    p = int(cfg.xlstm_proj_factor * d)
    p -= p % nh
    dh = p // nh
    return {
        "norm_x": Spec((d,), jnp.float32, "ones"),
        "up_proj": Spec((d, 2 * p), dt),
        # block-diagonal per-head projections (as in the xLSTM reference)
        "wq": Spec((nh, dh, dh), dt),
        "wk": Spec((nh, dh, dh), dt),
        "wv": Spec((nh, dh, dh), dt),
        "w_gates": Spec((p, 2 * nh), jnp.float32, "normal", 0.5),
        "down_proj": Spec((p, d), dt),
    }


def _slstm_specs(cfg: ModelConfig, dt) -> Dict[str, Spec]:
    d = cfg.d_model
    nh = cfg.n_heads
    p = int(cfg.xlstm_proj_factor * d)
    p -= p % nh
    dh = p // nh
    return {
        "norm_x": Spec((d,), jnp.float32, "ones"),
        "up_proj": Spec((d, 2 * p), dt),
        "wz": Spec((nh, dh, dh), dt),
        "w_gates": Spec((p, 3 * nh), jnp.float32, "normal", 0.5),
        "down_proj": Spec((p, d), dt),
    }


def _block_specs(kind: str, cfg: ModelConfig, dt) -> Dict[str, Spec]:
    s: Dict[str, Spec] = {}
    if kind in ("ad", "ae", "ar", "adx", "enc"):
        s.update(_attn_specs(cfg, dt))
    if kind in ("ad", "adx", "enc", "md", "ar"):
        s.update(_mlp_specs(cfg, dt))
    if kind in ("ae", "ar", "me"):
        s.update(_moe_specs(cfg, dt))
    if kind == "adx":
        s.update(_xattn_specs(cfg, dt))
    if kind in ("md", "me"):
        s.update(_mamba_specs(cfg, dt))
    if kind == "xm":
        s.update(_mlstm_specs(cfg, dt))
    if kind == "xs":
        s.update(_slstm_specs(cfg, dt))
    return s


def _stack_specs(specs: Dict[str, Spec], n: int) -> Dict[str, Spec]:
    return {k: Spec((n,) + v.shape, v.dtype, getattr(v, "init", "normal"),
                    getattr(v, "scale", 1.0)) for k, v in specs.items()}


def param_specs(cfg: ModelConfig) -> Params:
    dt = cfg.compute_dtype
    v = cfg.padded_vocab
    d = cfg.d_model
    out: Params = {
        "embed": Spec((v, d), dt, "normal"),
        "final_norm": Spec((d,), jnp.float32, "ones"),
        "blocks": {
            str(i): _stack_specs(_block_specs(kind, cfg, dt), cfg.n_super)
            for i, kind in enumerate(cfg.pattern)
        },
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = Spec((v, d), dt)
    if cfg.is_encoder_decoder:
        out["encoder"] = {
            "blocks": {"0": _stack_specs(_block_specs("enc", cfg, dt),
                                         cfg.n_encoder_layers)},
            "enc_norm": Spec((d,), jnp.float32, "ones"),
        }
    if cfg.vision_dim:
        out["vision_proj"] = Spec((cfg.vision_dim, d), dt)
    return out


def init_params(cfg: ModelConfig, key) -> Params:
    return materialize(param_specs(cfg), key)


def param_count(cfg: ModelConfig) -> int:
    import numpy as np

    leaves = jax.tree.leaves(
        param_specs(cfg), is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return int(sum(np.prod(l.shape) for l in leaves))


# ==========================================================================
# block application
# ==========================================================================

def _quantize_kv(x):
    """(B, S, H, D) -> (int8 values, f32 per-(B,S,H) scales)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _self_attention(x, p, cfg: ModelConfig, positions, mode, cache, pos,
                    causal=True, block_tables=None, segment_ids=None):
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    quant = cfg.kv_cache_dtype == "int8"
    hh = rms_norm(x, p["norm1"], cfg.norm_eps)
    q = linear(hh, p["wq"], p.get("bq")).reshape(b, s, h, hd)
    k = linear(hh, p["wk"], p.get("bk")).reshape(b, s, hkv, hd)
    v = linear(hh, p["wv"], p.get("bv")).reshape(b, s, hkv, hd)
    if causal:  # rope only on the causal (decoder) stacks
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    new_cache = None
    if mode == "decode" and block_tables is not None:
        # Block-paged cache: leaves are (num_blocks, block, hkv, hd) physical
        # stores shared by every slot; ``block_tables`` (B, blocks_per_slot)
        # maps each slot's logical blocks to physical ones (sentinel entries
        # point at the reserved trash block 0, which no reader unmasks).
        # Logical index: the absolute position, or — under a sliding window —
        # the position modulo the block-rounded ring capacity.
        bs_blk = cache["k"].shape[1]
        lcap = block_tables.shape[1] * bs_blk
        r = jnp.arange(lcap)
        if s > 1:
            # Speculative verify run: token i of the run is written at
            # logical row pos+i, and query i's mask stops at its own row —
            # (B, s, lcap) per-query validity.  Rows past the slot's block
            # reservation hit sentinel table entries (trash block); rows at
            # or past lcap itself are routed to the trash block explicitly,
            # because the clamped gather would otherwise corrupt the slot's
            # last real block.  Sliding-window rings are rejected here:
            # rolling back a rejected draft would need ring rows the run's
            # own writes already destroyed.
            if cfg.sliding_window:
                raise ValueError("multi-position decode (speculative verify)"
                                 " does not support sliding_window")
            widx = pos[:, None] + jnp.arange(s)            # (B, s)
            valid = r[None, None, :] <= widx[:, :, None]   # (B, s, lcap)
            blk = block_tables[jnp.arange(b)[:, None],
                               jnp.minimum(widx, lcap - 1) // bs_blk]
            blk = jnp.where(widx < lcap, blk, 0)
        elif cfg.sliding_window:
            ring = cfg.window_ring_blocks(bs_blk) * bs_blk
            widx = pos % ring
            _, in_ring = ring_slot_positions(pos[:, None], r[None, :],
                                             ring, cfg.sliding_window)
            valid = (r[None, :] < ring) & in_ring
            blk = block_tables[jnp.arange(b), widx // bs_blk]
        else:
            # Same out-of-capacity guard as the s > 1 run above: the
            # speculative draft pass drives this single-token path up to
            # draft_k - 2 rows past a slot's last reserved position, where
            # the clamped table gather would resolve to the slot's *last
            # real block* and overwrite a committed row with draft-mode
            # bits the verify step never rewrites.  Route those writes to
            # the trash block instead.
            widx = pos
            valid = r[None, :] <= pos[:, None]
            blk = block_tables[jnp.arange(b),
                               jnp.minimum(widx, lcap - 1) // bs_blk]
            blk = jnp.where(widx < lcap, blk, 0)
        off = widx % bs_blk

        def put(c, new):
            new = new if s > 1 else new[:, 0]
            return c.at[blk, off].set(new.astype(c.dtype))

        def gather(c):
            return c[block_tables].reshape((b, lcap) + c.shape[2:])

        if quant:
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            kc, vc = put(cache["k"], kq), put(cache["v"], vq)
            ksc, vsc = put(cache["k_scale"], ks), put(cache["v_scale"], vs)
            k_full = _dequantize_kv(gather(kc), gather(ksc),
                                    cfg.compute_dtype)
            v_full = _dequantize_kv(gather(vc), gather(vsc),
                                    cfg.compute_dtype)
            new_cache = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
        else:
            kc, vc = put(cache["k"], k), put(cache["v"], v)
            k_full, v_full = gather(kc), gather(vc)
            new_cache = {"k": kc, "v": vc}
        out = attn.decode_attention(q, k_full, v_full, valid=valid)
    elif mode == "decode":
        cap = cache["k"].shape[1]
        per_slot = jnp.ndim(pos) == 1  # continuous batching: (B,) positions

        if s > 1:
            # Speculative verify run over the contiguous pool: rows land at
            # their *unwrapped* absolute indices, with out-of-capacity
            # writes dropped — wrapping (the `% cap` ring below) would let a
            # past-the-budget garbage row overwrite live early rows that
            # rollback still needs.
            if not per_slot:
                raise ValueError("multi-position decode needs per-slot "
                                 "(B,) positions")
            if cfg.sliding_window:
                raise ValueError("multi-position decode (speculative verify)"
                                 " does not support sliding_window")
            idx = pos[:, None] + jnp.arange(s)             # (B, s)
            bidx = jnp.arange(b)[:, None]

            def put(c, new):
                return c.at[bidx, idx].set(new.astype(c.dtype), mode="drop")
        elif per_slot:
            # each slot writes its token at its own cache index: modulo the
            # ring for sliding windows, else the absolute position with
            # out-of-capacity writes dropped — the speculative draft pass
            # steps this path past a slot's last row, and the unconditional
            # `% cap` wrap would land that garbage on live row 0 (the same
            # hazard the s > 1 run above drops)
            bidx = jnp.arange(b)
            if cfg.sliding_window:
                idx = pos % cap

                def put(c, new):
                    return c.at[bidx, idx].set(new[:, 0].astype(c.dtype))
            else:
                idx = pos

                def put(c, new):
                    return c.at[bidx, idx].set(new[:, 0].astype(c.dtype),
                                               mode="drop")
        else:
            idx = pos % cap

            def put(c, new):
                start = (0, idx) + (0,) * (new.ndim - 2)
                return jax.lax.dynamic_update_slice(c, new.astype(c.dtype),
                                                    start)

        if quant:
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            kc, vc = put(cache["k"], kq), put(cache["v"], vq)
            ksc, vsc = put(cache["k_scale"], ks), put(cache["v_scale"], vs)
            k_full = _dequantize_kv(kc, ksc, cfg.compute_dtype)
            v_full = _dequantize_kv(vc, vsc, cfg.compute_dtype)
            new_cache = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
        else:
            kc, vc = put(cache["k"], k), put(cache["v"], v)
            k_full, v_full = kc, vc
            new_cache = {"k": kc, "v": vc}
        if s > 1:
            valid = jnp.arange(cap)[None, None, :] <= idx[:, :, None]
            out = attn.decode_attention(q, k_full, v_full, valid=valid)
        else:
            cache_len = jnp.minimum(pos + 1, cap)
            if per_slot:
                cache_len = cache_len[:, None]  # (B, 1): per-slot mask rows
            out = attn.decode_attention(q, k_full, v_full, cache_len)
    else:
        window = cfg.sliding_window if causal else None
        attn_fn = attn.flash_attention if cfg.flash_attention \
            else attn.direct_attention
        if mode == "prefill" and cache is not None:
            # Prefix-resume prefill (prefix caching): ``cache`` holds the
            # shared prompt prefix KV — dense, post-RoPE, positions
            # 0..m-1, gathered by ``PagedCachePool.read_prefix`` — and the
            # caller shifted ``positions`` by m, so q/k here are already
            # rotated at absolute positions m..m+s-1.  Queries attend
            # concat(prefix, tail) with ``q_offset=m``; the emitted cache
            # is the *tail only*, unpadded — the paged pool scatters it at
            # block offset m (``assign_tail``) without touching the shared
            # prefix blocks.
            m_len = cache["k"].shape[1]
            if quant:
                pk = _dequantize_kv(cache["k"], cache["k_scale"],
                                    cfg.compute_dtype)
                pv = _dequantize_kv(cache["v"], cache["v_scale"],
                                    cfg.compute_dtype)
            else:
                pk, pv = cache["k"].astype(k.dtype), cache["v"].astype(v.dtype)
            out = attn_fn(q, jnp.concatenate([pk, k], axis=1),
                          jnp.concatenate([pv, v], axis=1),
                          causal=causal, window=window, q_offset=m_len)
            if quant:
                kq, ks = _quantize_kv(k)
                vq, vs = _quantize_kv(v)
                new_cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
            else:
                new_cache = {"k": k.astype(cfg.compute_dtype),
                             "v": v.astype(cfg.compute_dtype)}
            y = linear(out.reshape(b, s, h * hd), p["wo"])
            return x + y, new_cache
        if segment_ids is not None:
            # Packed prefill: several prompts share one (1, L) stream.
            # ``positions`` is the per-token position vector (restarting at
            # 0 per segment; it already drove RoPE above) and the segment
            # mask keeps attention block-diagonal.  The emitted cache is
            # the *raw packed* k/v — per-segment ``start`` offsets in the
            # pool's assign closure unpack it, so no ring roll or headroom
            # padding here (windowed packing is gated to plen <= window,
            # where ring layout == dense layout).
            out = attn_fn(q, k, v, causal=causal, window=window,
                          segment_ids=segment_ids, positions=positions)
            if quant:
                kq, ks = _quantize_kv(k)
                vq, vs = _quantize_kv(v)
                new_cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
            else:
                new_cache = {"k": k.astype(cfg.compute_dtype),
                             "v": v.astype(cfg.compute_dtype)}
            y = linear(out.reshape(b, s, h * hd), p["wo"])
            return x + y, new_cache
        out = attn_fn(q, k, v, causal=causal, window=window)
        if mode == "prefill":
            if cfg.sliding_window:
                # ring capacity: the window, with decode headroom padded for
                # prompts shorter than it (a ring of only min(s, window)
                # entries would wrap early and forget keys still inside the
                # window); capped at max_seq_len like the dense branch.
                cap = min(cfg.sliding_window, max(s, cfg.max_seq_len))
                if s < cap:
                    pad = ((0, 0), (0, cap - s), (0, 0), (0, 0))
                    kc, vc = jnp.pad(k, pad), jnp.pad(v, pad)
                else:
                    # ring alignment: decode writes position p at index
                    # p % cap, so position (s-cap+r) must sit at index
                    # (s-cap+r) % cap.
                    shift = (s - cap) % cap if cap else 0
                    kc = jnp.roll(k[:, -cap:], shift, axis=1) if shift \
                        else k[:, -cap:]
                    vc = jnp.roll(v[:, -cap:], shift, axis=1) if shift \
                        else v[:, -cap:]
            else:
                # full cache with decode headroom up to max_seq_len
                cap = max(s, cfg.max_seq_len)
                pad = ((0, 0), (0, cap - s), (0, 0), (0, 0))
                kc = jnp.pad(k, pad) if cap > s else k
                vc = jnp.pad(v, pad) if cap > s else v
            if quant:
                kq, ks = _quantize_kv(kc)
                vq, vs = _quantize_kv(vc)
                new_cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
            else:
                new_cache = {"k": kc.astype(cfg.compute_dtype),
                             "v": vc.astype(cfg.compute_dtype)}
    y = linear(out.reshape(b, s, h * hd), p["wo"])
    return x + y, new_cache


def _cross_attention(x, p, cfg: ModelConfig, memory, mode, cache):
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    hh = rms_norm(x, p["normx"], cfg.norm_eps)
    q = linear(hh, p["xwq"]).reshape(b, s, h, hd)
    if mode == "decode":
        k, v = cache["xk"], cache["xv"]
        new_cache = cache
    else:
        sk = memory.shape[1]
        k = linear(memory, p["xwk"]).reshape(b, sk, hkv, hd)
        v = linear(memory, p["xwv"]).reshape(b, sk, hkv, hd)
        new_cache = ({"xk": k.astype(cfg.compute_dtype),
                      "xv": v.astype(cfg.compute_dtype)}
                     if mode == "prefill" else None)
    out = attn.cross_attention(q, k, v)
    y = linear(out.reshape(b, s, h * hd), p["xwo"])
    return x + y, new_cache


def _dense_ffn(hh, p, cfg: ModelConfig):
    act = activation(cfg.activation)
    h = act(linear(hh, p["w_in"]))
    if cfg.gated_mlp:
        h = h * linear(hh, p["w_gate"])
    return linear(h, p["w_out"])


def _mlp(x, p, cfg: ModelConfig):
    hh = rms_norm(x, p["norm2"], cfg.norm_eps)
    return x + _dense_ffn(hh, p, cfg)


def _moe(x, p, cfg: ModelConfig, dense_residual: bool):
    hh = rms_norm(x, p["norm_moe"], cfg.norm_eps)
    y = moe_ffn(hh, p, cfg)
    if dense_residual:  # arctic: parallel dense MLP on the same input
        y = y + _dense_ffn(hh, p, cfg)
    return x + y


def _mamba(x, p, cfg: ModelConfig, mode, cache):
    hh = rms_norm(x, p["norm_m"], cfg.norm_eps)
    state = (cache["ssm"], cache["conv"]) if mode == "decode" else None
    y, (ssm, conv) = mamba_mixer(hh, p, cfg, state)
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"ssm": ssm.astype(jnp.float32),
                     "conv": conv.astype(cfg.compute_dtype)}
    return x + y, new_cache


def _xlstm(x, p, cfg: ModelConfig, mode, cache, kind):
    hh = rms_norm(x, p["norm_x"], cfg.norm_eps)
    fn = mlstm_block if kind == "xm" else slstm_block
    state = ((cache["c"], cache["n"], cache["m"]) if mode == "decode" else None)
    y, (c, n, m) = fn(hh, p, cfg, state)
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"c": c.astype(jnp.float32), "n": n.astype(jnp.float32),
                     "m": m.astype(jnp.float32)}
    return x + y, new_cache


def apply_block(kind: str, x, p, cfg: ModelConfig, *, positions, mode,
                cache=None, pos=None, memory=None, block_tables=None,
                segment_ids=None):
    """Returns (x, cache_out or None)."""
    out_cache = {}
    if kind in ("ad", "ae", "ar", "adx", "enc"):
        x, c = _self_attention(x, p, cfg, positions, mode, cache, pos,
                               causal=(kind != "enc"),
                               block_tables=block_tables,
                               segment_ids=segment_ids)
        if c:
            out_cache.update(c)
    if kind == "adx":
        x, c = _cross_attention(x, p, cfg, memory, mode, cache)
        if c:
            out_cache.update({k2: v for k2, v in c.items()
                              if k2 in ("xk", "xv")})
    if kind in ("md", "me"):
        x, c = _mamba(x, p, cfg, mode, cache)
        if c:
            out_cache.update(c)
    if kind in ("xm", "xs"):
        x, c = _xlstm(x, p, cfg, mode, cache, kind)
        if c:
            out_cache.update(c)
    if kind in ("ad", "adx", "enc", "md"):
        x = _mlp(x, p, cfg)
    if kind == "ae":
        x = _moe(x, p, cfg, dense_residual=False)
    if kind == "ar":
        x = _moe(x, p, cfg, dense_residual=True)
    if kind == "me":
        x = _moe(x, p, cfg, dense_residual=False)
    x = dctx.shard(x, dctx.dp_axes(), None, None)  # pin residual stream to DP
    return x, (out_cache or None)


# ==========================================================================
# stacks
# ==========================================================================

def _decoder_stack(params, x, cfg: ModelConfig, *, positions, mode,
                   caches=None, pos=None, memory=None, block_tables=None,
                   segment_ids=None):
    """Scan over super-blocks. caches: dict pos->stacked cache (or None).
    ``block_tables`` is shared by every layer (one slot->physical-block map
    for the whole paged pool), so it rides the closure, not the scan."""

    def body(xc, layer_inputs):
        x = xc
        new_caches = {}
        for i, kind in enumerate(cfg.pattern):
            pslice = layer_inputs[0][str(i)]
            cslice = layer_inputs[1].get(str(i)) if layer_inputs[1] else None
            x, c = apply_block(kind, x, pslice, cfg, positions=positions,
                               mode=mode, cache=cslice, pos=pos, memory=memory,
                               block_tables=block_tables,
                               segment_ids=segment_ids)
            if c is not None:
                new_caches[str(i)] = c
        return x, (new_caches or None)

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)

    xs = (params["blocks"], caches if caches is not None
          else {str(i): None for i in range(len(cfg.pattern))})
    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, xs)
        return x, new_caches
    # unrolled (dry-run mode: XLA cost analysis counts while-loop bodies once,
    # so roofline cells lower with the stack unrolled)
    per_super = []
    for i in range(cfg.n_super):
        sl = jax.tree.map(lambda a: a[i], xs)
        x, c = body(x, sl)
        per_super.append(c)
    if any(c is not None for c in per_super):
        new_caches = jax.tree.map(lambda *ls: jnp.stack(ls), *per_super)
    else:
        new_caches = None
    return x, new_caches


def _encode(params, frames, cfg: ModelConfig):
    x = frames.astype(cfg.compute_dtype)

    def body(xc, pslice):
        x, _ = apply_block("enc", xc, pslice["0"], cfg,
                           positions=jnp.arange(xc.shape[1]), mode="train")
        return x, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    else:
        for i in range(cfg.n_encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i],
                                        params["encoder"]["blocks"]))
    return rms_norm(x, params["encoder"]["enc_norm"], cfg.norm_eps)


def _memory(params, batch, cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return _encode(params, batch["frames"], cfg)
    if cfg.vision_dim:
        return batch["patches"].astype(cfg.compute_dtype) @ params[
            "vision_proj"].astype(cfg.compute_dtype)
    return None


# ==========================================================================
# entry points: loss / prefill / decode
# ==========================================================================

def _embed_in(params, tokens, cfg: ModelConfig):
    x = embed_lookup(params["embed"], tokens).astype(cfg.compute_dtype)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return dctx.shard_batch_dim(x)


def _unembed_table(params, cfg):
    return params.get("lm_head", params["embed"])


def _pim_ctx(cfg: ModelConfig):
    """Thread ``cfg.pim_mode`` into the trace (MaxText-style config
    threading): every ``linear`` below the entry point resolves against it.
    ``None`` defers to the caller's ambient ``pim.engine.mode`` context.

    Every entry point — ``loss_fn``, ``prefill``, ``decode_step``, and the
    serving runtime's jitted ``decode_step_slots`` (contiguous *and*
    block-paged) — wraps its trace in this context, so a mode like
    ``"quant_tp"`` reaches the linears inside the ``lax.scan`` layer stack
    end to end; its shard_map tiles read the active mesh at the same trace
    time the ``dist`` sharding constraints do."""
    if cfg.pim_mode is None:
        return contextlib.nullcontext()
    from repro.pim import engine

    return engine.mode(cfg.pim_mode)


def loss_fn(params, batch, cfg: ModelConfig):
    """Mean next-token cross entropy (chunked over tokens)."""
    with _pim_ctx(cfg):
        return _loss_fn(params, batch, cfg)


def _loss_fn(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    labels = batch["labels"]
    x = _embed_in(params, tokens, cfg)
    memory = _memory(params, batch, cfg)
    positions = jnp.arange(tokens.shape[1])
    x, _ = _decoder_stack(params, x, cfg, positions=positions, mode="train",
                          memory=memory)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = _unembed_table(params, cfg)

    flat_x = x.reshape(-1, cfg.d_model)
    flat_y = labels.reshape(-1)
    n_tok = flat_x.shape[0]
    chunk = cfg.loss_chunk if n_tok % cfg.loss_chunk == 0 else n_tok

    dp = dctx.dp_axes()

    @functools.partial(jax.checkpoint, prevent_cse=False)  # don't keep logits
    def chunk_nll(args):
        xc, yc = args
        xc = dctx.shard(xc, dp, None)
        logits = unembed(xc, table,
                         chunk=cfg.unembed_chunk or None).astype(jnp.float32)
        logits = dctx.shard(logits, dp, dctx.tp_axis())  # tokens x vocab
        m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[:, 0]
        # gold logit via mask-sum: fuses elementwise over the vocab shard
        # (take_along_axis would gather across the "model"-sharded axis)
        idx = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        gold = jnp.sum(jnp.where(idx == yc[:, None], logits, 0.0), axis=-1)
        return jnp.sum(lse - gold)

    xs = (flat_x.reshape(-1, chunk, cfg.d_model), flat_y.reshape(-1, chunk))
    if cfg.scan_layers:
        nll = jax.lax.map(chunk_nll, xs)
    else:
        n_chunks = n_tok // chunk
        nll = jnp.stack([chunk_nll(jax.tree.map(lambda a: a[i], xs))
                         for i in range(n_chunks)])
    return nll.sum() / n_tok


def prefill(params, batch, cfg: ModelConfig, last_index=None, prefix=None):
    """Forward the prompt; return (last-token logits, caches).

    ``last_index`` — optional (B,) int32 index of each request's last real
    prompt token, for right-padded (bucketed) prompts: logits are read
    there instead of at ``S - 1``.  Causal masking makes every position
    <= ``last_index`` independent of the padding, so bucketed prefill is
    exact for *full-attention* stacks only: recurrent blocks (Mamba/xLSTM)
    fold the padding into their state (serve those unbucketed), and a
    sliding-window cache keeps pad KV inside its ring once the padded
    length exceeds the window — the serving scheduler buckets windowed
    prompts only while ``padded <= window`` and enforces the rest.

    ``prefix`` — optional mapped-prefix KV tree (``{layer: {"k": (ns, 1,
    m, ...), ...}}`` per super-block, as returned by
    ``PagedCachePool.read_prefix``): ``batch["tokens"]`` is then the
    *divergent tail* of the prompt, resumed at absolute position ``m`` —
    positions/RoPE shift by ``m``, attention reads concat(prefix, tail)
    keys, and the returned caches hold the tail only (the paged pool
    scatters them at block offset ``m``).  ``m`` must be block-aligned
    and positive; full-attention stacks only (the caller gates recurrent
    and MoE configs, whose state/KV is not prefix-separable).
    """
    with _pim_ctx(cfg):
        tokens = batch["tokens"]
        x = _embed_in(params, tokens, cfg)
        memory = _memory(params, batch, cfg)
        off = 0
        if prefix is not None:
            off = jax.tree.leaves(prefix)[0].shape[2]
        positions = off + jnp.arange(tokens.shape[1])
        x, caches = _decoder_stack(params, x, cfg, positions=positions,
                                   mode="prefill", memory=memory,
                                   caches=prefix)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if last_index is None:
            xl = x[:, -1]
        else:
            xl = jnp.take_along_axis(
                x, last_index[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        logits = unembed(xl, _unembed_table(params, cfg))
        return logits.astype(jnp.float32), caches


def prefill_packed(params, tokens, positions, segment_ids, last_index,
                   cfg: ModelConfig):
    """Prefill several prompts packed into one (1, L) token stream.

    ``positions`` (L,) int32 restarts at 0 for each prompt (driving RoPE
    and the causal/window masks), ``segment_ids`` (L,) int32 keeps
    attention block-diagonal — one prompt's tokens never attend to
    another's, so each segment's logits and KV are bit-identical to its
    own unpacked ``prefill`` (padding carries segment id -1 and position
    0, which no real segment matches).  ``last_index`` (K,) int32 indexes
    each segment's final prompt token in the stream; K is fixed (the
    scheduler passes ``max_batch``, padding unused entries with 0) so a
    short burst never retraces on burst size.  Returns ``((K, V) logits,
    packed caches)`` — cache leaves keep the raw packed (1, L) stream
    layout; ``PagedCachePool.admit(start=)`` unpacks per segment.

    Full-attention stacks only (same gate as the prefix-resume path:
    recurrent state folds segments together, MoE routing is
    batch-coupled); windowed configs only for segments ``<= window``.
    """
    with _pim_ctx(cfg):
        x = _embed_in(params, tokens, cfg)
        x, caches = _decoder_stack(params, x, cfg, positions=positions,
                                   mode="prefill",
                                   segment_ids=segment_ids)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        xl = x[0, last_index.astype(jnp.int32)]     # (K, d)
        table = _unembed_table(params, cfg)
        # one (1, d) unembed per segment: a (K, d) matmul picks a different
        # reduction order than the (1, d) row the unpacked prefill runs,
        # and bit-exactness vs unpacked is the packed path's contract
        logits = jnp.concatenate(
            [unembed(xl[i:i + 1], table) for i in range(xl.shape[0])], axis=0)
        return logits.astype(jnp.float32), caches


def decode_step(params, token, pos, caches, cfg: ModelConfig):
    """One greedy decode step. token: (B, 1) int32; pos: scalar int32."""
    with _pim_ctx(cfg):
        x = _embed_in(params, token, cfg)
        positions = jnp.full((1,), pos, jnp.int32)
        x, new_caches = _decoder_stack(params, x, cfg, positions=positions,
                                       mode="decode", caches=caches, pos=pos)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(x[:, -1],
                         _unembed_table(params, cfg)).astype(jnp.float32)
        next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return next_tok, logits, new_caches


def decode_step_slots(params, tokens, pos, active, caches, cfg: ModelConfig,
                      block_tables=None):
    """One decode step over a slot batch (continuous batching).

    ``tokens``: (B, 1) int32 current token per slot; ``pos``: (B,) int32
    absolute position of that token per slot; ``active``: (B,) bool slot
    occupancy.  Shapes are fixed at ``B = max_batch``, so one jitted step
    serves a churning request mix without ever recompiling — slots attend
    only up to their own ``pos`` (per-slot ``cache_len`` masks), and
    finished/empty slots keep computing on stale state.  An inactive slot
    writes its (garbage) KV at ``pos[b] % cap`` of its *own* cache rows,
    which other slots never read and which prefill-on-admit fully
    overwrites; its emitted token is pinned to 0 by the active mask.

    ``block_tables`` (B, blocks_per_slot) int32 switches the attention
    leaves to the block-paged layout (``paged_cache_specs``): reads gather
    the slot's blocks, writes land at the slot's current block/offset, and
    an inactive slot's all-sentinel row routes its garbage write to the
    trash block.  Its shape is fixed, so block churn never recompiles.
    """
    with _pim_ctx(cfg):
        x = _embed_in(params, tokens, cfg)
        x, new_caches = _decoder_stack(params, x, cfg,
                                       positions=pos[:, None],
                                       mode="decode", caches=caches, pos=pos,
                                       block_tables=block_tables)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(x[:, -1],
                         _unembed_table(params, cfg)).astype(jnp.float32)
        next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        next_tok = jnp.where(active[:, None], next_tok, 0)
        return next_tok, logits, new_caches


def decode_run_slots(params, tokens, pos, active, caches, cfg: ModelConfig,
                     block_tables=None):
    """Verify a run of ``S`` candidate tokens per slot in one decode step.

    The speculative-decoding verify pass: ``tokens`` (B, S) int32 holds,
    per slot, the current token followed by ``S - 1`` drafted tokens;
    ``pos`` (B,) int32 is the absolute position of ``tokens[:, 0]``.
    Token ``i`` is fed at position ``pos + i``, its KV row written at that
    logical index (overwriting whatever the drafting pass left there), and
    its greedy continuation read out — the returned ``verify_tok`` (B, S)
    int32 is ``argmax(logits[:, i])`` for every ``i``.  The caller accepts
    the longest prefix where ``verify_tok[:, i] == tokens[:, i + 1]``
    (pure integer comparison; greedy decode makes acceptance exact) and
    rewinds ``pos`` past the rejected tail — the rejected rows hold
    garbage KV, but every mask in this stack is position-gated
    (``row <= query pos``), so a garbage row is always overwritten by the
    next run before any query can see it.

    Bit-exactness contract: with ``S = 1`` this is ``decode_step_slots``;
    for any ``S``, row ``i``'s hidden state equals the plain decode step's
    at the same position with the same fed prefix, because every linear
    lowering in the engine quantizes per activation row and the unembed
    below runs one (B, d) matmul per position (same reduction order as the
    single-token step — a batched (B*S, d) unembed would pick a different
    one).  Shapes are fixed at (B, S), so acceptance-length churn never
    retraces.  Not supported: sliding-window rings (rollback would need
    rows the ring already overwrote), recurrent blocks and MoE routing
    (state/capacity couple positions; the scheduler gates these).
    """
    with _pim_ctx(cfg):
        x = _embed_in(params, tokens, cfg)
        run = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        x, new_caches = _decoder_stack(params, x, cfg,
                                       positions=pos[:, None] + run[None, :],
                                       mode="decode", caches=caches, pos=pos,
                                       block_tables=block_tables)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        table = _unembed_table(params, cfg)
        # one (B, d) unembed per run position: the prefill_packed precedent
        # — a (B*S, d) matmul picks a different reduction order than the
        # (B, d) rows the plain decode step runs, and bit-exactness vs
        # non-speculative decode is the verify pass's whole contract
        logits = jnp.stack([unembed(x[:, i], table)
                            for i in range(tokens.shape[1])],
                           axis=1).astype(jnp.float32)
        verify_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        verify_tok = jnp.where(active[:, None], verify_tok, 0)
        return verify_tok, logits, new_caches


# ==========================================================================
# cache specs (for the dry-run)
# ==========================================================================

#: Attention-KV leaf names eligible for block paging: these carry a token
#: (sequence) dim and grow with context.  Everything else in the decode
#: cache tree — recurrent state (ssm/conv/c/n/m) and cross-attention
#: memory (xk/xv) — is fixed-size per slot and stays slot-indexed.
PAGED_KV_KEYS = ("k", "v", "k_scale", "v_scale")


def ring_slot_positions(last_pos, r, ring: int, window: int):
    """Sliding-window ring congruence, shared by writeback and readback.

    For ring index ``r`` (broadcastable against ``last_pos``), returns
    ``(p_r, valid)``: the newest absolute position ``<= last_pos`` with
    ``p_r % ring == r``, and whether that position exists and is still
    inside the attention window.  The paged decode path (reading a slot's
    block ring at position ``last_pos``) and the pool's admit conversion
    (laying out a ``plen``-token prefill, ``last_pos = plen - 1``) must
    agree on this bit-for-bit — keep both on this helper.
    """
    p_r = last_pos - ((last_pos - r) % ring)
    return p_r, (p_r >= 0) & (p_r > last_pos - window)


def paged_cache_specs(cfg: ModelConfig, batch: int, seq_len: int,
                      num_blocks: int, block_size: int) -> Dict:
    """``cache_specs`` with the attention-KV leaves re-laid as block pools.

    Each ``PAGED_KV_KEYS`` leaf becomes a ``(n_super, num_blocks,
    block_size, ...)`` physical store shared by every slot (block 0 is the
    pool's reserved sentinel/trash block); the per-slot token capacity
    moves into the block table, not the array shapes.  Non-attention
    leaves keep their ``(n_super, batch, ...)`` slot layout.
    """
    out = cache_specs(cfg, batch, seq_len)
    for c in out.values():
        for key in PAGED_KV_KEYS:
            if key in c:
                s = c[key]
                # (ns, batch, cap, ...) -> (ns, num_blocks, block, ...)
                c[key] = jax.ShapeDtypeStruct(
                    (s.shape[0], num_blocks, block_size) + s.shape[3:],
                    s.dtype)
    return out


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> Dict:
    """ShapeDtypeStructs of the decode caches for a given shape cell."""
    dt = cfg.compute_dtype
    ns = cfg.n_super
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    out: Dict[str, Any] = {}
    for i, kind in enumerate(cfg.pattern):
        c: Dict[str, Any] = {}
        if kind in ("ad", "ae", "ar", "adx"):
            cap = min(seq_len, cfg.sliding_window) if cfg.sliding_window \
                else seq_len
            kv_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dt
            c["k"] = jax.ShapeDtypeStruct((ns, batch, cap, hkv, hd), kv_dt)
            c["v"] = jax.ShapeDtypeStruct((ns, batch, cap, hkv, hd), kv_dt)
            if cfg.kv_cache_dtype == "int8":
                c["k_scale"] = jax.ShapeDtypeStruct((ns, batch, cap, hkv),
                                                    jnp.float32)
                c["v_scale"] = jax.ShapeDtypeStruct((ns, batch, cap, hkv),
                                                    jnp.float32)
        if kind == "adx":
            p = cfg.n_patches or (seq_len // cfg.audio_frames_div)
            c["xk"] = jax.ShapeDtypeStruct((ns, batch, p, hkv, hd), dt)
            c["xv"] = jax.ShapeDtypeStruct((ns, batch, p, hkv, hd), dt)
        if kind in ("md", "me"):
            c["ssm"] = jax.ShapeDtypeStruct(
                (ns, batch, cfg.d_inner, cfg.mamba_d_state), jnp.float32)
            c["conv"] = jax.ShapeDtypeStruct(
                (ns, batch, cfg.mamba_d_conv - 1, cfg.d_inner), dt)
        if kind in ("xm", "xs"):
            p = int(cfg.xlstm_proj_factor * cfg.d_model)
            p -= p % cfg.n_heads
            dh = p // cfg.n_heads
            if kind == "xm":
                c["c"] = jax.ShapeDtypeStruct(
                    (ns, batch, cfg.n_heads, dh, dh), jnp.float32)
            else:
                c["c"] = jax.ShapeDtypeStruct(
                    (ns, batch, cfg.n_heads, dh), jnp.float32)
            c["n"] = jax.ShapeDtypeStruct(
                (ns, batch, cfg.n_heads) + ((dh,) if kind == "xm" else ()),
                jnp.float32)
            c["m"] = jax.ShapeDtypeStruct((ns, batch, cfg.n_heads), jnp.float32)
        if c:
            out[str(i)] = c
    return out
