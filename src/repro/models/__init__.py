from repro.models.config import ModelConfig, SHAPES, ShapeSpec
from repro.models import model as model_lib

__all__ = ["ModelConfig", "SHAPES", "ShapeSpec", "model_lib"]
