"""Mamba selective-SSM mixer (Jamba's recurrent block).

Training/prefill run the recurrence with ``lax.scan`` over the sequence
(selective scan is inherently sequential in S; chunked parallel forms trade
FLOPs for latency — noted in EXPERIMENTS §Perf).  Decode is a single-step
state update: state (B, d_inner, d_state) + conv tail (B, d_conv-1, d_inner)
— O(1) per token, which is what makes the 500k-decode cell admissible.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def mamba_mixer(x, params: Dict, cfg, state: Tuple = None):
    """x: (B, S, d). Returns (y, new_state).

    state = (ssm_state (B, di, ds), conv_state (B, d_conv-1, di)) or None
    for a fresh sequence (training/prefill from scratch).
    """
    b, s, d = x.shape
    di, ds, dc = cfg.d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    dtr = cfg.dt_rank
    cdt = x.dtype

    xz = x @ params["in_proj"].astype(cdt)            # (B, S, 2*di)
    xi, z = jnp.split(xz, 2, axis=-1)

    # causal depthwise conv over S
    conv_w = params["conv_w"].astype(cdt)             # (dc, di)
    if state is None:
        tail = jnp.zeros((b, dc - 1, di), cdt)
    else:
        tail = state[1].astype(cdt)
    xi_pad = jnp.concatenate([tail, xi], axis=1)      # (B, S+dc-1, di)
    conv = sum(xi_pad[:, t:t + s, :] * conv_w[t] for t in range(dc))
    conv = conv + params["conv_b"].astype(cdt)
    new_tail = xi_pad[:, -(dc - 1):, :] if dc > 1 else jnp.zeros((b, 0, di), cdt)
    u = jax.nn.silu(conv)                             # (B, S, di)

    # input-dependent SSM params
    proj = u @ params["x_proj"].astype(cdt)           # (B, S, dtr+2*ds)
    dt_r, b_t, c_t = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_proj"].astype(cdt)
                         + params["dt_bias"].astype(cdt))  # (B, S, di)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))      # (di, ds)

    h0 = (jnp.zeros((b, di, ds), jnp.float32) if state is None
          else state[0].astype(jnp.float32))

    def step(h, ins):
        dt_t, b_tt, c_tt, u_t = ins  # (B,di) (B,ds) (B,ds) (B,di)
        da = jnp.exp(dt_t[..., None].astype(jnp.float32) * a)      # (B,di,ds)
        dbu = (dt_t * u_t)[..., None].astype(jnp.float32) \
            * b_tt[:, None, :].astype(jnp.float32)                  # (B,di,ds)
        h = da * h + dbu
        y = jnp.einsum("bis,bs->bi", h, c_tt.astype(jnp.float32))
        return h, y

    xs = (dt.transpose(1, 0, 2), b_t.transpose(1, 0, 2),
          c_t.transpose(1, 0, 2), u.transpose(1, 0, 2))
    h_last, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2).astype(cdt)             # (B, S, di)
    y = y + u * params["d_skip"].astype(cdt)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(cdt)
    return out, (h_last, new_tail)
