"""Substrate: data determinism, optimizer, checkpoints, fault tolerance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, restore_checkpoint, save_checkpoint)
from repro.data.pipeline import SyntheticLM
from repro.optim.adamw import (AdamWConfig, apply_updates, cosine_schedule,
                               init_state)
from repro.runtime.fault_tolerance import (CheckpointManager,
                                           StragglerMonitor,
                                           run_with_restarts)


# -- data -------------------------------------------------------------------

def test_data_deterministic_and_stateless():
    a = SyntheticLM(512, 64, 8, seed=7)
    b = SyntheticLM(512, 64, 8, seed=7)
    for step in (0, 3, 1000):
        x, y = a.batch_at(step), b.batch_at(step)
        assert np.array_equal(x["tokens"], y["tokens"])
        assert np.array_equal(x["labels"], y["labels"])
    assert not np.array_equal(a.batch_at(0)["tokens"],
                              a.batch_at(1)["tokens"])


def test_data_shards_disjoint():
    shards = [SyntheticLM(512, 32, 8, seed=1, shard_index=i, shard_count=4)
              for i in range(4)]
    batches = [s.batch_at(5)["tokens"] for s in shards]
    assert all(b.shape == (2, 32) for b in batches)
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(batches[i], batches[j])


def test_labels_are_next_tokens():
    d = SyntheticLM(512, 64, 2, seed=0)
    b = d.batch_at(0)
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# -- optimizer ----------------------------------------------------------------

def _train_quadratic(cfg, steps=150):
    params = {"w": jnp.asarray(np.linspace(-2, 2, 256).reshape(16, 16),
                               jnp.float32)}
    state = init_state(cfg, params)
    loss_fn = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(steps):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state, _ = apply_updates(cfg, params, grads, state)
    return float(loss_fn(params))


def test_adamw_converges():
    cfg = AdamWConfig(lr_peak=0.2, warmup_steps=5, total_steps=150,
                      weight_decay=0.0, clip_norm=100.0)
    assert _train_quadratic(cfg) < 0.5


def test_factored_second_moment_converges():
    cfg = AdamWConfig(lr_peak=0.2, warmup_steps=5, total_steps=150,
                      weight_decay=0.0, clip_norm=100.0, factored=True,
                      factored_min_dim=8)
    assert _train_quadratic(cfg) < 1.0


def test_factored_state_is_small():
    cfg = AdamWConfig(factored=True, factored_min_dim=64)
    params = {"w": jnp.zeros((256, 512), jnp.bfloat16)}
    st = init_state(cfg, params)["leaves"]["w"]
    assert "v" not in st and st["vr"].shape == (256,) \
        and st["vc"].shape == (512,)


def test_schedule_shape():
    cfg = AdamWConfig(lr_peak=1.0, lr_min=0.1, warmup_steps=10,
                      total_steps=110)
    assert float(cosine_schedule(cfg, 0)) == 0.0
    assert abs(float(cosine_schedule(cfg, 10)) - 1.0) < 1e-6
    assert float(cosine_schedule(cfg, 110)) <= 0.1 + 1e-6


# -- checkpoint ---------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16),
                  "d": jnp.int32(7)}}
    save_checkpoint(str(tmp_path), 5, tree, metadata={"k": 1}, shard_count=2)
    assert latest_step(str(tmp_path)) == 5
    back, meta = restore_checkpoint(str(tmp_path), 5, tree)
    assert meta == {"k": 1}
    for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(l1), np.asarray(l2))


def test_checkpoint_retention(tmp_path):
    m = CheckpointManager(str(tmp_path), every_steps=1, keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        m.maybe_save(s, tree)
    from repro.checkpoint import available_steps

    assert available_steps(str(tmp_path)) == [3, 4]


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, {"x": jnp.zeros((3, 2))})


# -- fault tolerance ----------------------------------------------------------

def test_run_with_restarts_resumes_identically(tmp_path):
    """Crash mid-training; the restarted run must match an uninterrupted one
    step for step (stateless data indexing + checkpoint resume)."""
    cfg = AdamWConfig(lr_peak=0.05, warmup_steps=2, total_steps=20,
                      weight_decay=0.0)
    data = SyntheticLM(64, 16, 4, seed=3)

    def make_worker(crash_at, log, ckdir):
        manager = CheckpointManager(ckdir, every_steps=2, keep=3)

        def worker(resume_at):
            params = {"w": jnp.zeros((64, 8), jnp.float32)}
            state = init_state(cfg, params)
            start = 0
            if resume_at is not None:
                _, tree, _ = manager.resume({"p": params, "o": state})
                params, state = tree["p"], tree["o"]
                start = resume_at

            def loss_fn(p, batch):
                emb = jnp.take(p["w"], batch["tokens"], axis=0)
                return jnp.mean(emb ** 2) + 1e-3 * jnp.sum(
                    (p["w"] - 1.0) ** 2)

            for step in range(start, 14):
                if crash_at is not None and step == crash_at \
                        and resume_at is None:
                    raise RuntimeError("injected")
                batch = {k: jnp.asarray(v)
                         for k, v in data.batch_at(step).items()}
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
                params, state, _ = apply_updates(cfg, params, grads, state)
                log.append((step, round(float(loss), 8)))
                manager.maybe_save(step + 1, {"p": params, "o": state})
            return 14

        return worker, manager

    log_a, log_b = [], []
    wa, ma = make_worker(None, log_a, str(tmp_path / "a"))
    wa(None)
    wb, mb = make_worker(9, log_b, str(tmp_path / "b"))
    run_with_restarts(wb, mb)
    # steps 8.. re-run after the crash resume; compare the final tail
    tail_a = [x for x in log_a if x[0] >= 10]
    tail_b = [x for x in log_b if x[0] >= 10]
    assert tail_a == tail_b[-len(tail_a):]


def test_straggler_monitor():
    m = StragglerMonitor()
    for _ in range(10):
        assert not m.record(1.0)
    assert m.record(5.0)
    assert m.flagged == 1


def test_elastic_mesh_degrades():
    from repro.runtime.fault_tolerance import ElasticMesh

    em = ElasticMesh(model_parallel=16)
    mesh = em.make(jax.devices())  # 1 device -> tp degrades to 1
    assert mesh.size == len(jax.devices())
