"""Self-speculative decoding: exactness, rollback, and fleet drills.

The contract under test (serving/speculative.py): a cheap engine mode
drafts ``draft_k - 1`` tokens, the serving mode verifies the whole run
in one batched ``decode_run_slots`` call, and greedy acceptance commits
exactly the verify mode's own greedy chain — so speculative decode is
bit-identical to plain decode for every draft/verify pairing, every
acceptance length, and every KV layout.  Rejected draft rows are rolled
back by *not advancing pos* (position-gated masks hide the garbage KV
until the next round overwrites it), which the adversarial all-rejected
and block-boundary tests exercise directly by sabotaging the draft
step.
"""
import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.dist import context as dctx
from repro.launch.mesh import make_mesh
from repro.models import model_lib as M
from repro.serving import (FailurePlan, Router, RouterConfig, Scheduler,
                           ServingConfig, accept_length, make_request)


def _smoke():
    return C.get("qwen1.5-0.5b").smoke()


def _tiny(mode, **kw):
    return C.get("qwen1.5-0.5b").smoke().scaled(
        n_layers=1, pattern=("ad",), d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=64, pad_vocab_multiple=8,
        loss_chunk=8, max_seq_len=48, pim_mode=mode, **kw)


def _mesh_ctx(mode):
    if mode != "quant_tp":
        return contextlib.nullcontext()
    return dctx.use_mesh(make_mesh((8,), ("model",)))


def _trace(cfg, seed=0, n=5, gen=(8, 6, 7, 5, 6)):
    rng = np.random.default_rng(seed)
    return [make_request(rng.integers(1, cfg.vocab_size, (3, 5, 4, 6, 4)[i]),
                         gen[i]) for i in range(n)]


def _run(params, cfg, scfg, reqs):
    sched = Scheduler(params, cfg, scfg)
    rids = [sched.submit_request(make_request(r.prompt, r.max_new_tokens))
            for r in reqs]
    out = sched.run()
    return sched, [out[rid] for rid in rids]


# ---------------------------------------------------------------------------
# tentpole: bit-exactness in every engine mode
# ---------------------------------------------------------------------------

def test_spec_bit_exact_per_pim_mode(pim_test_mode):
    """Speculative generations must match plain decode token for token
    under every verify lowering (CI's PIM_TEST_MODE matrix).  The quant
    job drafts with the *float* xla mode — drafts then disagree with the
    integer verify chain at some positions, so the exactness claim is
    exercised with imperfect acceptance, not just the ~100% same-family
    case."""
    mode = pim_test_mode
    draft = "xla" if mode == "quant" else "quant"
    cfg = _tiny(mode)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _trace(cfg, seed=1)
    base = dict(max_batch=3, prompt_bucket=4, paged=True, block_size=4)
    with _mesh_ctx(mode):
        _, plain = _run(params, cfg, ServingConfig(**base), reqs)
        sched, spec = _run(params, cfg,
                           ServingConfig(speculative=True, draft_mode=draft,
                                         draft_k=4, **base), reqs)
    for i, (a, b) in enumerate(zip(plain, spec)):
        assert np.array_equal(a, b), \
            f"request {i} diverged under {mode} (draft {draft}): {a} vs {b}"
    # pinned shapes: one (B, 1) draft trace, one (B, k) verify trace
    assert sched.decode_traces == 1
    assert sched.draft_traces == 1
    s = sched.metrics.summary()
    assert s["spec_rounds"] > 0
    assert s["verified_tokens"] == 4 * s["spec_rounds"]
    assert s["drafted_tokens"] == 3 * s["spec_rounds"]
    assert 1.0 <= s["mean_accept_len"] <= 4.0


def test_spec_contiguous_pool_bit_exact():
    """The contiguous (non-paged) pool takes the multi-row write path
    through ``c.at[bidx, idx].set(..., mode="drop")`` — same exactness
    contract, different rollback mechanics."""
    cfg = _tiny("xla")
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    reqs = _trace(cfg, seed=4)
    base = dict(max_batch=3, prompt_bucket=4)
    _, plain = _run(params, cfg, ServingConfig(**base), reqs)
    sched, spec = _run(params, cfg,
                       ServingConfig(speculative=True, draft_mode="quant",
                                     draft_k=3, **base), reqs)
    for a, b in zip(plain, spec):
        assert np.array_equal(a, b)
    assert sched.decode_traces == 1 and sched.draft_traces == 1


# ---------------------------------------------------------------------------
# degenerate configs: draft_k=1 and draft==verify short-circuit
# ---------------------------------------------------------------------------

def test_spec_draft_k1_degenerates_to_plain_decode():
    cfg = _tiny("xla")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _trace(cfg, seed=2)
    base = dict(max_batch=3, prompt_bucket=4, paged=True, block_size=4)
    _, plain = _run(params, cfg, ServingConfig(**base), reqs)
    sched, spec = _run(params, cfg,
                       ServingConfig(speculative=True, draft_mode="quant",
                                     draft_k=1, **base), reqs)
    assert sched._spec is None, "draft_k=1 must short-circuit"
    assert sched.draft_traces == 0
    assert sched.metrics.summary()["spec_rounds"] == 0
    for a, b in zip(plain, spec):
        assert np.array_equal(a, b)


def test_spec_draft_equals_verify_short_circuits():
    """Drafting with the verify mode itself would just run every step
    twice — the scheduler must fall back to plain decode."""
    cfg = _tiny("quant")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    sched = Scheduler(params, cfg,
                      ServingConfig(max_batch=2, prompt_bucket=4,
                                    speculative=True, draft_mode="quant",
                                    draft_k=4))
    assert sched._spec is None
    rid = sched.submit_request(
        make_request(np.array([1, 2, 3], np.int32), 4))
    out = sched.run()
    assert len(out[rid]) == 4
    assert sched.draft_traces == 0


# ---------------------------------------------------------------------------
# adversarial rollback: sabotage the draft step
# ---------------------------------------------------------------------------

def _sabotage_drafts(sched):
    """Wrap the jitted draft step so every draft token is off by one —
    the verify pass must reject everything after position 0."""
    spec = sched._spec
    orig = spec._draft

    def bad_draft(p, tokens, pos, active, caches, tables):
        tok, logits, caches = orig(p, tokens, pos, active, caches, tables)
        return (tok + 1) % sched.cfg.vocab_size, logits, caches

    spec._draft = bad_draft


def test_spec_all_rejected_makes_forward_progress():
    """Even a draft that is wrong at every position must emit exactly
    one (verify-mode) token per round — same final generations, one
    accepted token per verify step."""
    cfg = _tiny("xla")
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    reqs = _trace(cfg, seed=6)
    base = dict(max_batch=3, prompt_bucket=4, paged=True, block_size=4)
    _, plain = _run(params, cfg, ServingConfig(**base), reqs)

    scfg = ServingConfig(speculative=True, draft_mode="quant", draft_k=4,
                         **base)
    sched = Scheduler(params, cfg, scfg)
    _sabotage_drafts(sched)
    rids = [sched.submit_request(make_request(r.prompt, r.max_new_tokens))
            for r in reqs]
    out = sched.run()
    for a, rid in zip(plain, rids):
        assert np.array_equal(a, out[rid])
    s = sched.metrics.summary()
    assert s["mean_accept_len"] == 1.0
    assert set(s["accept_len_hist"]) == {1}
    assert s["accepted_tokens"] == s["spec_rounds"]


def test_spec_rollback_across_paged_block_boundary():
    """Acceptance/rejection landing on paged-block boundaries: with
    block_size=4 and draft_k=4 every verify run straddles two KV blocks
    at some round.  Sabotaged drafts force a rollback at every round —
    the rejected rows' garbage KV sits in the *next* block and must be
    invisible after the non-advance."""
    cfg = _tiny("xla")
    params = M.init_params(cfg, jax.random.PRNGKey(7))
    rng = np.random.default_rng(8)
    # prompt lengths 3 and 5 put the first verify runs mid-block and
    # block-straddling respectively; long budgets cross several blocks
    reqs = [make_request(rng.integers(1, cfg.vocab_size, p), g)
            for p, g in ((3, 12), (5, 10), (4, 11))]
    base = dict(max_batch=3, prompt_bucket=4, paged=True, block_size=4)
    _, plain = _run(params, cfg, ServingConfig(**base), reqs)

    sched = Scheduler(params, cfg,
                      ServingConfig(speculative=True, draft_mode="quant",
                                    draft_k=4, **base))
    _sabotage_drafts(sched)
    rids = [sched.submit_request(make_request(r.prompt, r.max_new_tokens))
            for r in reqs]
    out = sched.run()
    for i, (a, rid) in enumerate(zip(plain, rids)):
        assert np.array_equal(a, out[rid]), \
            f"request {i} diverged across a block boundary"
    assert sched.decode_traces == 1


@pytest.mark.parametrize("paged", [True, False], ids=["paged", "contiguous"])
def test_single_token_write_past_capacity_is_discarded(paged):
    """The draft pass's KV-write invariant, asserted on the cache bits
    directly: a single-token ``decode_step_slots`` at ``pos >= capacity``
    (where the speculative draft loop drives it for slots near the end of
    their budget) must leave every committed row untouched — the paged
    path routes the write to the reserved trash block 0 and the contiguous
    path drops it.  Unguarded, the paged path's clamped block-table gather
    lands the write in the slot's *last real block* and the contiguous
    path's ``% cap`` wrap lands it on row 0."""
    cfg = _tiny("xla")
    params = M.init_params(cfg, jax.random.PRNGKey(11))
    b, bs = 2, 4
    tok = jnp.asarray(np.array([[5], [7]], np.int32))
    active = jnp.ones(b, bool)
    if paged:
        nblocks = 2 * (cfg.max_seq_len // bs) + 1
        specs = M.paged_cache_specs(cfg, b, cfg.max_seq_len, nblocks, bs)
        # slot 0 owns blocks 1..12 (full reservation), slot 1 blocks 13..24
        bps = cfg.max_seq_len // bs
        tables = jnp.asarray(np.arange(1, 2 * bps + 1,
                                       dtype=np.int32).reshape(b, bps))
        lcap = bps * bs
        pos = jnp.asarray(np.array([lcap, lcap + 1], np.int32))
    else:
        specs = M.cache_specs(cfg, b, cfg.max_seq_len)
        tables = None
        pos = jnp.asarray(np.full(b, cfg.max_seq_len, np.int32))
    caches = jax.tree.map(lambda s: jnp.ones(s.shape, s.dtype), specs)
    _, _, new = M.decode_step_slots(params, tok, pos, active, caches, cfg,
                                    block_tables=tables)
    for key, before in caches["0"].items():
        after = new["0"][key]
        if paged:
            # everything but the trash block must be bit-identical
            assert np.array_equal(np.asarray(after[:, 1:]),
                                  np.asarray(before[:, 1:])), \
                f"{key}: past-capacity write escaped the trash block"
        else:
            assert np.array_equal(np.asarray(after), np.asarray(before)), \
                f"{key}: past-capacity write was not dropped"


@pytest.mark.parametrize("paged", [True, False], ids=["paged", "contiguous"])
def test_spec_request_at_pool_capacity_bit_exact(paged):
    """A request whose prompt + budget equals the pool capacity drives the
    draft pass's single-token decode writes up to ``draft_k - 2`` rows past
    the slot's last reserved position.  Those writes must be discarded the
    same way the verify run's are — routed to the paged pool's trash block,
    or dropped by the contiguous path — because the unguarded fallbacks
    corrupt *live* rows: the clamped block-table gather lands on the slot's
    last real block (a committed row the verify step never rewrites) and
    the contiguous ``% cap`` wrap lands on row 0.  Sabotaged drafts advance
    ``pos`` by exactly one per round, so the final rounds deterministically
    start at capacity - 2 and capacity - 1 and the corrupted row would be
    read back before the request finishes."""
    cfg = _tiny("xla")
    params = M.init_params(cfg, jax.random.PRNGKey(11))
    rng = np.random.default_rng(12)
    cap = cfg.max_seq_len               # per-slot pool capacity (48)
    prompt = rng.integers(1, cfg.vocab_size, 4)
    budget = cap - len(prompt)          # prompt + budget == capacity
    base = dict(max_batch=2, prompt_bucket=4)
    if paged:
        base.update(paged=True, block_size=4)
    _, plain = _run(params, cfg, ServingConfig(**base),
                    [make_request(prompt, budget)])

    sched = Scheduler(params, cfg,
                      ServingConfig(speculative=True, draft_mode="quant",
                                    draft_k=4, **base))
    _sabotage_drafts(sched)
    rid = sched.submit_request(make_request(prompt, budget))
    out = sched.run()
    assert np.array_equal(plain[0], out[rid]), \
        "capacity-boundary generation diverged: a past-capacity draft " \
        "write corrupted a live KV row"


# ---------------------------------------------------------------------------
# fleet drill: replica killed mid-speculation
# ---------------------------------------------------------------------------

def test_spec_router_kill_mid_verify_bit_exact():
    """A replica killed while its slots are mid-speculative-round must
    drain and requeue; the rerun restarts from the prompt, so the fleet
    results stay bit-identical to a single-scheduler oracle."""
    cfg = _smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(9))
    rng = np.random.default_rng(10)
    reqs = [make_request(rng.integers(1, cfg.vocab_size, p), g)
            for p, g in ((6, 8), (5, 9), (7, 6), (4, 8), (6, 7))]
    scfg = ServingConfig(max_batch=2, prompt_bucket=8, paged=True,
                         block_size=8, speculative=True, draft_mode="quant",
                         draft_k=3)
    _, oracle = _run(params, cfg, scfg, reqs)

    class FakeClock:
        def __init__(self, t=0.0):
            self.t = t

        def __call__(self):
            return self.t

    router = Router(params, cfg, scfg,
                    RouterConfig(n_replicas=2, policy="round_robin"),
                    devices=jax.devices()[:2], clock=FakeClock(1.0),
                    failure_plan=FailurePlan(kill_replica=0, at_step=1))
    fresh = [make_request(r.prompt, r.max_new_tokens) for r in reqs]
    for r in fresh:
        router.submit_request(r)
    results = router.run()
    assert router.rebalanced_requests > 0, "kill must catch in-flight work"
    for i, r in enumerate(fresh):
        assert np.array_equal(results[r.rid], oracle[i]), i


# ---------------------------------------------------------------------------
# validation + the acceptance rule itself
# ---------------------------------------------------------------------------

def test_spec_config_validation():
    smoke = _smoke()
    with pytest.raises(ValueError, match="draft_mode"):
        Scheduler(None, smoke, ServingConfig(speculative=True,
                                             draft_mode="nope"))
    with pytest.raises(ValueError, match="draft_k"):
        Scheduler(None, smoke, ServingConfig(speculative=True, draft_k=0))
    windowed = smoke.scaled(sliding_window=8)
    with pytest.raises(ValueError, match="sliding_window"):
        Scheduler(None, windowed, ServingConfig(speculative=True,
                                                draft_mode="quant"))


def test_accept_length_rule():
    f = np.array
    # verify[0] is always accepted; each matching draft extends the run
    assert accept_length(f([7, 1, 2, 3]), f([1, 2, 3, 4])) == 4
    assert accept_length(f([7, 1, 2, 3]), f([1, 2, 9, 4])) == 3
    assert accept_length(f([7, 1, 2, 3]), f([1, 9, 3, 4])) == 2
    assert accept_length(f([7, 1, 2, 3]), f([9, 1, 2, 3])) == 1
    # a later "re-match" after a mismatch must NOT extend the prefix
    assert accept_length(f([7, 1, 2, 3]), f([9, 2, 3, 4])) == 1
    assert accept_length(f([5]), f([8])) == 1
