"""Semantic invariants of the LM stack: decode==forward, SWA ring buffers,
MoE routing equivalence, flash==direct attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import model_lib as M
from repro.models.attention import direct_attention, flash_attention


def test_flash_matches_direct():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 100, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 100, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 100, 2, 16)).astype(np.float32))
    for window in (None, 17):
        a = flash_attention(q, k, v, causal=True, window=window,
                            block_q=32, block_k=32)
        b = direct_attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("name", ["qwen1.5-0.5b", "h2o-danube-1.8b",
                                  "jamba-v0.1-52b", "xlstm-1.3b",
                                  "llama-3.2-vision-11b"])
def test_decode_matches_forward(name):
    """prefill(x[:L]) + decode step == forward(x[:L+1]) last-token logits.

    capacity_factor is raised so MoE archs drop no tokens in either path
    (capacity drops are legitimate forward/decode divergence otherwise)."""
    cfg = C.get(name).smoke().scaled(capacity_factor=16.0)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    L = 24
    toks = rng.integers(0, cfg.vocab_size, (2, L + 1))
    batch = {"tokens": jnp.asarray(toks[:, :L], jnp.int32)}
    if cfg.vision_dim:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(2, cfg.n_patches, cfg.vision_dim)), jnp.float32)

    _, caches = M.prefill(params, batch, cfg)
    nxt = jnp.asarray(toks[:, L:L + 1], jnp.int32)
    _, logits_dec, _ = M.decode_step(params, nxt, jnp.int32(L), caches, cfg)

    batch_full = dict(batch, tokens=jnp.asarray(toks, jnp.int32))
    x = M._embed_in(params, batch_full["tokens"], cfg)
    memory = M._memory(params, batch_full, cfg)
    x, _ = M._decoder_stack(params, x, cfg,
                            positions=jnp.arange(L + 1), mode="train",
                            memory=memory)
    from repro.models.layers import rms_norm, unembed

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits_fwd = unembed(x[:, -1], M._unembed_table(params, cfg))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_fwd), rtol=2e-3, atol=2e-3)


def test_swa_ring_buffer_long_decode():
    """Decoding past the window capacity must equal full-context SWA."""
    cfg = C.get("h2o-danube-1.8b").smoke()  # window 16
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    total = 40  # > 2x window
    toks = rng.integers(0, cfg.vocab_size, (1, total))
    # path A: prefill 24, decode the rest step by step
    _, caches = M.prefill(params,
                          {"tokens": jnp.asarray(toks[:, :24], jnp.int32)}, cfg)
    logits = None
    for pos in range(24, total):
        tok = jnp.asarray(toks[:, pos:pos + 1], jnp.int32)
        _, logits, caches = M.decode_step(params, tok, jnp.int32(pos),
                                          caches, cfg)
    # path B: single forward over all tokens
    x = M._embed_in(params, jnp.asarray(toks, jnp.int32), cfg)
    x, _ = M._decoder_stack(params, x, cfg, positions=jnp.arange(total),
                            mode="train")
    from repro.models.layers import rms_norm, unembed

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    want = unembed(x[:, -1], M._unembed_table(params, cfg))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_moe_routing_matches_dense_reference():
    """With ample capacity, the gather/scatter MoE equals the brute-force
    per-token expert sum."""
    from repro.models.moe import moe_ffn

    cfg = C.get("granite-moe-1b-a400m").smoke().scaled(capacity_factor=8.0)
    rng = np.random.default_rng(3)
    b, s, d = 2, 8, cfg.d_model
    e, f = cfg.n_experts, cfg.moe_d_ff
    x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
    params = {
        "router": jnp.asarray(rng.normal(size=(d, e)).astype(np.float32)),
        "w1": jnp.asarray(rng.normal(size=(e, d, f)).astype(np.float32)) * 0.1,
        "w2": jnp.asarray(rng.normal(size=(e, f, d)).astype(np.float32)) * 0.1,
        "w3": jnp.asarray(rng.normal(size=(e, d, f)).astype(np.float32)) * 0.1,
    }
    got = moe_ffn(x, params, cfg)

    xf = np.asarray(x).reshape(-1, d)
    logits = xf @ np.asarray(params["router"])
    top = np.argsort(-logits, axis=1)[:, :cfg.top_k]
    ref = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        ws = np.exp(logits[t, top[t]] - logits[t, top[t]].max())
        ws = ws / ws.sum()
        for j, eid in enumerate(top[t]):
            h = (xf[t] @ np.asarray(params["w1"][eid]))
            h = h / (1 + np.exp(-h))  # silu
            h = h * (xf[t] @ np.asarray(params["w3"][eid]))
            ref[t] += ws[j] * (h @ np.asarray(params["w2"][eid]))
    np.testing.assert_allclose(np.asarray(got).reshape(-1, d), ref,
                               rtol=2e-3, atol=2e-3)


def test_mamba_state_continuity():
    """Mamba prefill state must continue exactly into decode."""
    from repro.models.ssm import mamba_mixer

    cfg = C.get("jamba-v0.1-52b").smoke()
    rng = np.random.default_rng(4)
    d = cfg.d_model
    params = M.init_params(cfg, jax.random.PRNGKey(4))
    p = jax.tree.map(lambda a: a[0], params["blocks"]["0"])  # first md block
    x = jnp.asarray(rng.normal(size=(1, 12, d)).astype(np.float32))
    y_full, _ = mamba_mixer(x, p, cfg, None)
    y_a, st = mamba_mixer(x[:, :8], p, cfg, None)
    y_b, _ = mamba_mixer(x[:, 8:], p, cfg, st)
    np.testing.assert_allclose(np.asarray(y_full[:, 8:]), np.asarray(y_b),
                               rtol=2e-3, atol=2e-4)


def test_int8_kv_cache_close_to_full_precision():
    """Quantized KV cache (serving optimization) stays within ~1% of bf16."""
    cfg = C.get("qwen1.5-0.5b").smoke().scaled(kv_cache_dtype="int8")
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    L = 24
    toks = rng.integers(0, cfg.vocab_size, (2, L + 1))
    batch = {"tokens": jnp.asarray(toks[:, :L], jnp.int32)}
    _, caches = M.prefill(params, batch, cfg)
    nxt = jnp.asarray(toks[:, L:L + 1], jnp.int32)
    _, lg_q, _ = M.decode_step(params, nxt, jnp.int32(L), caches, cfg)
    cfg2 = cfg.scaled(kv_cache_dtype="bf16")
    _, caches2 = M.prefill(params, batch, cfg2)
    _, lg_f, _ = M.decode_step(params, nxt, jnp.int32(L), caches2, cfg2)
    rel = np.abs(np.asarray(lg_q) - np.asarray(lg_f)).max() / (
        np.abs(np.asarray(lg_f)).max() + 1e-9)
    assert rel < 0.05
