"""End-to-end sharded dry-run smoke on the forced 8-device CPU mesh.

Exercises the real ``launch/dryrun.py`` lowering path (pspec factories ->
jit in/out shardings -> compile) and then *runs* the compiled train and
decode steps with materialized arrays — the CPU-scale version of what the
512-device dry-run does shape-only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.dist import context as dctx
from repro.launch import dryrun
from repro.launch.mesh import make_host_mesh
from repro.models import model_lib as M
from repro.models.config import ShapeSpec
from repro.optim.adamw import init_state

B, S = 8, 16


def _materialize(tree, rng):
    def leaf(s):
        if np.issubdtype(s.dtype, np.integer):
            return jnp.zeros(s.shape, s.dtype)
        return jnp.asarray(rng.normal(size=s.shape) * 0.02, s.dtype)

    return jax.tree.map(leaf, tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


@pytest.fixture(scope="module")
def mesh():
    assert len(jax.devices()) == 8, "conftest must force 8 CPU devices"
    return make_host_mesh(model=2)  # (data=4, model=2)


def test_sharded_train_step_compiles_and_runs(mesh, small_model_config):
    cfg = small_model_config
    shape = ShapeSpec("tiny_train", S, B, "train")
    with dctx.use_mesh(mesh):
        fn, (pshapes, oshapes, bshapes) = dryrun.lower_cell(
            cfg, shape, mesh, unroll=False)
        assert fn.lower(pshapes, oshapes, bshapes).compile() is not None

        params = M.init_params(cfg, jax.random.PRNGKey(0))
        opt = init_state(dryrun._opt_cfg(cfg), params)
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab_size, (B, S + 1))
        batch = {"tokens": jnp.asarray(toks[:, :S], jnp.int32),
                 "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        params2, opt2, loss, gnorm = fn(params, opt, batch)

    assert np.isfinite(float(loss)) and 0.0 < float(loss) < 20.0
    assert np.isfinite(float(gnorm))
    # weights actually live sharded: the embed table spans the model axis
    emb_spec = params2["embed"].sharding.spec
    assert "model" in jax.tree.leaves(tuple(emb_spec))
    # step advanced exactly once
    assert int(opt2["step"]) == 1


def test_sharded_train_step_emits_collectives(mesh, small_model_config):
    """Model-axis sharded weights must cost at least one all-reduce/gather;
    also covers dryrun.parse_collectives on real compiled HLO."""
    cfg = small_model_config
    shape = ShapeSpec("tiny_train", S, B, "train")
    with dctx.use_mesh(mesh):
        fn, args = dryrun.lower_cell(cfg, shape, mesh, unroll=False)
        compiled = fn.lower(*args).compile()
    colls = dryrun.parse_collectives(compiled.as_text())
    assert isinstance(colls, dict) and colls, "expected collectives in HLO"
    assert all(c["count"] > 0 and c["wire_bytes"] >= 0.0
               for c in colls.values())


def test_sharded_decode_step_compiles_and_runs(mesh, small_model_config):
    cfg = small_model_config
    shape = ShapeSpec("tiny_decode", 32, B, "decode")
    with dctx.use_mesh(mesh):
        fn, (pshapes, tok_s, pos_s, cshapes) = dryrun.lower_cell(
            cfg, shape, mesh, unroll=False)
        assert fn.lower(pshapes, tok_s, pos_s, cshapes).compile() is not None

        params = M.init_params(cfg, jax.random.PRNGKey(1))
        rng = np.random.default_rng(1)
        caches = _materialize(cshapes, rng)
        tok = jnp.ones((B, 1), jnp.int32)
        nxt, logits, caches2 = fn(params, tok, jnp.int32(0), caches)

    assert nxt.shape == (B, 1)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(caches2) == jax.tree.structure(cshapes)


def test_sharded_moe_forward_runs_shard_map_path(mesh):
    """The expert-parallel shard_map path (experts over "model", tokens over
    "data") must produce the same loss as the single-device gather path."""
    cfg = configs.get("granite-moe-1b-a400m").smoke().scaled(
        capacity_factor=16.0)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab_size, (B, S + 1))
    batch = {"tokens": jnp.asarray(toks[:, :S], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}

    want = float(jax.jit(lambda p, b: M.loss_fn(p, b, cfg))(params, batch))
    with dctx.use_mesh(mesh):
        fn = jax.jit(lambda p, b: M.loss_fn(p, b, cfg))
        # Pin the path: the expert psum over "model" must show up as a
        # collective in the HLO (a vacuous fall-through to the local MoE
        # branch would compile collective-free for this isolated loss).
        from repro.models.moe import moe_ffn

        blk = jax.tree.map(lambda a: a[0], params["blocks"]["0"])
        x = jnp.asarray(np.zeros((B, S, cfg.d_model)), jnp.float32)
        moe_hlo = jax.jit(lambda x, p: moe_ffn(x, p, cfg)).lower(
            x, blk).compile().as_text()
        assert "all-reduce" in moe_hlo, "shard_map expert psum missing"
        got = float(fn(params, batch))
    np.testing.assert_allclose(got, want, rtol=2e-3)


def test_dp_only_policy_replicates_weights(mesh, small_model_config):
    cfg = small_model_config
    shape = ShapeSpec("tiny_train", S, B, "train")
    with dctx.use_mesh(mesh, dp_axes=("data", "model")):
        fn, args = dryrun.lower_cell(cfg, shape, mesh, unroll=False,
                                     policy="dp_only")
        compiled = fn.lower(*args).compile()
    assert compiled is not None
