"""End-to-end sharded dry-run smoke on the forced 8-device CPU mesh.

Exercises the real ``launch/dryrun.py`` lowering path (pspec factories ->
jit in/out shardings -> compile) and then *runs* the compiled train and
decode steps with materialized arrays — the CPU-scale version of what the
512-device dry-run does shape-only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.dist import context as dctx
from repro.launch import dryrun
from repro.launch.mesh import make_host_mesh
from repro.models import model_lib as M
from repro.models.config import ShapeSpec
from repro.optim.adamw import init_state

B, S = 8, 16


def _materialize(tree, rng):
    def leaf(s):
        if np.issubdtype(s.dtype, np.integer):
            return jnp.zeros(s.shape, s.dtype)
        return jnp.asarray(rng.normal(size=s.shape) * 0.02, s.dtype)

    return jax.tree.map(leaf, tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


@pytest.fixture(scope="module")
def mesh():
    assert len(jax.devices()) == 8, "conftest must force 8 CPU devices"
    return make_host_mesh(model=2)  # (data=4, model=2)


def test_sharded_train_step_compiles_and_runs(mesh, small_model_config):
    cfg = small_model_config
    shape = ShapeSpec("tiny_train", S, B, "train")
    with dctx.use_mesh(mesh):
        fn, (pshapes, oshapes, bshapes) = dryrun.lower_cell(
            cfg, shape, mesh, unroll=False)
        assert fn.lower(pshapes, oshapes, bshapes).compile() is not None

        params = M.init_params(cfg, jax.random.PRNGKey(0))
        opt = init_state(dryrun._opt_cfg(cfg), params)
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab_size, (B, S + 1))
        batch = {"tokens": jnp.asarray(toks[:, :S], jnp.int32),
                 "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        params2, opt2, loss, gnorm = fn(params, opt, batch)

    assert np.isfinite(float(loss)) and 0.0 < float(loss) < 20.0
    assert np.isfinite(float(gnorm))
    # weights actually live sharded: the embed table spans the model axis
    emb_spec = params2["embed"].sharding.spec
    assert "model" in jax.tree.leaves(tuple(emb_spec))
    # step advanced exactly once
    assert int(opt2["step"]) == 1


def test_sharded_train_step_emits_collectives(mesh, small_model_config):
    """Model-axis sharded weights must cost at least one all-reduce/gather;
    also covers dryrun.parse_collectives on real compiled HLO."""
    cfg = small_model_config
    shape = ShapeSpec("tiny_train", S, B, "train")
    with dctx.use_mesh(mesh):
        fn, args = dryrun.lower_cell(cfg, shape, mesh, unroll=False)
        compiled = fn.lower(*args).compile()
    colls = dryrun.parse_collectives(compiled.as_text())
    assert isinstance(colls, dict) and colls, "expected collectives in HLO"
    assert all(c["count"] > 0 and c["wire_bytes"] >= 0.0
               for c in colls.values())


def test_sharded_decode_step_compiles_and_runs(mesh, small_model_config):
    cfg = small_model_config
    shape = ShapeSpec("tiny_decode", 32, B, "decode")
    with dctx.use_mesh(mesh):
        fn, (pshapes, tok_s, pos_s, cshapes) = dryrun.lower_cell(
            cfg, shape, mesh, unroll=False)
        assert fn.lower(pshapes, tok_s, pos_s, cshapes).compile() is not None

        params = M.init_params(cfg, jax.random.PRNGKey(1))
        rng = np.random.default_rng(1)
        caches = _materialize(cshapes, rng)
        tok = jnp.ones((B, 1), jnp.int32)
        nxt, logits, caches2 = fn(params, tok, jnp.int32(0), caches)

    assert nxt.shape == (B, 1)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(caches2) == jax.tree.structure(cshapes)


def test_sharded_moe_forward_runs_shard_map_path(mesh):
    """The expert-parallel shard_map path (experts over "model", tokens over
    "data") must produce the same loss as the single-device gather path."""
    cfg = configs.get("granite-moe-1b-a400m").smoke().scaled(
        capacity_factor=16.0)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab_size, (B, S + 1))
    batch = {"tokens": jnp.asarray(toks[:, :S], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}

    want = float(jax.jit(lambda p, b: M.loss_fn(p, b, cfg))(params, batch))
    with dctx.use_mesh(mesh):
        fn = jax.jit(lambda p, b: M.loss_fn(p, b, cfg))
        # Pin the path: the expert psum over "model" must show up as a
        # collective in the HLO (a vacuous fall-through to the local MoE
        # branch would compile collective-free for this isolated loss).
        from repro.models.moe import moe_ffn

        blk = jax.tree.map(lambda a: a[0], params["blocks"]["0"])
        x = jnp.asarray(np.zeros((B, S, cfg.d_model)), jnp.float32)
        moe_hlo = jax.jit(lambda x, p: moe_ffn(x, p, cfg)).lower(
            x, blk).compile().as_text()
        assert "all-reduce" in moe_hlo, "shard_map expert psum missing"
        got = float(fn(params, batch))
    np.testing.assert_allclose(got, want, rtol=2e-3)


def test_parse_collectives_pod_boundary_term():
    """The multi-pod wire model: groups spanning pods report the byte
    fraction riding inter-pod links; intra-pod groups report zero."""
    line = ("  %r = f32[1024]{0} all-reduce(f32[1024]{0} %p0), "
            "replica_groups=[1,512]<=[512], to_apply=%add")
    wire = 2.0 * 4096 * 511 / 512
    colls = dryrun.parse_collectives(line, pod_size=256)
    ar = colls["all-reduce"]
    assert ar["wire_bytes"] == pytest.approx(wire)
    # 512-device ring over 2 pods: 2 of 512 hops cross the boundary
    assert ar["cross_pod_bytes"] == pytest.approx(wire * 2 / 512)
    # a group fitting one pod pays nothing at the boundary
    assert dryrun.parse_collectives(line, pod_size=512)[
        "all-reduce"]["cross_pod_bytes"] == 0.0
    assert dryrun.parse_collectives(line)[
        "all-reduce"]["cross_pod_bytes"] == 0.0
    # the slower boundary links make the modeled time strictly larger
    t_multi = dryrun.collective_time_s(colls)
    t_single = dryrun.collective_time_s(dryrun.parse_collectives(line))
    assert t_multi > t_single > 0.0


def test_pod_boundary_term_on_real_multipod_hlo():
    """CPU-scale 2x16x16 analogue: a (pod, data, model) mesh whose
    all-reduce spans both pods must show cross-pod bytes when parsed with
    the per-pod device count, and none with the whole-mesh count."""
    from repro.launch.mesh import make_mesh

    pmesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    pod_size = pmesh.size // pmesh.shape["pod"]
    x = jnp.ones((8, 64), jnp.float32)
    sharding = jax.sharding.NamedSharding(
        pmesh, jax.sharding.PartitionSpec(("pod", "data", "model"), None))
    fn = jax.jit(lambda a: a.sum(0), in_shardings=sharding,
                 out_shardings=jax.sharding.NamedSharding(
                     pmesh, jax.sharding.PartitionSpec()))
    compiled = fn.lower(jax.device_put(x, sharding)).compile()
    colls = dryrun.parse_collectives(compiled.as_text(), pod_size=pod_size)
    assert colls, "expected a cross-device reduction in the HLO"
    assert sum(c["cross_pod_bytes"] for c in colls.values()) > 0.0
    no_cross = dryrun.parse_collectives(compiled.as_text(),
                                        pod_size=pmesh.size)
    assert sum(c["cross_pod_bytes"] for c in no_cross.values()) == 0.0


def test_dp_only_policy_replicates_weights(mesh, small_model_config):
    cfg = small_model_config
    shape = ShapeSpec("tiny_train", S, B, "train")
    with dctx.use_mesh(mesh, dp_axes=("data", "model")):
        fn, args = dryrun.lower_cell(cfg, shape, mesh, unroll=False,
                                     policy="dp_only")
        compiled = fn.lower(*args).compile()
    assert compiled is not None
