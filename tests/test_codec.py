"""Control codec round-trips, including property-based random legal ops."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (GateOp, InitOp, Operation, PartitionConfig, decode,
                        encode, message_bits, validate)

CFG = PartitionConfig(1024, 32)


def _roundtrip(op, model, gate_type):
    msg = encode(op, CFG, model)
    # frame adds 2 bits on top of the paper-counted payload
    assert len(msg) == message_bits(model, CFG) + 2
    back = decode(msg, CFG, model, gate_type)
    if op.is_init:
        assert set(back.init.columns(CFG)) == set(op.init.columns(CFG))
    else:
        assert {(g.gate, g.inputs, g.output) for g in back.gates} == \
            {(g.gate, g.inputs, g.output) for g in op.gates}


def test_serial_roundtrip_all_models():
    op = Operation(gates=(GateOp("NOR", (5, 700), 900),))
    _roundtrip(op, "baseline", "NOR")
    _roundtrip(op, "unlimited", "NOR")
    op2 = Operation(gates=(GateOp("NOR", (CFG.col(3, 1), CFG.col(3, 7)),
                                  CFG.col(9, 2)),))
    for model in ("standard", "minimal"):
        _roundtrip(op2, model, "NOR")


def test_split_input_roundtrip_unlimited_only():
    op = Operation(gates=(GateOp("NOR", (CFG.col(0, 4), CFG.col(2, 9)),
                                 CFG.col(5, 1)),))
    _roundtrip(op, "unlimited", "NOR")


@pytest.mark.slow
@given(
    intra=st.tuples(st.integers(0, 31), st.integers(0, 31),
                    st.integers(0, 31)).filter(
        lambda t: len({t[0], t[1]}) == 2 and t[2] not in t[:2]),
    period=st.sampled_from([1, 2, 4, 8, 16]),
    start=st.integers(0, 15),
)
@settings(max_examples=40, deadline=None)
def test_parallel_periodic_roundtrip(intra, period, start):
    """Random within-partition periodic ops are legal + codable everywhere."""
    ia, ib, io = intra
    parts = list(range(start, CFG.k, period))
    op = Operation(gates=tuple(
        GateOp("NOR", (CFG.col(p, ia), CFG.col(p, ib)), CFG.col(p, io))
        for p in parts))
    for model in ("unlimited", "standard", "minimal"):
        validate(op, CFG, model)
        _roundtrip(op, model, "NOR")


@pytest.mark.slow
@given(
    dist=st.integers(1, 7),
    extra=st.integers(1, 8),
    start=st.integers(0, 7),
    direction=st.sampled_from([+1, -1]),
    intra=st.tuples(st.integers(0, 31), st.integers(0, 31)),
)
@settings(max_examples=40, deadline=None)
def test_semiparallel_periodic_roundtrip(dist, extra, start, direction, intra):
    """Random uniform-distance periodic copy ops round-trip in every model."""
    period = dist + extra
    src_intra, dst_intra = intra
    gates = []
    p = start
    while 0 <= p + direction * dist < CFG.k and p < CFG.k:
        gates.append(GateOp("NOT", (CFG.col(p, src_intra),),
                            CFG.col(p + direction * dist, dst_intra)))
        p += period
    if not gates:
        return
    op = Operation(gates=tuple(gates))
    for model in ("unlimited", "standard", "minimal"):
        validate(op, CFG, model)
        _roundtrip(op, model, "NOT")


def test_init_roundtrips():
    for model in ("baseline", "unlimited", "standard", "minimal"):
        _roundtrip(Operation(init=InitOp("range", 40, 50)), model, "INIT")
    for model in ("unlimited", "standard", "minimal"):
        _roundtrip(Operation(init=InitOp("periodic", 3, 9, 0, 28, 4)),
                   model, "INIT")
    # spanning range init: standard encodes arbitrary end partitions
    _roundtrip(Operation(init=InitOp("range", 10, 200)), "standard", "INIT")


def test_illegal_op_refused_by_encoder():
    op = Operation(gates=(GateOp("NOR", (CFG.col(0, 0), CFG.col(1, 0)),
                                 CFG.col(2, 0)),))
    with pytest.raises(Exception):
        encode(op, CFG, "minimal")
