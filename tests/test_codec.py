"""Control codec round-trips, including property-based random legal ops.

The property tests run under real ``hypothesis`` when installed (CI pins
it) and under the deterministic shim in ``tests/_compat`` otherwise; any
strategy surface used here must exist in both (see the shim's docstring).
Strategies deliberately cover the codable space edge-to-edge: all five
gate types, arbitrary (non-power-of-two) periods up to ``k - 1``, range
inits spanning partitions, and standard-model arbitrary partition
subsets.
"""
import pytest
from hypothesis import assume, given, settings, strategies as st

from repro.core import (GateOp, InitOp, Operation, PartitionConfig, decode,
                        encode, message_bits, validate)

CFG = PartitionConfig(1024, 32)

TWO_INPUT_GATES = ["NOR", "OR", "NAND", "AND"]


def _roundtrip(op, model, gate_type):
    msg = encode(op, CFG, model)
    # frame adds 2 bits on top of the paper-counted payload
    assert len(msg) == message_bits(model, CFG) + 2
    back = decode(msg, CFG, model, gate_type)
    if op.is_init:
        assert set(back.init.columns(CFG)) == set(op.init.columns(CFG))
    else:
        assert {(g.gate, g.inputs, g.output) for g in back.gates} == \
            {(g.gate, g.inputs, g.output) for g in op.gates}


def test_serial_roundtrip_all_models():
    op = Operation(gates=(GateOp("NOR", (5, 700), 900),))
    _roundtrip(op, "baseline", "NOR")
    _roundtrip(op, "unlimited", "NOR")
    op2 = Operation(gates=(GateOp("NOR", (CFG.col(3, 1), CFG.col(3, 7)),
                                  CFG.col(9, 2)),))
    for model in ("standard", "minimal"):
        _roundtrip(op2, model, "NOR")


def test_split_input_roundtrip_unlimited_only():
    op = Operation(gates=(GateOp("NOR", (CFG.col(0, 4), CFG.col(2, 9)),
                                 CFG.col(5, 1)),))
    _roundtrip(op, "unlimited", "NOR")


@pytest.mark.slow
@given(
    intra=st.tuples(st.integers(0, 31), st.integers(0, 31),
                    st.integers(0, 31)).filter(
        lambda t: len({t[0], t[1]}) == 2 and t[2] not in t[:2]),
    period=st.integers(1, 31),        # arbitrary, not just powers of two
    start=st.integers(0, 31),
    gate=st.sampled_from(TWO_INPUT_GATES),
)
@settings(max_examples=40, deadline=None)
def test_parallel_periodic_roundtrip(intra, period, start, gate):
    """Random within-partition periodic ops are legal + codable everywhere,
    for every two-input gate type (the type rides out-of-band)."""
    ia, ib, io = intra
    parts = list(range(start, CFG.k, period))
    op = Operation(gates=tuple(
        GateOp(gate, (CFG.col(p, ia), CFG.col(p, ib)), CFG.col(p, io))
        for p in parts))
    for model in ("unlimited", "standard", "minimal"):
        validate(op, CFG, model)
        _roundtrip(op, model, gate)


@pytest.mark.slow
@given(
    dist=st.integers(1, 15),
    extra=st.integers(1, 16),
    start=st.integers(0, 15),
    forward=st.booleans(),
    intra=st.tuples(st.integers(0, 31), st.integers(0, 31)),
)
@settings(max_examples=40, deadline=None)
def test_semiparallel_periodic_roundtrip(dist, extra, start, forward, intra):
    """Random uniform-distance periodic copy ops round-trip in every model."""
    period = dist + extra                  # minimal needs T > distance
    assume(period <= CFG.k - 1)            # ... and T encodable in log2(k)
    direction = 1 if forward else -1
    src_intra, dst_intra = intra
    gates = []
    p = start
    while 0 <= p + direction * dist < CFG.k and p < CFG.k:
        gates.append(GateOp("NOT", (CFG.col(p, src_intra),),
                            CFG.col(p + direction * dist, dst_intra)))
        p += period
    if not gates:
        return
    op = Operation(gates=tuple(gates))
    for model in ("unlimited", "standard", "minimal"):
        validate(op, CFG, model)
        _roundtrip(op, model, "NOT")


@st.composite
def _range_inits(draw):
    """Arbitrary in-bounds [lo, hi] range inits (dependent draw)."""
    lo = draw(st.integers(0, CFG.n - 1))
    hi = draw(st.integers(lo, CFG.n - 1))
    return InitOp("range", lo, hi)


@pytest.mark.slow
@given(init=_range_inits())
@settings(max_examples=40, deadline=None)
def test_random_range_init_roundtrip(init):
    """Random range inits round-trip wherever they are encodable: every
    model for in-partition ranges; minimal only when the span ends at the
    last partition (its generator has no end-partition field)."""
    p_lo, p_hi = CFG.partition(init.lo), CFG.partition(init.hi)
    models = ["baseline", "unlimited", "standard"]
    if p_lo == p_hi or p_hi == CFG.k - 1:
        models.append("minimal")
    for model in models:
        _roundtrip(Operation(init=init), model, "INIT")


@st.composite
def _periodic_inits(draw):
    ilo = draw(st.integers(0, CFG.m - 1))
    ihi = draw(st.integers(ilo, CFG.m - 1))
    p_start = draw(st.integers(0, CFG.k - 1))
    p_end = draw(st.integers(p_start, CFG.k - 1))
    period = draw(st.integers(1, CFG.k - 1))
    return InitOp("periodic", ilo, ihi, p_start, p_end, period)


@pytest.mark.slow
@given(init=_periodic_inits())
@settings(max_examples=40, deadline=None)
def test_random_periodic_init_roundtrip(init):
    """Random periodic inits (any stride, any partition window) round-trip
    in every partition model."""
    for model in ("unlimited", "standard", "minimal"):
        validate(Operation(init=init), CFG, model)
        _roundtrip(Operation(init=init), model, "INIT")


@pytest.mark.slow
@given(
    parts=st.lists(st.integers(0, 31), min_size=1, max_size=10),
    intra=st.tuples(st.integers(0, 31), st.integers(0, 31),
                    st.integers(0, 31)),
)
@settings(max_examples=40, deadline=None)
def test_standard_arbitrary_partition_subsets(parts, intra):
    """The standard model's per-partition enable bits encode *any* set of
    active partitions, periodic or not — only minimal requires the
    uniform stride its range generator can reproduce."""
    ia, ib, io = intra
    assume(ia != ib and io not in (ia, ib))
    parts = sorted(set(parts))
    op = Operation(gates=tuple(
        GateOp("NOR", (CFG.col(p, ia), CFG.col(p, ib)), CFG.col(p, io))
        for p in parts))
    for model in ("unlimited", "standard"):
        validate(op, CFG, model)
        _roundtrip(op, model, "NOR")


def test_init_roundtrips():
    for model in ("baseline", "unlimited", "standard", "minimal"):
        _roundtrip(Operation(init=InitOp("range", 40, 50)), model, "INIT")
    for model in ("unlimited", "standard", "minimal"):
        _roundtrip(Operation(init=InitOp("periodic", 3, 9, 0, 28, 4)),
                   model, "INIT")
    # spanning range init: standard encodes arbitrary end partitions
    _roundtrip(Operation(init=InitOp("range", 10, 200)), "standard", "INIT")


def test_illegal_op_refused_by_encoder():
    op = Operation(gates=(GateOp("NOR", (CFG.col(0, 0), CFG.col(1, 0)),
                                 CFG.col(2, 0)),))
    with pytest.raises(Exception):
        encode(op, CFG, "minimal")
