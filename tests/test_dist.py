"""repro.dist unit tests: mesh context nesting, no-op safety, axis
resolution on 1D/2D/3D meshes, and pspec factories for param / optimizer /
batch / cache trees (fsdp on and off).

The suite runs on 8 forced CPU devices (see conftest.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import context as dctx
from repro.dist import partitioning as part
from repro.launch.mesh import make_mesh


def mesh2d(data=4, model=2):
    return make_mesh((data, model), ("data", "model"))


# ---------------------------------------------------------------------------
# context
# ---------------------------------------------------------------------------

def test_no_mesh_is_total_noop():
    assert dctx.current_mesh() is None
    assert dctx.dp_axes() == ()
    assert dctx.tp_axis() is None
    x = jnp.ones((4, 4))
    assert dctx.shard(x, "data", "model") is x
    assert dctx.shard_batch_dim(x) is x


def test_use_mesh_nesting_restores_outer():
    outer, inner = mesh2d(4, 2), make_mesh((8,), ("data",))
    with dctx.use_mesh(outer):
        assert dctx.current_mesh() is outer
        assert dctx.dp_axes() == ("data",)
        assert dctx.tp_axis() == "model"
        with dctx.use_mesh(inner):
            assert dctx.current_mesh() is inner
            assert dctx.dp_axes() == ("data",)
            assert dctx.tp_axis() is None
        assert dctx.current_mesh() is outer
        assert dctx.tp_axis() == "model"
    assert dctx.current_mesh() is None


def test_use_mesh_restores_on_exception():
    with pytest.raises(RuntimeError):
        with dctx.use_mesh(mesh2d()):
            raise RuntimeError("boom")
    assert dctx.current_mesh() is None


@pytest.mark.parametrize("shape,axes,want_dp,want_tp", [
    ((8,), ("data",), ("data",), None),
    ((8,), ("model",), (), "model"),
    ((4, 2), ("data", "model"), ("data",), "model"),
    ((2, 2, 2), ("pod", "data", "model"), ("pod", "data"), "model"),
])
def test_axis_resolution(shape, axes, want_dp, want_tp):
    with dctx.use_mesh(make_mesh(shape, axes)):
        assert dctx.dp_axes() == want_dp
        assert dctx.tp_axis() == want_tp


def test_dp_axes_override_dp_only_policy():
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    with dctx.use_mesh(mesh, dp_axes=("pod", "data", "model")):
        assert dctx.dp_axes() == ("pod", "data", "model")
        assert dctx.tp_axis() is None
    with pytest.raises(ValueError):
        with dctx.use_mesh(mesh, dp_axes=("nope",)):
            pass


def test_shard_applies_constraint_in_jit():
    mesh = mesh2d(4, 2)

    @jax.jit
    def f(x):
        return dctx.shard(x, "data", "model")

    with dctx.use_mesh(mesh):
        y = f(jnp.ones((8, 4)))
    assert y.sharding.spec == P("data", "model")


def test_shard_drops_non_dividing_axes():
    mesh = mesh2d(4, 2)
    with dctx.use_mesh(mesh):
        # 6 % 4 != 0 -> data axis dropped; 4 % 2 == 0 -> model kept
        y = dctx.shard(jnp.ones((6, 4)), "data", "model")
        assert y.sharding.spec == P(None, "model")
        # nothing shardable -> identity (no constraint inserted)
        x = jnp.ones((3, 3))
        assert dctx.shard(x, "data", "model") is x


def test_shard_batch_dim_uses_all_dp_axes():
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    with dctx.use_mesh(mesh):
        y = dctx.shard_batch_dim(jnp.ones((8, 3)))
        assert y.sharding.spec == P(("pod", "data"), None)


def test_mesh_axes_for_foreign_mesh():
    active = mesh2d(4, 2)
    other = make_mesh((2, 2, 2), ("pod", "data", "model"))
    with dctx.use_mesh(active, dp_axes=("data", "model")):
        assert dctx.mesh_axes(active) == (("data", "model"), None)
        assert dctx.mesh_axes(other) == (("pod", "data"), "model")


# ---------------------------------------------------------------------------
# partitioning
# ---------------------------------------------------------------------------

def _shapes(tree):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, jnp.float32), tree,
                        is_leaf=lambda x: isinstance(x, tuple))


PARAMS = _shapes({
    "embed": (1024, 64),            # vocab x d
    "norm": (64,),
    "blocks": {"w_in": (4, 64, 256), "w_out": (4, 256, 64)},
})


def test_param_pspecs_tp_picks_largest_dim_late_ties():
    mesh = mesh2d(4, 2)
    specs = part.param_pspecs(PARAMS, mesh, fsdp=False)
    assert specs["embed"] == P("model", None)          # vocab largest
    assert specs["norm"] == P("model")                 # 64 % 2 == 0
    assert specs["blocks"]["w_in"] == P(None, None, "model")
    assert specs["blocks"]["w_out"] == P(None, "model", None)


def test_param_pspecs_fsdp_adds_data_axis():
    mesh = mesh2d(4, 2)
    specs = part.param_pspecs(PARAMS, mesh, fsdp=True)
    assert specs["embed"] == P("model", "data")
    assert specs["blocks"]["w_in"] == P(None, "data", "model")
    assert specs["blocks"]["w_out"] == P(None, "model", "data")
    # fsdp=False leaves "data" out everywhere
    flat = jax.tree.leaves(part.param_pspecs(PARAMS, mesh, fsdp=False))
    assert all("data" not in [a for e in sp if e for a in
               ((e,) if isinstance(e, str) else e)] for sp in flat)


def test_param_pspecs_tp_off_replicates_model_axis():
    mesh = mesh2d(4, 2)
    specs = part.param_pspecs(PARAMS, mesh, fsdp=False, tp=False)
    assert all(sp == P(*([None] * len(sp)))
               for sp in jax.tree.leaves(specs))


def test_opt_state_pspecs_mirror_params_and_factored_stats():
    from repro.optim.adamw import AdamWConfig, init_state

    mesh = mesh2d(4, 2)
    cfg = AdamWConfig(factored=True, factored_min_dim=64)
    ostate = jax.eval_shape(lambda: init_state(cfg, PARAMS))
    p_part = part.param_pspecs(PARAMS, mesh, fsdp=True)
    o_part = part.opt_state_pspecs(PARAMS, p_part, ostate, mesh)
    assert o_part["step"] == P()
    leaves = o_part["leaves"]
    assert leaves["embed"]["m"] == p_part["embed"]
    # embed (1024, 64) factored: vr (1024,) keeps dim-0 spec, vc (64,) dim-1
    assert leaves["embed"]["vr"] == P("model")
    assert leaves["embed"]["vc"] == P("data")
    assert leaves["norm"]["v"] == p_part["norm"]
    # structures line up exactly with the real state tree
    assert (jax.tree.structure(o_part["leaves"])
            == jax.tree.structure(ostate["leaves"]))


def test_batch_pspecs_shard_leading_dim():
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    batch = _shapes({"tokens": (16, 32), "labels": (16, 32)})
    with dctx.use_mesh(mesh):
        specs = part.batch_pspecs(batch, mesh)
    assert specs["tokens"] == P(("pod", "data"), None)
    # non-dividing batch replicates
    odd = _shapes({"tokens": (3, 32)})
    with dctx.use_mesh(mesh):
        assert part.batch_pspecs(odd, mesh)["tokens"] == P(None, None)


def test_cache_pspecs_batch_and_head_dims():
    mesh = mesh2d(4, 2)
    caches = _shapes({
        "kv": (6, 8, 128, 2, 16),    # (ns, batch, cap, hkv, hd)
        "ssm": (6, 8, 64, 16),       # (ns, batch, d_inner, d_state)
        "m": (6, 8, 4),
    })
    specs = part.cache_pspecs(caches, mesh)
    assert specs["kv"] == P(None, "data", None, "model", None)
    assert specs["ssm"] == P(None, "data", "model", None)  # d_inner on -2
    assert specs["m"] == P(None, "data", None)


def test_tree_shardings_wraps_every_spec():
    mesh = mesh2d(4, 2)
    specs = part.param_pspecs(PARAMS, mesh)
    sh = part.tree_shardings(specs, mesh)
    flat = jax.tree.leaves(sh, is_leaf=lambda x: isinstance(x, NamedSharding))
    assert len(flat) == len(jax.tree.leaves(PARAMS))
    assert all(isinstance(s, NamedSharding) and s.mesh is mesh for s in flat)


def test_sharded_matmul_matches_single_device():
    """End-to-end numeric check: same result with and without a mesh."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))

    def f(x, w):
        x = dctx.shard_batch_dim(x)
        y = x @ w
        return dctx.shard(y, dctx.dp_axes(), dctx.tp_axis())

    # The active mesh is read at *trace* time, so jit separately per context.
    want = np.asarray(jax.jit(f)(x, w))
    with dctx.use_mesh(mesh2d(4, 2)):
        got = np.asarray(jax.jit(f)(x, w))
    np.testing.assert_allclose(got, want, rtol=1e-6)
