"""Per-architecture smoke tests: reduced config, one loss/prefill/decode step
on CPU, asserting output shapes and finiteness (assigned-arch deliverable)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import model_lib as M

B, S = 2, 32


def _batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S // cfg.audio_frames_div, cfg.d_model)),
            jnp.float32)
    if cfg.vision_dim:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.vision_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("name", C.ARCH_NAMES)
def test_smoke_loss_prefill_decode(name):
    cfg = C.get(name).smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)

    loss = jax.jit(lambda p, b: M.loss_fn(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss))
    assert 0.0 < float(loss) < 20.0

    logits, caches = jax.jit(lambda p, b: M.prefill(p, b, cfg))(params, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    nt, lg, caches2 = jax.jit(
        lambda p, t, pos, c: M.decode_step(p, t, pos, c, cfg))(
        params, tok, jnp.int32(S), caches)
    assert nt.shape == (B, 1)
    assert np.isfinite(np.asarray(lg)).all()
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("name", C.ARCH_NAMES)
def test_full_config_param_specs(name):
    """The FULL configs are exercised shape-only (dry-run covers lowering)."""
    cfg = C.get(name)
    n = M.param_count(cfg)
    assert n > 1e8
    specs = M.param_specs(cfg)
    leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    assert all(len(l.shape) >= 1 for l in leaves)
    # vocab padding keeps the model-axis shardable
    assert cfg.padded_vocab % 16 == 0


def test_assigned_cell_matrix():
    """40 cells total; long_500k skips exactly the pure-full-attention archs."""
    from repro.models.config import SHAPES

    cells = [(a, s.name, C.get(a).runnable(s)[0])
             for a in C.ARCH_NAMES for s in SHAPES]
    assert len(cells) == 40
    skipped = {(a, s) for a, s, ok in cells if not ok}
    assert skipped == {
        ("granite-20b", "long_500k"), ("gemma-7b", "long_500k"),
        ("qwen1.5-0.5b", "long_500k"), ("granite-moe-1b-a400m", "long_500k"),
        ("arctic-480b", "long_500k"), ("seamless-m4t-medium", "long_500k"),
        ("llama-3.2-vision-11b", "long_500k"),
    }
