"""Core PIM library: gates, partitions, legality, periphery, bounds."""
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (GATE_DEFS, GateOp, LegalityError, Operation,
                        PartitionConfig, bounds, is_legal, message_bits,
                        op_intervals, tight_selects, validate)
from repro.core.periphery import (minimal_range_generator, op_opcodes,
                                  sections_from_selects, simulate_voltages,
                                  standard_opcode_generator)

CFG = PartitionConfig(1024, 32)


# ---------------------------------------------------------------------------
# gates
# ---------------------------------------------------------------------------

@given(a=st.integers(0, 2**32 - 1), b=st.integers(0, 2**32 - 1))
@settings(max_examples=50, deadline=None)
def test_gate_semantics_bitwise(a, b):
    aw = jnp.uint32(a)
    bw = jnp.uint32(b)
    m = (1 << 32) - 1
    assert int(GATE_DEFS["NOT"](aw)) == (~a) & m
    assert int(GATE_DEFS["NOR"](aw, bw)) == (~(a | b)) & m
    assert int(GATE_DEFS["OR"](aw, bw)) == (a | b) & m
    assert int(GATE_DEFS["NAND"](aw, bw)) == (~(a & b)) & m
    assert int(GATE_DEFS["AND"](aw, bw)) == (a & b) & m
    assert int(GATE_DEFS["INIT"]()) == m


# ---------------------------------------------------------------------------
# partitions / sections
# ---------------------------------------------------------------------------

def test_partition_indexing():
    assert CFG.m == 32
    assert CFG.partition(0) == 0 and CFG.partition(1023) == 31
    assert CFG.intra(33) == 1 and CFG.col(1, 1) == 33
    with pytest.raises(ValueError):
        CFG.partition(1024)


def test_overlapping_sections_rejected():
    op = Operation(gates=(
        GateOp("NOT", (CFG.col(0, 0),), CFG.col(2, 0)),
        GateOp("NOT", (CFG.col(1, 0),), CFG.col(3, 0)),
    ))
    with pytest.raises(LegalityError):
        op_intervals(op, CFG)
    for model in ("unlimited", "standard", "minimal"):
        assert not is_legal(op, CFG, model)


def test_tight_selects():
    op = Operation(gates=(GateOp("NOT", (CFG.col(1, 0),), CFG.col(3, 0)),))
    sel = tight_selects(op, CFG)
    # transistors 1,2 conduct (span the gate); everything else isolates
    assert sel[1] is False and sel[2] is False
    assert sel[0] is True and all(sel[3:])
    secs = sections_from_selects(sel)
    assert (1, 3) in secs


# ---------------------------------------------------------------------------
# model legality matrix
# ---------------------------------------------------------------------------

def _parallel_op(intra=(0, 1, 2)):
    return Operation(gates=tuple(
        GateOp("NOR", (CFG.col(p, intra[0]), CFG.col(p, intra[1])),
               CFG.col(p, intra[2])) for p in range(CFG.k)))


def test_parallel_op_legal_everywhere():
    op = _parallel_op()
    for model in ("unlimited", "standard", "minimal"):
        validate(op, CFG, model)
    assert op.classify(CFG) == "parallel"
    assert not is_legal(op, CFG, "baseline")


def test_identical_indices_criterion():
    gates = list(_parallel_op().gates)
    gates[3] = GateOp("NOR", (CFG.col(3, 4), CFG.col(3, 1)), CFG.col(3, 2))
    op = Operation(gates=tuple(gates))
    assert is_legal(op, CFG, "unlimited")
    assert not is_legal(op, CFG, "standard")
    assert not is_legal(op, CFG, "minimal")


def test_split_input_criterion():
    op = Operation(gates=(GateOp("NOR", (CFG.col(0, 0), CFG.col(1, 0)),
                                 CFG.col(2, 0)),))
    assert is_legal(op, CFG, "unlimited")
    assert not is_legal(op, CFG, "standard")


def test_uniform_direction_criterion():
    op = Operation(gates=(
        GateOp("NOT", (CFG.col(1, 0),), CFG.col(0, 0)),
        GateOp("NOT", (CFG.col(4, 0),), CFG.col(5, 0)),
    ))
    assert is_legal(op, CFG, "unlimited")
    assert not is_legal(op, CFG, "standard")


def test_minimal_periodic_criterion():
    # periodic distance-2 copies, period 4: minimal-legal
    ok = Operation(gates=tuple(
        GateOp("NOT", (CFG.col(p, 0),), CFG.col(p + 2, 0))
        for p in (0, 4, 8, 12)))
    validate(ok, CFG, "minimal")
    # non-periodic input partitions: standard-legal, minimal-illegal
    bad = Operation(gates=tuple(
        GateOp("NOT", (CFG.col(p, 0),), CFG.col(p + 2, 0))
        for p in (0, 4, 12)))
    assert is_legal(bad, CFG, "standard")
    assert not is_legal(bad, CFG, "minimal")
    # period must exceed distance
    tight = Operation(gates=tuple(
        GateOp("NOT", (CFG.col(p, 0),), CFG.col(p + 2, 0))
        for p in (0, 2)))
    assert not is_legal(tight, CFG, "minimal")  # and physically overlapping
    assert not is_legal(tight, CFG, "unlimited")


def test_mixed_distance_minimal_illegal():
    op = Operation(gates=(
        GateOp("NOT", (CFG.col(0, 0),), CFG.col(1, 0)),
        GateOp("NOT", (CFG.col(8, 0),), CFG.col(10, 0)),
    ))
    assert is_legal(op, CFG, "standard")
    assert not is_legal(op, CFG, "minimal")


def test_one_gate_type_per_operation():
    with pytest.raises(LegalityError):
        Operation(gates=(
            GateOp("NOT", (CFG.col(0, 0),), CFG.col(0, 1)),
            GateOp("NOR", (CFG.col(4, 0), CFG.col(4, 1)), CFG.col(4, 2)),
        ))


# ---------------------------------------------------------------------------
# message lengths & lower bounds (paper §2.3/§3.3/§4.3)
# ---------------------------------------------------------------------------

def test_paper_message_lengths():
    assert message_bits("baseline", CFG) == 30
    assert message_bits("unlimited", CFG) == 607
    assert message_bits("standard", CFG) == 79
    assert message_bits("minimal", CFG) == 36


def test_lower_bounds_match_paper():
    assert bounds.unlimited_lower_bound(CFG) == 444  # paper: "over 2^443"
    assert bounds.standard_lower_bound(CFG) == 46
    assert bounds.minimal_lower_bound(CFG) == 25


def test_bounds_below_implemented_lengths():
    for model, lb in (("unlimited", bounds.unlimited_lower_bound(CFG)),
                      ("standard", bounds.standard_lower_bound(CFG)),
                      ("minimal", bounds.minimal_lower_bound(CFG))):
        assert lb <= message_bits(model, CFG)


# ---------------------------------------------------------------------------
# periphery: half-gates, opcode generation, range generator
# ---------------------------------------------------------------------------

def test_half_gate_voltage_reconstruction():
    op = _parallel_op()
    opcodes, selects = op_opcodes(op, CFG)
    gates = simulate_voltages(opcodes, selects, CFG, "NOR")
    assert {(g.inputs, g.output) for g in gates} == \
        {(g.inputs, g.output) for g in op.gates}


def test_standard_opcode_generator_matches_direct_opcodes():
    # distance-1 copies, period 2 ("inputs left of outputs")
    op = Operation(gates=tuple(
        GateOp("NOT", (CFG.col(p, 3),), CFG.col(p + 1, 5))
        for p in range(0, 30, 2)))
    opcodes, selects = op_opcodes(op, CFG)
    active = [False] * CFG.k
    for p in range(0, 30, 2):
        active[p] = active[p + 1] = True
    trios = standard_opcode_generator(selects, active, +1)
    for p in range(CFG.k):
        en_a, en_b, en_out = trios[p]
        assert en_a == opcodes[p].en_a
        assert en_out == opcodes[p].en_out


def test_minimal_range_generator():
    in_en, out_en, selects = minimal_range_generator(
        32, p_start=0, p_end=28, period=4, distance=2, direction=+1)
    assert [p for p in range(32) if in_en[p]] == [0, 4, 8, 12, 16, 20, 24, 28]
    assert [p for p in range(32) if out_en[p]] == [2, 6, 10, 14, 18, 22, 26, 30]
    secs = sections_from_selects(selects)
    assert (0, 2) in secs and (4, 6) in secs


def test_too_many_output_drivers_detected():
    from repro.core.periphery import PartitionOpcode

    opcodes = [PartitionOpcode()] * 30 + [
        PartitionOpcode(en_out=True, idx_out=0),
        PartitionOpcode(en_out=True, idx_out=0),
    ]
    selects = [True] * 30 + [False]  # last two partitions share a section
    with pytest.raises(LegalityError):
        simulate_voltages(opcodes, selects, CFG, "NOT")
