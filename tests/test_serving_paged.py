"""Block-paged KV-cache pool: allocator mechanics, scheduler churn
equivalence, and sliding-window serving.

Load-bearing assertions mirror test_serving.py's, extended to the paged
layout: (1) the pool layout is a memory optimization, never a semantic
one — a churning Poisson request mix yields tokens bit-identical to the
sequential (max_batch=1) oracle through *both* pools; (2) block churn
never recompiles the decode step (the block table's shape is fixed);
(3) free-list exhaustion defers admission instead of crashing, and evict
returns blocks; (4) sliding-window configs — which the contiguous pool
rejects by construction — serve end-to-end as rings over their block
lists, matching both the naive ring-decode oracle and a teacher-forced
full-prefill oracle with prompts on either side of the window.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import context as dctx
from repro.dist import partitioning as dpart
from repro.launch.mesh import make_host_mesh
from repro.models import model_lib as M
from repro.serving import PagedCachePool, Scheduler, ServingConfig


@pytest.fixture(scope="module")
def cfg(small_model_config):
    return small_model_config


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def wcfg(cfg):
    return cfg.scaled(sliding_window=16)


@pytest.fixture(scope="module")
def wparams(wcfg):
    return M.init_params(wcfg, jax.random.PRNGKey(0))


def _naive_decode(params, cfg, prompt, n):
    """One-request-at-a-time reference: unpadded prefill + scalar decode."""
    batch = {"tokens": jnp.asarray(np.asarray(prompt)[None, :], jnp.int32)}
    logits, caches = jax.jit(lambda p, b: M.prefill(p, b, cfg))(params, batch)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [int(tok[0, 0])]
    step = jax.jit(lambda p, t, pos, c: M.decode_step(p, t, pos, c, cfg))
    for i in range(n - 1):
        tok, _, caches = step(params, tok, jnp.int32(len(prompt) + i), caches)
        out.append(int(tok[0, 0]))
    return np.asarray(out, np.int32)


def _teacher_forced(params, cfg, prompt, n):
    """Cache-free oracle: re-prefill the whole sequence for every token.
    Exercises none of the ring/paging machinery, so it cross-checks it."""
    toks = list(map(int, prompt))
    out = []
    for _ in range(n):
        logits, _ = M.prefill(params, {"tokens": jnp.asarray([toks],
                                                            jnp.int32)}, cfg)
        t = int(np.asarray(jnp.argmax(logits, -1))[0])
        out.append(t)
        toks.append(t)
    return np.asarray(out, np.int32)


# --------------------------------------------------------------------------
# pool mechanics
# --------------------------------------------------------------------------

def test_paged_pool_admit_read_evict_roundtrip(cfg, params):
    """Admit converts the prefill cache into position-ordered blocks;
    read_slot gathers them back; evict zeroes and frees the blocks."""
    pool = PagedCachePool(cfg, max_batch=2, block_size=8)
    assert pool.blocks_per_slot == cfg.max_seq_len // 8
    plen = 8
    toks = jnp.asarray(np.arange(plen)[None, :], jnp.int32)
    _, cache = jax.jit(lambda p, b: M.prefill(p, b, cfg))(
        params, {"tokens": toks})
    pool.admit(1, cache, plen=plen, n_tokens=12)   # ceil(12/8) = 2 blocks
    assert pool.blocks_in_use == 2
    assert pool.peak_blocks_in_use == 2
    got = pool.read_slot(1)
    for li, c in got.items():
        for key in M.PAGED_KV_KEYS:
            if key not in c:
                continue
            g = np.asarray(c[key])          # (ns, 1, lcap, ...)
            want = np.asarray(cache[li][key]).astype(g.dtype)
            np.testing.assert_array_equal(g[:, :, :plen], want[:, :, :plen])
            # reserved-but-unwritten positions inside the slot's blocks
            # were zeroed at admit (prefill headroom never leaks through)
            assert not g[:, :, plen:16].any()
    # slot 0 untouched
    assert all(not np.asarray(l).any()
               for l in jax.tree.leaves(pool.read_slot(0)))
    pool.evict(1)
    assert pool.blocks_in_use == 0
    assert all(not np.asarray(l).any()
               for l in jax.tree.leaves(pool.read_slot(1)))


def test_paged_pool_free_list_accounting(cfg, params):
    """Blocks freed by evict are reusable; double-admit and free-list
    underflow are loud errors, not corruption."""
    pool = PagedCachePool(cfg, max_batch=2, block_size=16, num_blocks=3)
    toks = jnp.asarray(np.arange(4)[None, :], jnp.int32)
    _, cache = jax.jit(lambda p, b: M.prefill(p, b, cfg))(
        params, {"tokens": toks})
    assert pool.can_admit(20) and not pool.can_admit(40)  # 2 usable blocks
    pool.admit(0, cache, plen=4, n_tokens=20)
    assert not pool.can_admit(20)                # free list exhausted
    with pytest.raises(RuntimeError, match="free list underflow"):
        pool.admit(1, cache, plen=4, n_tokens=20)
    with pytest.raises(RuntimeError, match="already holds"):
        pool.admit(0, cache, plen=4, n_tokens=4)
    pool.evict(0)
    assert pool.can_admit(20)                    # blocks came back
    pool.admit(1, cache, plen=4, n_tokens=20)
    assert pool.blocks_in_use == 2


def test_unsatisfiable_request_rejected_at_submit(cfg, params):
    """A request that could never fit the whole pool is refused up front —
    deferring it would stall the queue forever."""
    sched = Scheduler(params, cfg,
                      ServingConfig(max_batch=1, paged=True, block_size=16,
                                    num_blocks=2))     # 1 usable block
    with pytest.raises(ValueError, match="KV blocks"):
        sched.submit(list(range(1, 9)), 16)            # needs 2 blocks
    sched.submit([1, 2], 8)                            # 1 block: fine


def test_exhaustion_defers_admission_until_blocks_free(cfg, params):
    """A request whose reservation exceeds the free list stays queued
    (FIFO back-pressure) and is served once an eviction frees blocks."""
    sched = Scheduler(params, cfg,
                      ServingConfig(max_batch=2, prompt_bucket=8, paged=True,
                                    block_size=16, num_blocks=3))
    r1 = sched.submit(list(range(1, 9)), 20)     # 2 blocks: whole free list
    r2 = sched.submit([5, 6, 7], 10)             # 1 block: must wait
    sched.step()
    assert sched._slot_rid.tolist() == [r1, -1]  # r2 deferred, slot left free
    assert len(sched.queue) == 1
    assert sched.metrics.deferred_admits == 1
    out = sched.run()
    assert len(out[r1]) == 20 and len(out[r2]) == 10
    assert sched.decode_traces == 1
    # counted once per deferred *request*, not per step spent waiting
    assert sched.metrics.deferred_admits == 1
    want = _naive_decode(params, cfg, [5, 6, 7], 10)
    np.testing.assert_array_equal(out[r2], want)


def test_paged_specs_and_block_math(cfg, wcfg):
    """paged_cache_specs re-lays only the attention-KV leaves; block-need
    arithmetic clamps windowed requests to the ring."""
    specs = M.paged_cache_specs(cfg, batch=4, seq_len=64, num_blocks=9,
                                block_size=8)
    for c in specs.values():
        for key, leaf in c.items():
            if key in M.PAGED_KV_KEYS:
                assert leaf.shape[1:3] == (9, 8)
            else:
                assert leaf.shape[1] == 4        # slot-indexed
    assert cfg.kv_blocks_for(1, 16) == 1
    assert cfg.kv_blocks_for(17, 16) == 2
    assert cfg.window_ring_blocks(16) is None
    assert wcfg.window_ring_blocks(8) == 2       # window 16 / block 8
    assert wcfg.kv_blocks_for(1000, 8) == 2      # ring-capped, not linear


# --------------------------------------------------------------------------
# mesh placement
# --------------------------------------------------------------------------

def test_paged_pool_under_mesh_matches_meshless(cfg, params):
    """Block-table round-trip under the 8-device mesh: paged leaves keep
    the block dim replicated with heads on "model" (cache_pspecs), the
    table replicates, and generations match the meshless run."""
    mesh = make_host_mesh(model=2)
    with dctx.use_mesh(mesh):
        sched = Scheduler(params, cfg,
                          ServingConfig(max_batch=2, prompt_bucket=8,
                                        paged=True, block_size=8),
                          mesh=mesh)
        specs = dpart.cache_pspecs(sched.pool.caches, mesh,
                                   batch_over_dp=False)
        for c in specs.values():
            for key, spec in c.items():
                entries = tuple(spec)
                if key in M.PAGED_KV_KEYS:
                    assert entries[1] is None    # block dim replicated
                    if len(entries) >= 4:
                        assert entries[-2] == "model"
        assert sched.pool.block_tables.sharding.is_fully_replicated
        rids = [sched.submit([1, 2, 3, 4, 5], 6), sched.submit([9, 8], 4)]
        out = sched.run()
        assert sched.decode_traces == 1
    plain = Scheduler(params, cfg, ServingConfig(max_batch=2,
                                                 prompt_bucket=8,
                                                 paged=True, block_size=8))
    rids2 = [plain.submit([1, 2, 3, 4, 5], 6), plain.submit([9, 8], 4)]
    out2 = plain.run()
    for ra, rb in zip(rids, rids2):
        np.testing.assert_array_equal(out[ra], out2[rb])


# --------------------------------------------------------------------------
# scheduler churn: paged == contiguous == sequential oracle
# --------------------------------------------------------------------------

def test_random_churn_both_pools_match_sequential_oracle(cfg, params):
    """A seeded Poisson admit/finish trace with randomized prompt lengths
    *and* budgets runs through the contiguous pool, the paged pool, and a
    sequential (max_batch=1) scheduler: all three emit bit-identical
    tokens, and neither batched run ever recompiles its decode step."""
    rng = np.random.default_rng(11)
    n_req = 9
    t, reqs = 0.0, []
    for _ in range(n_req):
        t += float(rng.exponential(1.0 / 200.0))  # Poisson arrivals
        plen = int(rng.integers(1, 20))
        budget = int(rng.integers(1, 9))          # includes admit-finishers
        reqs.append((rng.integers(0, cfg.vocab_size, plen), budget, t))

    def run_pool(**kw):
        sched = Scheduler(params, cfg,
                          ServingConfig(prompt_bucket=8, **kw))
        base = sched.clock()
        rids = [sched.submit(p, b, arrival_time=base + at)
                for p, b, at in reqs]
        res = sched.run()
        return [res[r] for r in rids], sched

    oracle, _ = run_pool(max_batch=1)
    got_c, sc = run_pool(max_batch=3, paged=False)
    got_p, sp = run_pool(max_batch=3, paged=True, block_size=8)
    assert sc.decode_traces <= 1 and sp.decode_traces <= 1, \
        "slot/block churn must not recompile the decode step"
    for want, a, b in zip(oracle, got_c, got_p):
        np.testing.assert_array_equal(a, want)
        np.testing.assert_array_equal(b, want)
    # the paged run peaked strictly below the contiguous reservation
    assert (sp.metrics.summary()["peak_kv_bytes"]
            < sc.metrics.summary()["peak_kv_bytes"])


# --------------------------------------------------------------------------
# sliding-window serving
# --------------------------------------------------------------------------

def test_sliding_window_serves_end_to_end(wcfg, wparams):
    """Windowed configs serve through the (auto-enabled) paged pool with
    prompts on both sides of the window and decodes straddling it,
    matching the naive ring-decode oracle and the cache-free
    teacher-forced oracle."""
    rng = np.random.default_rng(3)
    short = rng.integers(0, wcfg.vocab_size, 5)   # < window; decode crosses
    long_ = rng.integers(0, wcfg.vocab_size, 29)  # > window at prefill
    sched = Scheduler(wparams, wcfg,
                      ServingConfig(max_batch=2, prompt_bucket=8,
                                    block_size=8))
    assert sched.pool.paged
    assert sched.pool.blocks_per_slot == 2        # the ring, not max_len/8
    rid_s = sched.submit(short, 20)
    rid_l = sched.submit(long_, 10)
    out = sched.run()
    assert sched.decode_traces == 1
    for rid, prompt, n in ((rid_s, short, 20), (rid_l, long_, 10)):
        np.testing.assert_array_equal(
            out[rid], _naive_decode(wparams, wcfg, prompt, n))
        np.testing.assert_array_equal(
            out[rid], _teacher_forced(wparams, wcfg, prompt, n))


def test_sliding_window_matches_unwindowed_when_window_never_binds(
        cfg, params, wcfg, wparams):
    """A request whose prompt+generation stays inside the window must
    decode as if unwindowed — the window mask never cuts a key.  (The
    last-token logits of windowed vs unwindowed prefill agree too.)"""
    prompt = [3, 1, 4, 1]
    n = 6                                         # 4 + 6 <= window 16
    sched = Scheduler(wparams, wcfg, ServingConfig(max_batch=1,
                                                   prompt_bucket=8,
                                                   block_size=8))
    rid = sched.submit(prompt, n)
    got = sched.run()[rid]
    want = _naive_decode(params, cfg, prompt, n)  # unwindowed, same params
    np.testing.assert_array_equal(got, want)


def test_windowed_bucket_rule(wcfg, wparams):
    """Prompts bucket while the padded length stays inside the window;
    past it they run unpadded (pad KV inside the ring would corrupt)."""
    sched = Scheduler(wparams, wcfg, ServingConfig(max_batch=1,
                                                   prompt_bucket=8))
    assert sched._bucket(3) == 8                  # 8 <= window 16
    assert sched._bucket(13) == 16                # 16 <= window 16
    assert sched._bucket(17) == 17                # 24 > window: unpadded


def test_int8_kv_pages_with_scales(cfg, params):
    """Quantized KV caches page too (values + per-position scales)."""
    qcfg = cfg.scaled(kv_cache_dtype="int8")
    qparams = params                              # same tree, new cache dtype
    outs = {}
    for paged in (False, True):
        sched = Scheduler(qparams, qcfg,
                          ServingConfig(max_batch=2, prompt_bucket=8,
                                        paged=paged, block_size=8))
        rid = sched.submit([1, 2, 3, 4, 5], 6)
        outs[paged] = sched.run()[rid]
    np.testing.assert_array_equal(outs[True], outs[False])
