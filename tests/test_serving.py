"""repro.serving: queue, cache pool, continuous-batching scheduler, metrics.

The load-bearing assertions: (1) steady-state decode under a churning
request mix triggers exactly one jit trace (the recompile counter), and
(2) the scheduler's generations are bit-identical to naive one-request-
at-a-time prefill+decode — continuous batching changes throughput, never
tokens.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import context as dctx
from repro.dist import partitioning as dpart
from repro.launch.mesh import make_host_mesh
from repro.models import model_lib as M
from repro.serving import (AdmissionQueue, CachePool, Scheduler,
                           ServingConfig, make_request, synthetic_requests)

B_SLOTS = 3


class FakeClock:
    """Settable clock: metrics become exactly computable in tests."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


@pytest.fixture(scope="module")
def cfg(small_model_config):
    return small_model_config


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _naive_decode(params, cfg, req):
    """One-request-at-a-time reference: unpadded prefill + scalar decode."""
    batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
    logits, caches = jax.jit(lambda p, b: M.prefill(p, b, cfg))(params, batch)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [int(tok[0, 0])]
    step = jax.jit(lambda p, t, pos, c: M.decode_step(p, t, pos, c, cfg))
    for i in range(req.max_new_tokens - 1):
        tok, _, caches = step(params, tok,
                              jnp.int32(len(req.prompt) + i), caches)
        out.append(int(tok[0, 0]))
    return np.asarray(out, np.int32)


# --------------------------------------------------------------------------
# queue
# --------------------------------------------------------------------------

def test_queue_fifo_and_arrival_gating():
    q = AdmissionQueue()
    r1 = make_request([1, 2], 4, arrival_time=1.0)
    r2 = make_request([3], 4, arrival_time=5.0)
    q.submit(r1)
    q.submit(r2)
    assert len(q) == 2
    assert q.pop(now=0.5) is None          # head not arrived yet
    assert q.pop(now=1.0) is r1            # FIFO head
    assert q.pop(now=1.0) is None          # r2 still in the future
    assert q.pop() is r2                   # now=None ignores arrival times
    assert q.pop() is None


def test_request_validation():
    with pytest.raises(ValueError, match="empty prompt"):
        make_request([], 4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        make_request([1], 0)


def test_synthetic_requests_deterministic():
    a = synthetic_requests(5, vocab_size=64, prompt_lens=[3, 7],
                           max_new_tokens=4, rate=10.0, seed=3)
    b = synthetic_requests(5, vocab_size=64, prompt_lens=[3, 7],
                           max_new_tokens=4, rate=10.0, seed=3)
    for ra, rb in zip(a, b):
        assert np.array_equal(ra.prompt, rb.prompt)
        assert ra.arrival_time == rb.arrival_time
    assert a[0].arrival_time <= a[-1].arrival_time


# --------------------------------------------------------------------------
# cache pool
# --------------------------------------------------------------------------

def test_cache_pool_assign_read_evict(cfg, params):
    pool = CachePool(cfg, max_batch=2, max_len=cfg.max_seq_len)
    toks = jnp.asarray(np.arange(8)[None, :], jnp.int32)
    _, cache = jax.jit(lambda p, b: M.prefill(p, b, cfg))(
        params, {"tokens": toks})
    pool.assign(1, cache)
    got = pool.read_slot(1)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(cache)):
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(b).astype(a.dtype))
    # slot 0 untouched (still zeros)
    assert all(not np.asarray(l).any() for l in jax.tree.leaves(
        pool.read_slot(0)))
    pool.evict(1)
    assert all(not np.asarray(l).any() for l in jax.tree.leaves(
        pool.read_slot(1)))


def test_cache_pool_pspecs_keep_slot_dim_replicated(cfg):
    """Serving pool placement: slot (batch) dim replicated, heads on
    "model" — dist.cache_pspecs(batch_over_dp=False)."""
    mesh = make_host_mesh(model=2)
    specs = M.cache_specs(cfg, 4, 16)
    with dctx.use_mesh(mesh):
        pinned = dpart.cache_pspecs(specs, mesh, batch_over_dp=False)
        default = dpart.cache_pspecs(specs, mesh)
    for spec, leaf in zip(jax.tree.leaves(
            pinned, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)),
            jax.tree.leaves(specs,
                            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))):
        entries = tuple(spec)
        assert len(entries) < 2 or entries[1] is None
        if len(leaf.shape) >= 4:
            assert entries[-2] == "model"
    # and the default still shards the batch dim over DP somewhere
    assert any(tuple(s)[1:2] not in ((), (None,)) for s in jax.tree.leaves(
        default, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))


# --------------------------------------------------------------------------
# scheduler: slot mechanics
# --------------------------------------------------------------------------

def test_slot_backfill_and_eviction_order(cfg, params):
    """Admissions fill the lowest free slot; a finished slot is evicted and
    backfilled on the next step while other slots keep decoding."""
    clk = FakeClock()
    sched = Scheduler(params, cfg, ServingConfig(max_batch=2,
                                                 prompt_bucket=8),
                      clock=clk)
    r_short = sched.submit([1, 2, 3], 3)        # finishes first
    r_long = sched.submit([4, 5, 6, 7], 6)
    r_wait = sched.submit([8, 9], 5)            # queued until a slot frees

    sched.step()          # admit emits token 1, decode token 2 for both
    assert sched._slot_rid.tolist() == [r_short, r_long]
    assert len(sched.queue) == 1
    sched.step()                                 # r_short emits its 3rd token
    assert sched._slot_rid[0] == -1              # ... and is evicted
    assert sched._slot_rid[1] == r_long
    # evicted slot is zeroed (stale KV cannot leak into the next request)
    assert all(not np.asarray(l).any()
               for l in jax.tree.leaves(sched.pool.read_slot(0)))
    sched.step()                                 # backfill into slot 0
    assert sched._slot_rid.tolist() == [r_wait, r_long]
    assert len(sched.queue) == 0
    out = sched.run()
    assert {r_short: 3, r_long: 6, r_wait: 5} == {
        rid: len(toks) for rid, toks in out.items()}


def test_scheduler_rejects_oversized_request(cfg, params):
    sched = Scheduler(params, cfg, ServingConfig(max_batch=1))
    with pytest.raises(ValueError, match="exceeds cache capacity"):
        sched.submit(np.zeros(cfg.max_seq_len, np.int32), 1)


def test_scheduler_rejects_unservable_configs(cfg, params):
    """Explicit capability boundaries: multimodal prefill inputs are a
    ROADMAP follow-on, not silent garbage.  Sliding-window configs are
    servable since the paged pool landed — they page unconditionally (a
    windowed slot is a ring over its block list, which the contiguous
    pool cannot express)."""
    with pytest.raises(NotImplementedError, match="multimodal"):
        Scheduler(params, cfg.scaled(vision_dim=8, n_patches=4),
                  ServingConfig(max_batch=1))
    sched = Scheduler(params, cfg.scaled(sliding_window=16),
                      ServingConfig(max_batch=1))
    assert sched.pool.paged, "windowed configs must auto-page"


def test_run_raises_on_stalled_clock(cfg, params):
    """run() must not spin forever when an injected clock never reaches the
    head request's arrival time."""
    sched = Scheduler(params, cfg, ServingConfig(max_batch=1),
                      clock=FakeClock(0.0))
    sched.submit([1, 2], 2, arrival_time=100.0)
    with pytest.raises(RuntimeError, match="clock is not advancing"):
        sched.run()


def test_eos_stops_generation_early(cfg, params):
    probe = Scheduler(params, cfg, ServingConfig(max_batch=1))
    rid = probe.submit([5, 6, 7], 6)
    full = probe.run()[rid]
    eos = int(full[2])                           # third generated token
    sched = Scheduler(params, cfg, ServingConfig(max_batch=1, eos_id=eos))
    rid2 = sched.submit([5, 6, 7], 6)
    got = sched.run()[rid2]
    assert got.tolist() == full[:3].tolist()
    assert sched.metrics.requests[rid2].finish_time is not None


# --------------------------------------------------------------------------
# scheduler: metrics
# --------------------------------------------------------------------------

def test_ttft_tpot_accounting(cfg, params):
    clk = FakeClock()
    sched = Scheduler(params, cfg, ServingConfig(max_batch=1,
                                                 prompt_bucket=8),
                      clock=clk)
    rid = sched.submit([1, 2, 3, 4], 3, arrival_time=0.0)
    clk.t = 5.0
    sched.step()          # admit (token 1) + decode (token 2), both @ 5.0
    clk.t = 7.0
    sched.step()                                 # third token @ 7.0, finish
    m = sched.metrics.requests[rid]
    assert m.ttft == pytest.approx(5.0)
    assert m.queue_wait == pytest.approx(5.0)
    assert m.tpot == pytest.approx(1.0)          # (7 - 5) / 2
    assert m.n_tokens == 3 and m.finish_time == pytest.approx(7.0)
    s = sched.metrics.summary()
    assert s["n_finished"] == 1 and s["total_tokens"] == 3
    assert s["tokens_per_s"] == pytest.approx(3 / 2.0)  # busy window 5..7
    assert s["max_queue_depth"] == 0


# --------------------------------------------------------------------------
# scheduler: steady state + end-to-end equivalence
# --------------------------------------------------------------------------

def test_churning_stream_matches_naive_decode_and_never_recompiles(
        cfg, params):
    """Acceptance: a churning request stream produces tokens identical to
    one-at-a-time decode, with exactly one decode-step trace."""
    reqs = synthetic_requests(7, vocab_size=cfg.vocab_size,
                              prompt_lens=[5, 9, 13, 3], max_new_tokens=6,
                              seed=1)
    sched = Scheduler(params, cfg, ServingConfig(max_batch=B_SLOTS,
                                                 prompt_bucket=8))
    for r in reqs:
        sched.submit_request(r)
    out = sched.run()
    assert sched.decode_traces == 1, \
        "slot churn must not recompile the decode step"
    assert sched.n_active == 0 and len(sched.queue) == 0
    for r in reqs:
        want = _naive_decode(params, cfg, r)
        assert np.array_equal(out[r.rid], want), r.rid
    s = sched.metrics.summary()
    assert s["n_finished"] == len(reqs)
    assert s["total_tokens"] == sum(r.max_new_tokens for r in reqs)


def test_recurrent_arch_unbucketed_prefill_matches_naive():
    """SSM/xLSTM stacks serve exactly with prompt_bucket=1 (no padding to
    fold into the recurrent state); slot churn still never recompiles."""
    import repro.configs as configs

    rcfg = configs.get("xlstm-1.3b").smoke()
    rparams = M.init_params(rcfg, jax.random.PRNGKey(3))
    reqs = synthetic_requests(3, vocab_size=rcfg.vocab_size,
                              prompt_lens=[4, 7], max_new_tokens=4, seed=2)
    sched = Scheduler(rparams, rcfg, ServingConfig(max_batch=2,
                                                   prompt_bucket=1))
    for r in reqs:
        sched.submit_request(r)
    out = sched.run()
    assert sched.decode_traces == 1
    for r in reqs:
        want = _naive_decode(rparams, rcfg, r)
        assert np.array_equal(out[r.rid], want), r.rid


def test_single_token_requests_never_occupy_slots(cfg, params):
    """max_new_tokens=1 completes at admit (prefill emits the only token)."""
    sched = Scheduler(params, cfg, ServingConfig(max_batch=2))
    rids = [sched.submit([i + 1, i + 2], 1) for i in range(4)]
    out = sched.run()
    assert sched.decode_traces == 0              # decode never even traced
    for rid in rids:
        assert out[rid].shape == (1,)
        assert sched.metrics.requests[rid].finish_time is not None
    # admit-and-finish never touched the pool: free slots stay zeroed
    for slot in range(2):
        assert all(not np.asarray(l).any()
                   for l in jax.tree.leaves(sched.pool.read_slot(slot)))
