"""pim.cost_model: the autotuner's planner — monotonicity, partition
scaling, per-design control bits, device-parameter overrides, and the
registry-priced serial multiplier algorithms."""
import dataclasses

import pytest

from repro.pim.cost_model import PimDeviceParams, gemm_cost, mult_cost

PARTITIONED = ("unlimited", "standard", "minimal")


# --------------------------------------------------------------------------
# monotonicity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("model",
                         ["baseline", "minimal", "serial_fast",
                          "compressor42"])
def test_mult_cost_monotonic_in_bits(model):
    c8 = mult_cost(8, model)
    c16 = mult_cost(16, model)
    c32 = mult_cost(32, model)
    assert c8["cycles"] < c16["cycles"] < c32["cycles"]
    assert c8["gates"] < c16["gates"] < c32["gates"]


@pytest.mark.parametrize("model", PARTITIONED)
def test_gemm_cost_monotonic_in_terms(model):
    times = [gemm_cost(4, k, 8, 8, model).time_s for k in (8, 16, 32)]
    assert times[0] < times[1] < times[2]


def test_gemm_cost_monotonic_in_bits():
    t8 = gemm_cost(4, 16, 8, 8, "minimal").time_s
    t16 = gemm_cost(4, 16, 8, 16, "minimal").time_s
    assert t8 < t16


# --------------------------------------------------------------------------
# partition-count / crossbar scaling
# --------------------------------------------------------------------------

def test_crossbar_count_scales_with_output_rows():
    """One output element per crossbar row: m*n rows -> ceil over n_rows."""
    dev = PimDeviceParams()
    c1 = gemm_cost(256, 16, 4, 8, "minimal", dev)
    assert c1.crossbars == 1          # 1024 rows fit one crossbar
    c2 = gemm_cost(2048, 16, 1024, 8, "minimal", dev)
    assert c2.crossbars == 2048       # 2048*1024 rows / 1024 per crossbar
    c3 = gemm_cost(4096, 16, 1024, 8, "minimal", dev)
    assert c3.crossbars == 2 * c2.crossbars


def test_waves_when_chip_is_smaller_than_the_gemm():
    dev = PimDeviceParams(crossbars=4)
    c = gemm_cost(8 * 1024, 16, 1, 8, "minimal", dev)  # needs 8 crossbars
    assert c.waves == 2 and c.crossbars == 4
    big = gemm_cost(8 * 1024, 16, 1, 8, "minimal", PimDeviceParams())
    assert c.time_s == pytest.approx(2 * big.time_s)


# --------------------------------------------------------------------------
# control bits per partition design (§5.2)
# --------------------------------------------------------------------------

def test_control_bits_per_design():
    want = {"baseline": 30, "unlimited": 607, "standard": 79, "minimal": 36}
    for model, bits in want.items():
        assert mult_cost(32, model)["msg_bits"] == bits
    # control traffic ranks the designs the way the paper does
    g = {m: gemm_cost(4, 16, 8, 8, m).control_bits
         for m in ("unlimited", "standard", "minimal")}
    assert g["minimal"] < g["standard"] < g["unlimited"]


# --------------------------------------------------------------------------
# device-parameter overrides
# --------------------------------------------------------------------------

def test_cycle_time_override_scales_time():
    slow = gemm_cost(4, 16, 8, 8, "minimal", PimDeviceParams(cycle_ns=20.0))
    base = gemm_cost(4, 16, 8, 8, "minimal", PimDeviceParams(cycle_ns=10.0))
    assert slow.time_s == pytest.approx(2 * base.time_s)
    assert slow.energy_j == base.energy_j   # energy is cycle-time-free


def test_gate_energy_override_scales_energy():
    hot = gemm_cost(4, 16, 8, 8, "minimal",
                    PimDeviceParams(gate_energy_pj=1.0))
    base = gemm_cost(4, 16, 8, 8, "minimal",
                     PimDeviceParams(gate_energy_pj=0.1))
    assert hot.energy_j == pytest.approx(10 * base.energy_j)
    assert hot.time_s == base.time_s


def test_device_n_cols_sets_default_geometry():
    wide = gemm_cost(4, 16, 8, 8, "minimal", PimDeviceParams(n_cols=2048))
    assert wide.n_cols == 2048
    override = gemm_cost(4, 16, 8, 8, "minimal", n_cols=4096)
    assert override.n_cols == 4096


# --------------------------------------------------------------------------
# geometry + chunk pricing (the autotuner's search axes)
# --------------------------------------------------------------------------

def test_chunk_none_collapses_to_legacy_pricing():
    legacy = gemm_cost(4, 64, 8, 8, "minimal")
    explicit = gemm_cost(4, 64, 8, 8, "minimal", n_cols=1024, chunk=None)
    assert dataclasses.asdict(explicit) == dataclasses.asdict(legacy)


def test_chunking_pays_per_chunk_fixed_cost():
    one = gemm_cost(4, 64, 8, 8, "minimal", n_cols=1024, chunk=64)
    two = gemm_cost(4, 64, 8, 8, "minimal", n_cols=1024, chunk=32)
    assert two.chunks == 2 and one.chunks == 1
    assert two.cycles_per_wave > one.cycles_per_wave


def test_wider_geometry_beats_chunked_narrow_at_k96():
    """max_dot_terms(8, 1024) < 96 <= max_dot_terms(8, 2048): the trade
    the tuner exists to call — one wide program vs three narrow chunks."""
    from repro.pim.matmul import max_dot_terms

    narrow_chunk = max_dot_terms(8, 1024)
    assert narrow_chunk < 96 <= max_dot_terms(8, 2048)
    narrow = gemm_cost(4, 96, 8, 8, "minimal", n_cols=1024,
                       chunk=narrow_chunk)
    wide = gemm_cost(4, 96, 8, 8, "minimal", n_cols=2048, chunk=96)
    assert narrow.chunks == 3 and wide.chunks == 1
    assert wide.cycles_per_wave < narrow.cycles_per_wave


# --------------------------------------------------------------------------
# serial multiplier algorithms price through the engine registry
# --------------------------------------------------------------------------

def test_new_serial_models_priced_and_faster_than_nor_baseline():
    base = mult_cost(32, "baseline")
    for name in ("serial_fast", "compressor42"):
        c = mult_cost(32, name)
        assert c["cycles"] < base["cycles"], name
        assert c["msg_bits"] == base["msg_bits"] == 30  # all serial: 30 bits


def test_serial_algorithms_still_lose_to_partitioned_gemm():
    """The race result the candidates() ranking reproduces (paper ~9x)."""
    t_part = gemm_cost(4, 16, 8, 8, "minimal").time_s
    for name in ("baseline", "serial_fast", "compressor42"):
        assert gemm_cost(4, 16, 8, 8, name).time_s > t_part, name


def test_unknown_model_raises():
    with pytest.raises(Exception):
        gemm_cost(4, 16, 8, 8, "not-a-model")
