"""pim.autotune: cost-model-driven search, plan application, persistence.

Every tuned configuration must compute the identical integer GEMM — the
tuner changes speed, never results — and the pick can never lose to the
hardcoded default because the default is always in the timed race."""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.pim import autotune, engine


@pytest.fixture(autouse=True)
def _fresh():
    engine.clear_cache()        # also clears the tuner table + counters
    autotune.enable(False)
    yield
    engine.clear_cache()
    autotune.enable(False)


def _operands(k, m=2, o=4, n_bits=8, seed=0):
    rng = np.random.default_rng(seed)
    hi = np.uint64(1) << np.uint64(n_bits)
    return (rng.integers(0, hi, size=(m, k), dtype=np.uint64),
            rng.integers(0, hi, size=(o, k), dtype=np.uint64))


# --------------------------------------------------------------------------
# candidate enumeration
# --------------------------------------------------------------------------

def test_candidates_cover_the_search_grid_sorted():
    cands = autotune.candidates(24, 8, (2, 8), "raw")
    execable = [p for p in cands if p.chunk > 0]
    assert {p.model for p in execable} == set(autotune.PARTITIONED_MODELS)
    assert {p.n_cols for p in execable} == set(autotune.GEOMETRIES)
    assert {p.backend for p in execable} == set(autotune.STATE_BACKENDS)
    # serial multiplier algorithms rank in the race but cannot execute
    serial = {p.model for p in cands if p.chunk == 0}
    assert {"serial_fast", "compressor42", "baseline"} <= serial
    pred = [p.predicted_us for p in cands]
    assert pred == sorted(pred)


def test_pim_sim_candidates_are_callback_safe():
    """Inside jax.pure_callback only the jax-free interpreter may run."""
    cands = autotune.candidates(24, 8, (2, 8), "pim_sim")
    backends = {p.backend for p in cands if p.chunk > 0}
    assert backends == set(autotune.CALLBACK_BACKENDS) == {"numpy"}


def test_tune_key_buckets_batch_rows():
    k = autotune.tune_key(24, 8, "minimal", (5, 16), "raw")
    assert k == autotune.tune_key(24, 8, "minimal", (8, 16), "raw")
    assert k != autotune.tune_key(24, 8, "minimal", (9, 16), "raw")
    assert k != autotune.tune_key(24, 8, "minimal", (5, 16), "pim_sim")


# --------------------------------------------------------------------------
# the search
# --------------------------------------------------------------------------

def test_autotune_never_loses_to_the_default():
    plan = autotune.autotune(12, 8, (2, 4), "raw", trials=1, top_k=2)
    assert plan.source == "trial"
    assert plan.default_us > 0, "the default must have raced"
    assert plan.vs_default >= 1.0
    assert plan.trial_us <= plan.default_us


def test_autotune_caches_and_counts():
    p1 = autotune.autotune(12, 8, (2, 4), "raw", trials=0)
    info = engine.cache_info()
    assert info.tune_misses == 1 and info.tune_hits == 0
    p2 = autotune.autotune(12, 8, (2, 4), "raw", trials=0)
    assert p2 is p1
    info = engine.cache_info()
    assert info.tune_hits == 1
    # trials are counted through cache_info too
    engine.clear_cache()
    autotune.autotune(12, 8, (2, 4), "raw", trials=1, top_k=2)
    assert engine.cache_info().tune_trials >= 3  # top_k + default


def test_plan_attached_to_compiled_artifact():
    plan = autotune.autotune(12, 8, (2, 4), "raw", trials=0)
    art = engine.compile_matmul(min(plan.chunk, 12), 8, model=plan.model,
                                n_cols=plan.n_cols)
    assert art.plan is plan
    # cache hits carry the plan with them
    assert engine.compile_matmul(min(plan.chunk, 12), 8, model=plan.model,
                                 n_cols=plan.n_cols).plan is plan


def test_tuned_matmul_bit_exact_vs_default():
    x, w = _operands(12)
    want = x.astype(object) @ w.T.astype(object)
    default = engine.matmul_int(x, w, 8)
    for mode in ("raw", "pim_sim"):
        plan = autotune.autotune(12, 8, (2, 4), mode, trials=0)
        tuned = engine.matmul_int(x, w, 8, plan=plan)
        assert np.array_equal(tuned.astype(object), want), mode
        assert np.array_equal(tuned, default), mode


def test_tune_ctx_lookup_is_gated_on_enable():
    x, w = _operands(12)
    plan = autotune.autotune(12, 8, (2, 4), "pim_sim", trials=0)
    # disabled: lookup returns None, matmul takes the default path
    assert autotune.lookup(12, 8, shape=(2, 4), pim_mode="pim_sim") is None
    autotune.enable(True)
    got = autotune.lookup(12, 8, shape=(2, 4), pim_mode="pim_sim")
    assert got is plan
    before = engine.cache_info().tune_hits
    y = engine.matmul_int(x, w, 8, tune_ctx="pim_sim")
    assert engine.cache_info().tune_hits == before + 1
    assert np.array_equal(y, engine.matmul_int(x, w, 8))


def test_sim_linear_tuned_matches_untuned_bit_exactly():
    """The serving contract: a tuned pim_sim decode changes nothing."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 6)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(6, 4)).astype(np.float32))
    ref = np.asarray(engine.sim_linear(x, w))
    # sim_linear quantizes to 7 bits and multiplies at 8 (offset-shifted)
    autotune.autotune(6, 8, (2, 4), "pim_sim", trials=0)
    autotune.enable(True)
    tuned = np.asarray(engine.sim_linear(x, w))
    assert np.array_equal(tuned, ref)
    assert engine.cache_info().tune_hits >= 1
    # and under jit (the scheduler's decode path)
    jitted = np.asarray(jax.jit(engine.sim_linear)(x, w))
    assert np.array_equal(jitted, ref)


# --------------------------------------------------------------------------
# persistence
# --------------------------------------------------------------------------

def test_table_roundtrip_preserves_picks(tmp_path):
    p_raw = autotune.autotune(12, 8, (2, 4), "raw", trials=0)
    p_sim = autotune.autotune(12, 8, (2, 4), "pim_sim", trials=0)
    path = str(tmp_path / "table.json")
    assert autotune.save_table(path) == 2
    engine.clear_cache()
    assert autotune.table_info().size == 0
    assert autotune.load_table(path) == 2
    autotune.enable(True)
    for orig, mode in ((p_raw, "raw"), (p_sim, "pim_sim")):
        got = autotune.lookup(12, 8, shape=(2, 4), pim_mode=mode)
        assert got is not None and got.source == "table"
        assert (got.model, got.n_cols, got.chunk, got.backend) == \
            (orig.model, orig.n_cols, orig.chunk, orig.backend)
    # reloaded picks execute bit-exactly
    x, w = _operands(12)
    plan = autotune.lookup(12, 8, shape=(2, 4), pim_mode="raw")
    assert np.array_equal(engine.matmul_int(x, w, 8, plan=plan),
                          engine.matmul_int(x, w, 8))


def test_table_version_mismatch_raises(tmp_path):
    path = tmp_path / "stale.json"
    path.write_text(json.dumps({"version": 0, "entries": {}}))
    with pytest.raises(ValueError, match="version"):
        autotune.load_table(str(path))


def test_clear_cache_clears_the_tuner_table():
    autotune.autotune(12, 8, (2, 4), "raw", trials=0)
    assert autotune.table_info().size == 1
    engine.clear_cache()
    info = autotune.table_info()
    assert info.size == 0 and info.misses == 0 and info.trials == 0


def test_cache_info_merges_tune_counters():
    info = engine.cache_info()
    assert (info.tune_hits, info.tune_misses, info.tune_trials) == (0, 0, 0)
    autotune.autotune(12, 8, (2, 4), "raw", trials=0)
    autotune.autotune(12, 8, (2, 4), "raw", trials=0)
    info = engine.cache_info()
    assert info.tune_misses == 1 and info.tune_hits == 1


def test_summary_mentions_state_and_a_pick():
    assert autotune.summary().startswith("off, 0 plan(s)")
    autotune.enable(True)
    plan = autotune.autotune(12, 8, (2, 4), "raw", trials=0)
    s = autotune.summary()
    assert s.startswith("on, 1 plan(s)")
    assert plan.model in s and str(plan.n_cols) in s


# --------------------------------------------------------------------------
# warmup + the linear split rule
# --------------------------------------------------------------------------

def test_plan_for_params_walks_stacked_layer_leaves():
    params = {"stacked": np.zeros((3, 6, 8), np.float32),
              "flat": np.zeros((6, 8), np.float32),
              "other": np.zeros((12, 4), np.float32),
              "vec": np.zeros((5,), np.float32)}
    n = autotune.plan_for_params(params, max_batch=2, trials=0)
    assert n == 2   # (6, 8) deduplicates across the 2-D and 3-D leaves
    autotune.enable(True)
    assert autotune.lookup(6, 8, shape=(2, 8), pim_mode="pim_sim") is not None
    assert autotune.lookup(12, 8, shape=(2, 4),
                           pim_mode="pim_sim") is not None


def test_autotune_linear_races_the_int8_lowerings():
    plan = autotune.autotune_linear(4, 8, 8, trials=1)
    assert plan.kind == "linear"
    assert plan.model in ("quant", "quant_tp")
    assert plan.key == "linear:t4d8o8"
    assert plan.trial_us > 0
    # cached on the second ask
    assert autotune.autotune_linear(4, 8, 8) is plan
