"""repro.pim.engine: compile cache, mode selection, backends, jit safety."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.layers import linear, unembed
from repro.pim import engine
from repro.pim.matmul import pim_matmul_int


@pytest.fixture(autouse=True)
def _fresh_cache():
    engine.clear_cache()
    yield
    engine.clear_cache()


# --------------------------------------------------------------------------
# compile cache
# --------------------------------------------------------------------------

def test_compile_cache_hit_returns_same_artifact():
    a1 = engine.compile_dot(3, 8, model="minimal")
    info = engine.cache_info()
    assert (info.builds, info.misses, info.hits) == (1, 1, 0)
    a2 = engine.compile_dot(3, 8, model="minimal")
    assert a2 is a1, "cache hit must return the identical artifact"
    info = engine.cache_info()
    assert info.builds == 1 and info.hits == 1
    # a different key builds again
    a3 = engine.compile_dot(2, 8, model="minimal")
    assert a3 is not a1
    assert engine.cache_info().builds == 2


def test_compile_matmul_shares_dot_cache():
    a1 = engine.compile_dot(2, 8, model="minimal")
    a2 = engine.compile_matmul(2, 8, model="minimal")
    assert a2 is a1
    assert engine.cache_info().builds == 1


def test_pim_matmul_int_builds_exactly_once():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 256, size=(3, 4), dtype=np.uint64)
    w = rng.integers(0, 256, size=(2, 4), dtype=np.uint64)
    y1 = pim_matmul_int(x, w, n_bits=8, model="minimal",
                        rows_per_crossbar=16)
    y2 = pim_matmul_int(x, w, n_bits=8, model="minimal",
                        rows_per_crossbar=16)
    assert engine.cache_info().builds == 1
    want = x.astype(object) @ w.T.astype(object)
    assert np.array_equal(y1.astype(object), want)
    assert np.array_equal(y2.astype(object), want)


# --------------------------------------------------------------------------
# mode selection
# --------------------------------------------------------------------------

def test_mode_default_and_nesting():
    assert engine.current_mode() == "xla"
    with engine.mode("quant"):
        assert engine.current_mode() == "quant"
        with engine.mode("pim_sim"):
            assert engine.current_mode() == "pim_sim"
        assert engine.current_mode() == "quant"
    assert engine.current_mode() == "xla"


def test_mode_restored_on_exception():
    with engine.mode("quant"):
        with pytest.raises(RuntimeError, match="boom"):
            with engine.mode("pim_sim"):
                assert engine.current_mode() == "pim_sim"
                raise RuntimeError("boom")
        assert engine.current_mode() == "quant"
    assert engine.current_mode() == "xla"


def test_mode_rejects_unknown():
    with pytest.raises(ValueError, match="unknown PIM mode"):
        with engine.mode("analog"):
            pass
    assert engine.current_mode() == "xla"
    with pytest.raises(ValueError):
        engine.resolve_mode("analog")


def test_explicit_mode_overrides_ambient():
    x = jnp.ones((2, 4), jnp.float32)
    w = jnp.eye(4, dtype=jnp.float32)
    with engine.mode("pim_sim"):
        # explicit "xla" must NOT route through the simulator: exact einsum
        y = linear(x, w, mode="xla")
    assert np.array_equal(np.asarray(y), np.asarray(x))


# --------------------------------------------------------------------------
# backends
# --------------------------------------------------------------------------

def test_backend_registry_contents_and_unknown():
    names = engine.backends()
    for expected in ("scan", "jnp", "unrolled", "pallas"):
        assert expected in names
    with pytest.raises(ValueError, match="unknown backend"):
        engine.get_backend("does-not-exist")


def test_custom_backend_does_not_suppress_defaults():
    """Registering an extension backend first must still leave the
    built-ins resolvable (the ROADMAP quant_tp extension flow)."""
    engine.register_backend("_test_backend", lambda s, mc, **kw: s)
    try:
        names = engine.backends()
        assert "_test_backend" in names and "scan" in names
        assert engine.get_backend("scan") is not None
    finally:
        engine._backends.pop("_test_backend", None)


def test_backends_agree_on_microcode():
    rng = np.random.default_rng(7)
    state = jnp.asarray(
        rng.integers(0, 2 ** 32, size=(2, 24, 2), dtype=np.uint32))
    g = 40
    mc = np.stack([rng.integers(0, 6, g), rng.integers(0, 24, g),
                   rng.integers(0, 24, g), rng.integers(0, 24, g)],
                  axis=1).astype(np.int32)
    outs = {b: np.asarray(engine.execute_state(jnp.array(state), mc,
                                               backend=b))
            for b in ("scan", "unrolled", "pallas", "numpy")}
    for b in ("unrolled", "pallas", "numpy"):
        assert np.array_equal(outs["scan"], outs[b]), b


def test_execute_pallas_matches_scan_on_artifact():
    rng = np.random.default_rng(3)
    x = rng.integers(0, 256, size=(2, 3), dtype=np.uint64)
    w = rng.integers(0, 256, size=(2, 3), dtype=np.uint64)
    art = engine.compile_dot(3, 8, model="minimal")
    y_scan = engine.execute(art, x, w, backend="scan", rows_per_crossbar=16)
    y_pal = engine.execute(art, x, w, backend="pallas", rows_per_crossbar=16)
    want = x.astype(object) @ w.T.astype(object)
    assert np.array_equal(y_scan.astype(object), want)
    assert np.array_equal(y_pal, y_scan)


def test_execute_rejects_wrong_k():
    art = engine.compile_dot(3, 8, model="minimal")
    x = np.ones((2, 4), np.uint64)
    w = np.ones((2, 4), np.uint64)
    with pytest.raises(ValueError, match="compiled for 3 terms"):
        engine.execute(art, x, w)


# --------------------------------------------------------------------------
# jit composition
# --------------------------------------------------------------------------

def _tiny_operands():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(2, 4)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))
    return x, w


def test_matmul_int_chunks_long_inner_dim():
    """K beyond one row's column budget splits into exact cached chunks."""
    from repro.pim.matmul import max_dot_terms

    chunk = max_dot_terms(8)
    K = chunk + 3
    rng = np.random.default_rng(9)
    x = rng.integers(0, 256, size=(2, K), dtype=np.uint64)
    w = rng.integers(0, 256, size=(2, K), dtype=np.uint64)
    y = engine.matmul_int(x, w, 8, model="minimal", rows_per_crossbar=16)
    want = x.astype(object) @ w.T.astype(object)
    assert np.array_equal(y.astype(object), want)
    assert engine.cache_info().builds == 2  # one per distinct chunk size


def test_pim_sim_is_differentiable():
    """Straight-through VJP: quantized forward, ideal-matmul backward."""
    x, w = _tiny_operands()

    def loss(w_):
        return jnp.sum(engine.sim_linear(x, w_) ** 2)

    val, grad = jax.value_and_grad(loss)(w)
    y = np.asarray(engine.sim_linear(x, w))
    ref = np.asarray(x).T @ (2 * y)   # d/dw sum(y^2) with y treated as x@w
    assert np.isfinite(val)
    np.testing.assert_allclose(np.asarray(grad), ref, rtol=1e-5)
    # and it compiles
    _, g2 = jax.jit(jax.value_and_grad(loss))(w)
    assert np.array_equal(np.asarray(grad), np.asarray(g2))


def test_pim_sim_jit_matches_eager_bit_exactly():
    x, w = _tiny_operands()
    with engine.mode("pim_sim"):
        eager = linear(x, w)
        jitted = jax.jit(lambda a, b: linear(a, b))(x, w)
    assert np.array_equal(np.asarray(eager), np.asarray(jitted))


def test_modes_agree_on_tiny_linear_under_jit():
    x, w = _tiny_operands()
    ref = np.asarray(x) @ np.asarray(w)
    scale = np.abs(ref).max()
    results = {}
    for m in ("xla", "quant", "pim_sim"):
        with engine.mode(m):
            # one jit wrapper per mode: the ambient mode is read at trace
            # time and is not part of jax's jit cache key (see engine docs)
            results[m] = np.asarray(jax.jit(lambda a, b: linear(a, b))(x, w))
    assert np.array_equal(results["xla"], ref)  # einsum is the reference
    for m in ("quant", "pim_sim"):  # fixed-point paths: quantization error
        assert np.abs(results[m] - ref).max() / scale < 0.05, m


def test_config_threading_through_loss(small_model_config):
    """cfg.pim_mode reaches every linear in a jitted loss."""
    from repro.models import model_lib as M

    cfg = small_model_config.scaled(n_layers=1, pattern=("ad",),
                                    loss_chunk=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)),
                              jnp.int32),
    }
    base = float(jax.jit(lambda p, b: M.loss_fn(p, b, cfg))(params, batch))
    qcfg = cfg.scaled(pim_mode="quant")
    quant = float(jax.jit(lambda p, b: M.loss_fn(p, b, qcfg))(params, batch))
    assert np.isfinite(base) and np.isfinite(quant)
    assert abs(quant - base) / abs(base) < 0.25  # int8 path, same model
    assert quant != base  # and it actually took the quantized path


# --------------------------------------------------------------------------
# chunked unembed
# --------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [None, 3, 5, 64])
def test_unembed_chunk_matches_full(chunk):
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(2, 7, 16)).astype(np.float32))
    table = jnp.asarray(rng.normal(size=(13, 16)).astype(np.float32))
    full = unembed(x, table)
    got = unembed(x, table, chunk=chunk)
    assert got.shape == full.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=1e-6, atol=1e-6)


def test_unembed_chunk_under_jit():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    table = jnp.asarray(rng.normal(size=(12, 16)).astype(np.float32))
    got = jax.jit(lambda a, t: unembed(a, t, chunk=5))(x, table)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(unembed(x, table)),
                               rtol=1e-6, atol=1e-6)


def test_loss_path_unembed_chunk_equivalent(small_model_config):
    from repro.models import model_lib as M

    cfg = small_model_config.scaled(n_layers=1, pattern=("ad",),
                                    loss_chunk=8)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(2)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)),
                              jnp.int32),
    }
    base = float(M.loss_fn(params, batch, cfg))
    chunked = float(M.loss_fn(params, batch,
                              cfg.scaled(unembed_chunk=100)))
    np.testing.assert_allclose(chunked, base, rtol=1e-5)
