"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels.crossbar_exec import crossbar_exec, crossbar_exec_ref
from repro.kernels.quant_matmul import (quant_linear, quant_matmul_int,
                                        quant_matmul_int_ref)


@pytest.mark.parametrize("c,n,w,wt", [
    (1, 32, 1, 128), (2, 64, 4, 128), (3, 128, 130, 128), (1, 64, 8, 8),
])
def test_crossbar_kernel_shapes(c, n, w, wt):
    rng = np.random.default_rng(c * 7 + n)
    state = jnp.asarray(rng.integers(0, 2**32, size=(c, n, w), dtype=np.uint32))
    g = 64
    mc = np.stack([rng.integers(0, 6, g), rng.integers(0, n, g),
                   rng.integers(0, n, g), rng.integers(0, n, g)],
                  axis=1).astype(np.int32)
    ref = crossbar_exec_ref(jnp.array(state), jnp.asarray(mc))
    got = crossbar_exec(jnp.array(state), jnp.asarray(mc), w_tile=wt)
    assert np.array_equal(np.asarray(ref), np.asarray(got))


@given(seed=st.integers(0, 10**6), g=st.integers(1, 80))
@settings(max_examples=15, deadline=None)
def test_crossbar_kernel_random_microcode(seed, g):
    rng = np.random.default_rng(seed)
    c, n, w = 2, 48, 3
    state = jnp.asarray(rng.integers(0, 2**32, size=(c, n, w), dtype=np.uint32))
    mc = np.stack([rng.integers(0, 6, g), rng.integers(0, n, g),
                   rng.integers(0, n, g), rng.integers(0, n, g)],
                  axis=1).astype(np.int32)
    ref = crossbar_exec_ref(jnp.array(state), jnp.asarray(mc))
    got = crossbar_exec(jnp.array(state), jnp.asarray(mc), w_tile=128)
    assert np.array_equal(np.asarray(ref), np.asarray(got))


def test_crossbar_kernel_runs_real_program():
    from repro.pim import executor as ex
    from repro.pim.multpim import build_multpim

    pm = build_multpim(8, model="minimal")
    rng = np.random.default_rng(3)
    rows = 64
    a = rng.integers(0, 256, size=(1, rows), dtype=np.uint64)
    b = rng.integers(0, 256, size=(1, rows), dtype=np.uint64)
    state = ex.blank_state(1, 1024, rows)
    state = ex.write_numbers(state, pm.a_cols, a)
    state = ex.write_numbers(state, pm.b_cols, b)
    out = crossbar_exec(jnp.array(state),
                        jnp.asarray(pm.program.to_microcode()))
    got = ex.read_numbers(out, pm.result_cols, rows)
    assert np.array_equal(got, a * b)


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 256, 128, 128, 128, 128),
    (100, 130, 60, 128, 128, 128),   # padding path
    (256, 512, 256, 128, 128, 256),
    (17, 33, 9, 8, 8, 16),
])
def test_quant_matmul_sweep(m, k, n, bm, bn, bk):
    rng = np.random.default_rng(m + k + n)
    x = jnp.asarray(rng.integers(-128, 128, size=(m, k), dtype=np.int8))
    w = jnp.asarray(rng.integers(-128, 128, size=(k, n), dtype=np.int8))
    got = quant_matmul_int(x, w, bm=bm, bn=bn, bk=bk)
    want = quant_matmul_int_ref(x, w)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_quant_linear_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(32, 96)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(96, 48)).astype(np.float32))
    y = quant_linear(x, w)
    ref = np.asarray(x) @ np.asarray(w)
    rel = np.abs(np.asarray(y) - ref).max() / np.abs(ref).max()
    assert rel < 0.05


def test_pim_sim_linear_matches_float():
    """Bit-exact crossbar execution of a linear layer (7-bit fixed point)."""
    from repro.pim.engine import sim_linear

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 6)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(6, 3)).astype(np.float32))
    y = sim_linear(x, w)
    ref = np.asarray(x) @ np.asarray(w)
    rel = np.abs(np.asarray(y) - ref).max() / np.abs(ref).max()
    assert rel < 0.08
