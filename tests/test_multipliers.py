"""The paper's case study: bit-exact multipliers + §5 evaluation properties."""
import numpy as np
import pytest

from repro.pim import executor as ex
from repro.pim.mult_serial import build_serial_multiplier
from repro.pim.multpim import build_multpim

MODELS = ("unlimited", "standard", "minimal")


def _check(mult, rows=64, crossbars=2, seed=0):
    n = mult.n_bits
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 1 << n, size=(crossbars, rows), dtype=np.uint64)
    b = rng.integers(0, 1 << n, size=(crossbars, rows), dtype=np.uint64)
    a[0, :4] = [0, (1 << n) - 1, 1, (1 << n) - 1]
    b[0, :4] = [0, (1 << n) - 1, (1 << n) - 1, 1]
    state = ex.blank_state(crossbars, mult.program.cfg.n, rows)
    state = ex.write_numbers(state, mult.a_cols, a)
    state = ex.write_numbers(state, mult.b_cols, b)
    state = ex.execute(state, mult.program.to_microcode())
    got = ex.read_numbers(state, mult.result_cols, rows)
    assert np.array_equal(got.astype(object), a.astype(object) * b.astype(object))


@pytest.mark.parametrize("n", [8, 16, 32])
def test_serial_multiplier_exact(n):
    m = build_serial_multiplier(n)
    m.program.validate()
    _check(m)


@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("n", [8, 16, 32])
def test_multpim_exact(model, n):
    m = build_multpim(n, model=model)
    m.program.validate()
    _check(m)


def test_paper_speedups_32bit():
    """§5.1: partitions keep ~9x of the serial latency at 32 bits."""
    serial = build_serial_multiplier(32).program.stats().cycles
    cycles = {m: build_multpim(32, model=m).program.stats().cycles
              for m in MODELS}
    for m in MODELS:
        speedup = serial / cycles[m]
        assert 7.0 <= speedup <= 13.0, (m, speedup)
    # restricted models may not beat unlimited
    assert cycles["unlimited"] <= cycles["standard"] <= cycles["minimal"]
    # paper: standard/minimal within ~1.35x of unlimited
    assert cycles["minimal"] / cycles["unlimited"] <= 1.35


def test_paper_control_overheads_32bit():
    """§5.2: per-message control = 607/79/36 vs 30 baseline bits."""
    serial = build_serial_multiplier(32).program.stats()
    assert serial.control_bits_per_message == 30
    want = {"unlimited": 607, "standard": 79, "minimal": 36}
    for m, bits in want.items():
        st = build_multpim(32, model=m).program.stats()
        assert st.control_bits_per_message == bits
    # total control traffic: partitions REDUCE it (fewer messages)
    minimal = build_multpim(32, model="minimal").program.stats()
    assert minimal.total_control_bits < serial.total_control_bits


def test_area_and_energy_overheads():
    """§5.3/§5.4: parallel costs more memristors and more gate switches."""
    s = build_serial_multiplier(32).program.stats()
    p = build_multpim(32, model="minimal").program.stats()
    assert p.area_columns > s.area_columns
    assert p.energy_gates > s.energy_gates
    assert p.area_columns / s.area_columns < 3.5
    assert p.energy_gates / s.energy_gates < 3.5


def test_every_message_of_every_model_roundtrips():
    for m in MODELS:
        build_multpim(16, model=m).program.check_messages(sample_every=3)
    build_serial_multiplier(16).program.check_messages(sample_every=17)


def test_op_class_mix():
    st = build_multpim(32, model="minimal").program.stats()
    assert st.op_class_counts.get("parallel", 0) > 200
    assert st.op_class_counts.get("semi-parallel", 0) > 100
