"""The paper's case study: bit-exact multipliers + §5 evaluation properties."""
import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from repro.pim import executor as ex
from repro.pim.compressor42 import build_compressor42_multiplier
from repro.pim.mult_serial import build_serial_multiplier
from repro.pim.mult_serial_fast import build_fast_serial_multiplier
from repro.pim.multpim import build_multpim

MODELS = ("unlimited", "standard", "minimal")
SERIAL_BUILDERS = {"serial": build_serial_multiplier,
                   "serial_fast": build_fast_serial_multiplier,
                   "compressor42": build_compressor42_multiplier}


def _check(mult, rows=64, crossbars=2, seed=0):
    n = mult.n_bits
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 1 << n, size=(crossbars, rows), dtype=np.uint64)
    b = rng.integers(0, 1 << n, size=(crossbars, rows), dtype=np.uint64)
    a[0, :4] = [0, (1 << n) - 1, 1, (1 << n) - 1]
    b[0, :4] = [0, (1 << n) - 1, (1 << n) - 1, 1]
    state = ex.blank_state(crossbars, mult.program.cfg.n, rows)
    state = ex.write_numbers(state, mult.a_cols, a)
    state = ex.write_numbers(state, mult.b_cols, b)
    state = ex.execute(state, mult.program.to_microcode())
    got = ex.read_numbers(state, mult.result_cols, rows)
    assert np.array_equal(got.astype(object), a.astype(object) * b.astype(object))


@pytest.mark.parametrize("n", [8, 16, 32])
def test_serial_multiplier_exact(n):
    m = build_serial_multiplier(n)
    m.program.validate()
    _check(m)


@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("n", [8, 16, 32])
def test_multpim_exact(model, n):
    m = build_multpim(n, model=model)
    m.program.validate()
    _check(m)


@pytest.mark.parametrize("name", ["serial_fast", "compressor42"])
@pytest.mark.parametrize("n", [2, 3, 4, 5, 8, 16, 32])
def test_new_serial_multipliers_exact(name, n):
    """The two autotune backends, bit-exact incl. tiny and odd widths
    (compressor42's last pass degenerates to one multiplier bit when n is
    odd; serial_fast's first/last iterations special-case n <= 2)."""
    m = SERIAL_BUILDERS[name](n)
    m.program.validate()
    _check(m, rows=32)


@pytest.mark.parametrize("name", ["serial_fast", "compressor42"])
def test_new_serial_multipliers_exhaustive_4bit(name):
    m = SERIAL_BUILDERS[name](4)
    a, b = np.meshgrid(np.arange(16, dtype=np.uint64),
                       np.arange(16, dtype=np.uint64))
    a, b = a.reshape(1, -1), b.reshape(1, -1)
    state = ex.blank_state(1, m.program.cfg.n, a.shape[1])
    state = ex.write_numbers(state, m.a_cols, a)
    state = ex.write_numbers(state, m.b_cols, b)
    state = ex.execute(state, m.program.to_microcode())
    got = ex.read_numbers(state, m.result_cols, a.shape[1])
    assert np.array_equal(got, a * b)


@pytest.mark.slow
@settings(deadline=None, max_examples=20)
@given(a=st.integers(0, 255), b=st.integers(0, 255),
       name=st.sampled_from(["serial_fast", "compressor42"]))
def test_new_serial_multipliers_property_8bit(a, b, name):
    m = SERIAL_BUILDERS[name](8)
    av = np.full((1, 1), a, np.uint64)
    bv = np.full((1, 1), b, np.uint64)
    state = ex.blank_state(1, m.program.cfg.n, 1)
    state = ex.write_numbers(state, m.a_cols, av)
    state = ex.write_numbers(state, m.b_cols, bv)
    state = ex.execute(state, m.program.to_microcode())
    got = ex.read_numbers(state, m.result_cols, 1)
    assert int(got[0, 0]) == a * b


def test_new_serial_multipliers_beat_nor_baseline_cycles():
    """The point of registering them: fewer cycles than the NOR serial
    multiplier at 32 bits (FELIX mixed-gate adders vs 9-gate NOR FAs)."""
    base = build_serial_multiplier(32).program.stats().cycles
    for name in ("serial_fast", "compressor42"):
        c = SERIAL_BUILDERS[name](32).program.stats().cycles
        assert c < base, (name, c, base)


def test_mult_registry_kind_dispatch():
    """PR 5 pattern: kinds partition the registry — state executors and
    multiplier algorithms must reject each other by name."""
    from repro.pim import engine

    names = engine.backends()
    for nm in ("serial", "serial_fast", "compressor42"):
        assert nm in names
        assert engine.backend_kind(nm) == "mult"
    assert engine.backend_kind("scan") == "state"
    built = engine.build_multiplier("serial_fast", 8)
    assert built.n_bits == 8
    with pytest.raises(ValueError, match="not a multiplier algorithm"):
        engine.build_multiplier("scan", 8)
    with pytest.raises(ValueError, match="not a multiplier algorithm"):
        engine.build_multiplier("quant_tp", 8)
    with pytest.raises(ValueError, match="unknown backend"):
        engine.build_multiplier("does-not-exist", 8)
    with pytest.raises(ValueError, match="not a crossbar-state executor"):
        engine.execute_state(None, None, backend="compressor42")


def test_paper_speedups_32bit():
    """§5.1: partitions keep ~9x of the serial latency at 32 bits."""
    serial = build_serial_multiplier(32).program.stats().cycles
    cycles = {m: build_multpim(32, model=m).program.stats().cycles
              for m in MODELS}
    for m in MODELS:
        speedup = serial / cycles[m]
        assert 7.0 <= speedup <= 13.0, (m, speedup)
    # restricted models may not beat unlimited
    assert cycles["unlimited"] <= cycles["standard"] <= cycles["minimal"]
    # paper: standard/minimal within ~1.35x of unlimited
    assert cycles["minimal"] / cycles["unlimited"] <= 1.35


def test_paper_control_overheads_32bit():
    """§5.2: per-message control = 607/79/36 vs 30 baseline bits."""
    serial = build_serial_multiplier(32).program.stats()
    assert serial.control_bits_per_message == 30
    want = {"unlimited": 607, "standard": 79, "minimal": 36}
    for m, bits in want.items():
        st = build_multpim(32, model=m).program.stats()
        assert st.control_bits_per_message == bits
    # total control traffic: partitions REDUCE it (fewer messages)
    minimal = build_multpim(32, model="minimal").program.stats()
    assert minimal.total_control_bits < serial.total_control_bits


def test_area_and_energy_overheads():
    """§5.3/§5.4: parallel costs more memristors and more gate switches."""
    s = build_serial_multiplier(32).program.stats()
    p = build_multpim(32, model="minimal").program.stats()
    assert p.area_columns > s.area_columns
    assert p.energy_gates > s.energy_gates
    assert p.area_columns / s.area_columns < 3.5
    assert p.energy_gates / s.energy_gates < 3.5


def test_every_message_of_every_model_roundtrips():
    for m in MODELS:
        build_multpim(16, model=m).program.check_messages(sample_every=3)
    build_serial_multiplier(16).program.check_messages(sample_every=17)


def test_op_class_mix():
    st = build_multpim(32, model="minimal").program.stats()
    assert st.op_class_counts.get("parallel", 0) > 200
    assert st.op_class_counts.get("semi-parallel", 0) > 100
