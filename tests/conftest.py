import os
import sys

# tests must see the real device count (1 CPU); the 512-device trick is
# exclusively for launch/dryrun.py (see the brief)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
