"""Test-suite bootstrap.

Runs before any test module, and therefore before jax initializes: forces a
deterministic 8-device CPU topology so the ``repro.dist`` mesh paths are
exercised everywhere (a mesh-free run would silently no-op every sharding
constraint).  ``launch/dryrun.py`` detects the override and keeps it instead
of forcing its standalone 512-device topology.
"""
import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.xla_flags import ensure_host_device_count  # noqa: E402

ensure_host_device_count(8)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Property-based tests prefer the real hypothesis; fall back to the bundled
# deterministic shim when it is not installed (see tests/_compat).
if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_compat"))

import pytest  # noqa: E402

# CI's tier-1 matrix pins PIM_TEST_MODE to one engine mode per job
# (.github/workflows/ci.yml) so a backend regression pinpoints its mode;
# locally (unset) the mode-sensitive suites parametrize over every mode.
# Comma lists work too: PIM_TEST_MODE=quant,quant_tp.
_ALL_PIM_MODES = ["xla", "quant", "quant_tp", "pim_sim"]
PIM_TEST_MODES = [m for m in
                  os.environ.get("PIM_TEST_MODE", "").replace(" ", "")
                  .split(",") if m] or _ALL_PIM_MODES


def pytest_generate_tests(metafunc):
    # any test taking a ``pim_test_mode`` argument fans out over the
    # selected engine modes (tests/test_pim_modes.py is the main consumer)
    if "pim_test_mode" in metafunc.fixturenames:
        metafunc.parametrize("pim_test_mode", PIM_TEST_MODES)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running (hypothesis-heavy) tests; deselect with "
        "-m 'not slow'")


@pytest.fixture(scope="session")
def small_model_config():
    """The smallest dense decoder config that exercises the full stack
    (GQA attention, SwiGLU MLP, scan-over-superblocks, tied embeddings)."""
    import repro.configs as configs

    return configs.get("qwen1.5-0.5b").smoke()
