"""repro.serving.router: the multi-replica fleet layer.

The load-bearing assertions: (1) every dispatch policy produces tokens
bit-identical to one Scheduler serving the same trace — routing changes
throughput and placement, never generations; (2) a replica kill
mid-trace loses nothing — its requests drain to the front of the global
queue with their original ``arrival_time`` and a bumped ``n_migrations``,
and the fleet's final outputs still match the single-scheduler oracle;
(3) the respawn path re-derives the mesh over surviving devices
(``ElasticMesh`` shrink under serving) and the health probe
(``StragglerMonitor`` strikes) triggers the same drain/respawn without a
``FailurePlan``.
"""
import jax
import numpy as np
import pytest

from repro.models import model_lib as M
from repro.serving import (AdmissionQueue, FailurePlan, FleetClock, Router,
                           RouterConfig, Scheduler, ServingConfig,
                           make_request, synthetic_requests)

N_REQ = 8
GEN = 8


class FakeClock:
    """Settable clock: router timing becomes exactly computable."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


@pytest.fixture(scope="module")
def cfg(small_model_config):
    return small_model_config


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _trace(cfg):
    """The shared fleet trace; same seed => same prompts across calls
    (fresh Request objects each time — the router mutates replica_id)."""
    return synthetic_requests(N_REQ, vocab_size=cfg.vocab_size,
                              prompt_lens=[5, 7], max_new_tokens=GEN,
                              seed=11)


@pytest.fixture(scope="module")
def oracle(cfg, params):
    """Single-scheduler generations for _trace, by trace index."""
    reqs = _trace(cfg)
    sched = Scheduler(params, cfg, ServingConfig(max_batch=4,
                                                 prompt_bucket=8))
    for r in reqs:
        sched.submit_request(r)
    out = sched.run()
    return [out[r.rid] for r in reqs]


def _scfg(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("prompt_bucket", 8)
    return ServingConfig(**kw)


def _assert_matches_oracle(results, reqs, oracle):
    assert set(results) == {r.rid for r in reqs}
    for i, r in enumerate(reqs):
        assert np.array_equal(results[r.rid], oracle[i]), i


# --------------------------------------------------------------------------
# config + clock + queue plumbing
# --------------------------------------------------------------------------

def test_router_config_validation():
    with pytest.raises(ValueError, match="unknown router policy"):
        RouterConfig(policy="fastest")
    with pytest.raises(ValueError, match="n_replicas"):
        RouterConfig(n_replicas=0)


def test_fleet_clock_rounds_cost_their_slowest_segment():
    wall = FakeClock(0.0)
    fc = FleetClock(wall=wall)
    assert fc() == 0.0
    fc.start_segment()
    wall.t += 2.0
    assert fc() == pytest.approx(2.0)    # in-segment reads stay ordered
    dt1 = fc.end_segment()
    assert dt1 == pytest.approx(2.0)
    assert fc() == 0.0                   # round not over: back to round start
    fc.start_segment()
    wall.t += 5.0
    dt2 = fc.end_segment()
    fc.end_round([dt1, dt2])
    assert fc() == pytest.approx(5.0)    # max, not sum: replicas overlap
    fc.advance_to(9.0)
    assert fc() == 9.0
    fc.advance_to(1.0)
    assert fc() == 9.0                   # idle jumps never rewind


def test_requeue_front_keeps_arrival_and_order():
    q = AdmissionQueue()
    r1 = make_request([1, 2, 3], 4, arrival_time=0.5)
    r2 = make_request([4, 5], 4, arrival_time=0.6)
    q.submit(r1)
    q.submit(r2)
    assert q.pop(now=1.0) is r1
    r1.n_migrations += 1
    q.requeue(r1)
    assert q.peek(now=1.0) is r1         # drained work goes to the front
    assert r1.arrival_time == 0.5        # arrival is never rewritten
    assert r1.n_migrations == 1


# --------------------------------------------------------------------------
# dispatch policies
# --------------------------------------------------------------------------

def test_round_robin_cycles_and_matches_oracle(cfg, params, oracle):
    reqs = _trace(cfg)
    router = Router(params, cfg, _scfg(),
                    RouterConfig(n_replicas=2, policy="round_robin"),
                    devices=jax.devices()[:2])
    for r in reqs:
        router.submit_request(r)
    results = router.run()
    assert [r.replica_id for r in reqs] == [i % 2 for i in range(N_REQ)]
    _assert_matches_oracle(results, reqs, oracle)
    s = router.metrics().summary()
    assert s["router_policy"] == "round_robin"
    assert set(s["per_replica_tok_s"]) == {0, 1}
    assert s["rebalanced_requests"] == 0 and s["replica_restarts"] == 0
    assert s["n_finished"] == N_REQ


def test_least_loaded_prefers_emptier_replica(cfg, params):
    router = Router(params, cfg, _scfg(),
                    RouterConfig(n_replicas=2, policy="least_loaded"),
                    devices=jax.devices()[:2], clock=FakeClock(1.0))
    # pre-load replica 0 behind the router's back
    router.replicas[0].sched.submit([9, 9, 9], 2)
    a = make_request([1, 2, 3], 2)
    b = make_request([4, 5, 6], 2)
    router.submit_request(a)
    router.submit_request(b)
    router._dispatch()
    assert a.replica_id == 1             # 0 queued+active vs replica 0's 1
    assert b.replica_id == 0             # now tied 1-1; lowest rid wins


def test_prefix_affinity_pins_tenants_to_replicas(cfg, params):
    scfg = _scfg(paged=True, block_size=8)
    router = Router(params, cfg, scfg,
                    RouterConfig(n_replicas=2, policy="prefix_affinity"),
                    devices=jax.devices()[:2], clock=FakeClock(1.0))
    # two tenants, each with its own 8-token shared system prompt — one
    # full block_size run, the affinity key
    reqs = synthetic_requests(6, vocab_size=cfg.vocab_size, prompt_lens=[4],
                              max_new_tokens=2, seed=5,
                              shared_prefix_len=8, n_tenants=2)
    for r in reqs:
        router.submit_request(r)
    router._dispatch()
    by_tenant = {0: {r.replica_id for r in reqs[0::2]},
                 1: {r.replica_id for r in reqs[1::2]}}
    assert len(by_tenant[0]) == 1, "tenant 0 smeared across replicas"
    assert len(by_tenant[1]) == 1, "tenant 1 smeared across replicas"
    # least-loaded fallback on first sight puts the tenants on different
    # replicas, and the mapping is remembered
    assert by_tenant[0] != by_tenant[1]
    assert len(router._affinity) == 2


def test_prefix_affinity_beats_round_robin_hit_rate(cfg, params):
    """Acceptance: on a multi-tenant shared-system-prompt trace, pinning
    tenants to replicas keeps each tenant's blocks in one trie — only
    the first request per tenant misses — while round_robin smears every
    tenant across both tries and re-misses per (tenant, replica) pair."""
    scfg = _scfg(paged=True, block_size=8, prefix_cache=True)
    rates = {}
    for policy in ("round_robin", "prefix_affinity"):
        router = Router(params, cfg, scfg,
                        RouterConfig(n_replicas=2, policy=policy),
                        devices=jax.devices()[:2], clock=FakeClock(1.0))
        # 3 tenants over 2 replicas: coprime, so round_robin's i % 2
        # cursor cannot accidentally reproduce the tenant pinning
        reqs = synthetic_requests(12, vocab_size=cfg.vocab_size,
                                  prompt_lens=[4], max_new_tokens=3, seed=9,
                                  shared_prefix_len=16, n_tenants=3)
        for r in reqs:
            router.submit_request(r)
        results = router.run()
        assert len(results) == 12
        rates[policy] = router.metrics().summary()["prefix_hit_rate"]
    assert rates["prefix_affinity"] > rates["round_robin"], rates


# --------------------------------------------------------------------------
# fault path: kill, drain, requeue, respawn
# --------------------------------------------------------------------------

def test_kill_mid_trace_is_bit_exact_and_keeps_arrivals(cfg, params, oracle):
    reqs = _trace(cfg)
    for i, r in enumerate(reqs):
        r.arrival_time = 0.1 * i         # distinct, all arrived at t=100
    arrivals = {r.rid: r.arrival_time for r in reqs}
    router = Router(params, cfg, _scfg(),
                    RouterConfig(n_replicas=2, policy="round_robin"),
                    devices=jax.devices()[:2], clock=FakeClock(100.0),
                    failure_plan=FailurePlan(kill_replica=0, at_step=3))
    for r in reqs:
        router.submit_request(r)
    results = router.run()
    _assert_matches_oracle(results, reqs, oracle)
    assert router.replica_restarts == 1
    assert router.rebalanced_requests > 0
    migrated = [r for r in reqs if r.n_migrations > 0]
    assert len(migrated) == router.rebalanced_requests
    for r in reqs:                       # drains never launder latency
        assert r.arrival_time == arrivals[r.rid]
    m = router.metrics().summary()
    assert m["n_finished"] == N_REQ
    assert m["rebalanced_requests"] == router.rebalanced_requests


def test_kill_without_respawn_retires_replica(cfg, params, oracle):
    reqs = _trace(cfg)
    router = Router(params, cfg, _scfg(),
                    RouterConfig(n_replicas=2, policy="least_loaded"),
                    devices=jax.devices()[:2], clock=FakeClock(1.0),
                    failure_plan=FailurePlan(kill_replica=0, at_step=2,
                                             respawn=False))
    for r in reqs:
        router.submit_request(r)
    results = router.run()
    _assert_matches_oracle(results, reqs, oracle)
    assert not router.replicas[0].alive
    assert router.replica_restarts == 0
    assert router.rebalanced_requests > 0
    # the lone survivor served every migrated request
    assert {r.replica_id for r in reqs if r.n_migrations > 0} == {1}


def test_all_replicas_dead_raises(cfg, params):
    router = Router(params, cfg, _scfg(),
                    RouterConfig(n_replicas=1),
                    devices=jax.devices()[:1], clock=FakeClock(1.0),
                    failure_plan=FailurePlan(kill_replica=0, at_step=0,
                                             respawn=False))
    router.submit([1, 2, 3], 2)
    with pytest.raises(RuntimeError, match="all replicas dead"):
        router.run()


def test_elastic_mesh_shrinks_on_device_loss(cfg, params, oracle):
    """Respawn under device loss: the replica's ElasticMesh re-derives
    over the survivors mid-serve and the trace still completes exactly."""
    reqs = _trace(cfg)
    router = Router(params, cfg, _scfg(),
                    RouterConfig(n_replicas=2, policy="round_robin"),
                    devices=jax.devices()[:4], clock=FakeClock(1.0),
                    failure_plan=FailurePlan(kill_replica=0, at_step=2,
                                             lose_devices=1))
    assert router.replicas[0].mesh.devices.size == 2
    for r in reqs:
        router.submit_request(r)
    results = router.run()
    _assert_matches_oracle(results, reqs, oracle)
    rep = router.replicas[0]
    assert rep.alive and router.replica_restarts == 1
    assert rep.mesh.devices.size == 1    # shrank to the surviving device
    assert len(rep.devices) == 1


def test_straggler_strikes_kill_and_respawn(cfg, params, oracle):
    """Health transition without a FailurePlan: a replica whose step
    times spike past the EWMA band accumulates consecutive strikes, gets
    drained + respawned, then serves healthily (monitor reset)."""
    reqs = _trace(cfg)
    clk = FakeClock(1.0)
    router = Router(params, cfg, _scfg(),
                    RouterConfig(n_replicas=2, policy="round_robin",
                                 health_check=True, straggler_patience=3,
                                 straggler_threshold=3.0,
                                 straggler_alpha=0.1),
                    devices=jax.devices()[:2], clock=clk)
    rep = router.replicas[1]
    orig_step = rep.step
    # 4 healthy rounds seed the EWMA (the monitor needs >3 samples), then
    # 3 spiked rounds = 3 consecutive strikes = patience; afterwards the
    # respawned replica steps instantly again
    dts = iter([0.01] * 4 + [5.0] * 3)
    rep.step = lambda: (orig_step(), setattr(
        clk, "t", clk.t + next(dts, 0.0)))[0]
    for r in reqs:
        router.submit_request(r)
    results = router.run()
    _assert_matches_oracle(results, reqs, oracle)
    assert router.replica_restarts == 1
    assert router.rebalanced_requests > 0
    assert rep.alive and rep.strikes == 0


# --------------------------------------------------------------------------
# queue policy: sjf vs fifo
# --------------------------------------------------------------------------

def _bimodal(cfg):
    """One long job submitted first, then short ones — FIFO's worst case."""
    rng = np.random.default_rng(7)
    reqs = [make_request(rng.integers(0, cfg.vocab_size, 16), 12)]
    reqs += [make_request(rng.integers(0, cfg.vocab_size, 4), 2)
             for _ in range(4)]
    return reqs


@pytest.mark.parametrize("policy", ["fifo", "sjf"])
def test_queue_policy_accepted_by_scheduler(cfg, params, policy):
    sched = Scheduler(params, cfg, _scfg(max_batch=1, queue_policy=policy))
    assert sched.queue.policy == policy


def test_sjf_beats_fifo_p50_queue_wait_on_bimodal_trace(cfg, params):
    """Satellite acceptance: with a bimodal job mix (one long job ahead
    of many short ones), shortest-prompt-first admission cuts the median
    queue wait vs FIFO — the long job no longer convoys the shorts."""
    p50 = {}
    for policy in ("fifo", "sjf"):
        clk = FakeClock(0.0)
        sched = Scheduler(params, cfg,
                          _scfg(max_batch=1, queue_policy=policy),
                          clock=clk)
        for r in _bimodal(cfg):
            sched.submit_request(r)
        while len(sched.queue) or sched.n_active:
            sched.step()
            clk.t += 1.0                 # one time unit per step
        p50[policy] = sched.metrics.summary()["p50_queue_wait_s"]
    assert p50["sjf"] < p50["fifo"], p50
