"""Prefix caching with copy-on-write block sharing, plus the serving-path
bugfix sweep that rode along.

Tentpole coverage: trie-hit admits must be *bit-identical* to cold
prefill (through the paged pool with and without the index, against the
contiguous pool, under the 8-device mesh, and per PIM engine mode);
refcount invariants must hold under seeded Poisson churn (no block freed
while referenced, no leak at drain); COW must fire — and preserve other
referents' bits — on fork divergent tails and on windowed ring wraps.

Satellite regressions: a request finishing at admit must not consume its
free-slot iteration; a deferred rid must reset on successful admit (now
the ``_deferred_rids`` set — see test_serving_chunked for the SJF
head-churn case);
``stats()`` must report logical ``tokens_reserved`` and physical
``tokens_in_use`` separately, with aligned keys across both pools.
"""
import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.dist import context as dctx
from repro.launch.mesh import make_mesh
from repro.models import model_lib as M
from repro.serving import (PagedCachePool, Scheduler, ServingConfig,
                           make_request, synthetic_requests)


def _smoke():
    return C.get("qwen1.5-0.5b").smoke()


def _tiny(mode):
    return C.get("qwen1.5-0.5b").smoke().scaled(
        n_layers=1, pattern=("ad",), d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=64, pad_vocab_multiple=8,
        loss_chunk=8, max_seq_len=16, pim_mode=mode)


def _mesh_ctx(mode):
    if mode != "quant_tp":
        return contextlib.nullcontext()
    return dctx.use_mesh(make_mesh((8,), ("model",)))


def _shared_trace(cfg, *, shared_len, tails, budget, seed=0):
    """Requests sharing one system prompt, with divergent random tails."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, cfg.vocab_size, shared_len)
    return [make_request(
        np.concatenate([shared, rng.integers(1, cfg.vocab_size, t)]), budget)
        for t in tails]


def _run(params, cfg, scfg, reqs, *, mesh=None):
    sched = Scheduler(params, cfg, scfg, mesh=mesh)
    rids = [sched.submit_request(make_request(r.prompt, r.max_new_tokens))
            for r in reqs]
    out = sched.run()
    return sched, [out[rid] for rid in rids]


def _check_refcounts(pool):
    """The allocator's ground-truth invariant: _ref equals the reference
    multiset (slot block lists + trie entries), the free list holds
    exactly the unreferenced non-sentinel blocks, once each."""
    refs = np.zeros(pool.num_blocks, np.int64)
    for bl in pool._slot_blocks:
        for b in bl:
            refs[b] += 1
    trie = pool.prefix.blocks() if pool.prefix is not None else []
    for b in trie:
        refs[b] += 1
    assert (refs == pool._ref).all(), (refs.tolist(), pool._ref.tolist())
    free = set(pool._free)
    assert len(free) == len(pool._free), "free list holds duplicates"
    assert 0 not in free, "sentinel block leaked into the free list"
    used = {b for bl in pool._slot_blocks for b in bl} | set(trie)
    assert not free & used, "block simultaneously free and referenced"
    assert len(free) + len(used) == pool.num_blocks - 1, "block leak"


# ---------------------------------------------------------------------------
# tentpole: bit-exactness of trie-hit admits
# ---------------------------------------------------------------------------

def test_prefix_admits_bit_exact_across_pools():
    """Warm (trie-hit) generations must match cold paged and contiguous
    pool generations token for token, under the 8-device mesh."""
    cfg = _smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_mesh((8,), ("model",))
    reqs = _shared_trace(cfg, shared_len=32, tails=(5, 7, 9, 6), budget=6)
    base = dict(max_batch=2, prompt_bucket=8)
    _, contiguous = _run(params, cfg, ServingConfig(**base), reqs, mesh=mesh)
    _, cold = _run(params, cfg, ServingConfig(paged=True, **base), reqs,
                   mesh=mesh)
    warm_sched, warm = _run(params, cfg,
                            ServingConfig(prefix_cache=True, **base), reqs,
                            mesh=mesh)
    for a, b, c in zip(contiguous, cold, warm):
        assert (a == b).all()
        assert (b == c).all()
    assert warm_sched.decode_traces == 1
    s = warm_sched.metrics.summary()
    assert s["prefix_hit_rate"] == pytest.approx(3 / 4)  # first admit is cold
    assert s["prefix_tokens_reused"] == 3 * 32
    _check_refcounts(warm_sched.pool)


def test_prefix_bit_exact_per_pim_mode(pim_test_mode):
    """The trie-hit path must stay bit-identical to cold prefill under
    every engine lowering (CI's PIM_TEST_MODE matrix owns this)."""
    cfg = _tiny(pim_test_mode)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    reqs = _shared_trace(cfg, shared_len=8, tails=(3, 2, 4), budget=4,
                         seed=2)
    base = dict(max_batch=2, prompt_bucket=4, block_size=4)
    with _mesh_ctx(pim_test_mode):
        _, cold = _run(params, cfg, ServingConfig(paged=True, **base), reqs)
        sched, warm = _run(params, cfg,
                           ServingConfig(prefix_cache=True, **base), reqs)
    for a, b in zip(cold, warm):
        assert (a == b).all(), f"prefix-cache divergence under {pim_test_mode}"
    assert sched.decode_traces == 1
    assert sched.metrics.summary()["prefix_tokens_reused"] == 2 * 8


def test_windowed_ring_wrap_cow_bit_exact():
    """A windowed slot whose ring wraps onto mapped prefix blocks must COW
    them — generations stay identical to the no-prefix-cache run and the
    trie's copy of the prefix survives for later hits."""
    cfg = _smoke().scaled(sliding_window=8, max_seq_len=64)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    # plen 7 <= window 8 (so the prompt matches the trie), budget 12 wraps
    # the 8-token ring
    reqs = _shared_trace(cfg, shared_len=4, tails=(3, 3, 3), budget=12,
                         seed=3)
    base = dict(max_batch=2, prompt_bucket=4, block_size=4)
    _, cold = _run(params, cfg, ServingConfig(**base), reqs)
    sched, warm = _run(params, cfg, ServingConfig(prefix_cache=True, **base),
                       reqs)
    for a, b in zip(cold, warm):
        assert (a == b).all()
    assert sched.pool.cow_copies > 0, "ring wrap never triggered COW"
    assert sched.decode_traces == 1
    _check_refcounts(sched.pool)


# ---------------------------------------------------------------------------
# tentpole: fork (parallel sampling) + COW on the divergent tail
# ---------------------------------------------------------------------------

def test_fork_cow_divergent_tail():
    """fork() shares content blocks by reference; the boundary block COWs
    on the sibling's first divergent write, and the sibling's generation
    matches a fully private continuation bit for bit."""
    cfg = _smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(4)
    prompt = rng.integers(1, cfg.vocab_size, 10)  # boundary mid-block (bs 4)
    prefill = jax.jit(lambda p, t, li: M.prefill(p, {"tokens": t}, cfg,
                                                 last_index=li))
    toks = np.zeros((1, 16), np.int32)
    toks[0, :10] = prompt
    logits, cache = prefill(params, jnp.asarray(toks),
                            jnp.asarray([9], np.int32))
    first = int(np.asarray(jnp.argmax(logits, -1))[0])
    div = (first + 1) % cfg.vocab_size  # forced divergent second branch

    dec = jax.jit(lambda p, t, pos, act, c, bt: M.decode_step_slots(
        p, t, pos, act, c, cfg, block_tables=bt))

    def decode(pool, n_slots, firsts, steps=6):
        tokens = np.zeros((n_slots, 1), np.int32)
        pos = np.zeros(n_slots, np.int32)
        act = np.zeros(n_slots, bool)
        outs = [[] for _ in range(len(firsts))]
        for s, f in enumerate(firsts):
            tokens[s, 0] = f
            pos[s] = 10
            act[s] = True
        for _ in range(steps):
            for s in range(len(firsts)):
                pool.ensure_writable(s, int(pos[s]))
            nt, _, nc = dec(params, jnp.asarray(tokens), jnp.asarray(pos),
                            jnp.asarray(act), pool.caches, pool.block_tables)
            pool.caches = nc
            t = np.asarray(nt)
            for s in range(len(firsts)):
                outs[s].append(int(t[s, 0]))
                tokens[s, 0] = t[s, 0]
            pos += act
        return outs

    pool = PagedCachePool(cfg, 2, cfg.max_seq_len, block_size=4,
                          prefix_cache=True)
    pool.admit(0, cache, 10, 16, prompt=prompt)
    pool.fork(0, 1, 10, 16)
    assert pool.has_shared
    a, b = decode(pool, 2, [first, div])
    assert a != b, "forced divergent branches converged"
    assert pool.cow_copies == 1, "boundary block must COW exactly once"

    # reference: the divergent branch on a private, freshly admitted slot
    ref_pool = PagedCachePool(cfg, 1, cfg.max_seq_len, block_size=4)
    ref_pool.admit(0, cache, 10, 16)
    (ref,) = decode(ref_pool, 1, [div])
    assert ref == b, "fork sibling diverged from private continuation"

    pool.evict(0)
    pool.evict(1)
    _check_refcounts(pool)
    # drained: only the trie holds blocks
    assert pool.blocks_in_use == pool.prefix.n_blocks
    pool.clear_prefix()
    assert pool.blocks_in_use == 0


# ---------------------------------------------------------------------------
# tentpole: refcount invariants under churn
# ---------------------------------------------------------------------------

def test_refcount_invariants_under_poisson_churn():
    """Seeded Poisson trace through a deliberately undersized pool:
    admissions defer, the trie reclaims under pressure, rings of varying
    budgets churn blocks — after every scheduler step the refcount
    ground truth must hold, and the drain must not leak a block."""
    cfg = _smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(4))
    rng = np.random.default_rng(5)
    shared = rng.integers(1, cfg.vocab_size, 16)
    arrivals = np.cumsum(rng.exponential(0.5, size=14))
    reqs = [make_request(
        np.concatenate([shared, rng.integers(1, cfg.vocab_size,
                                             int(rng.integers(2, 9)))]),
        int(rng.integers(1, 7)), arrival_time=float(t)) for t in arrivals]

    now = [0.0]
    sched = Scheduler(params, cfg,
                      ServingConfig(max_batch=3, prompt_bucket=8,
                                    block_size=4, prefix_cache=True,
                                    num_blocks=24),
                      clock=lambda: now[0])
    for r in reqs:
        sched.submit_request(r)
    for _ in range(400):
        sched.step()
        _check_refcounts(sched.pool)
        now[0] += 0.5
        if not len(sched.queue) and not sched.active_slots.any():
            break
    assert not len(sched.queue) and not sched.active_slots.any(), \
        "trace failed to drain"
    # no leak at drain: everything still allocated is owned by the trie
    assert sched.pool.blocks_in_use == sched.pool.prefix.n_blocks
    sched.pool.clear_prefix()
    assert sched.pool.blocks_in_use == 0
    assert len(sched.pool._free) == sched.pool.num_blocks - 1


# ---------------------------------------------------------------------------
# satellites: admit-loop and deferral bookkeeping regressions
# ---------------------------------------------------------------------------

def test_finished_at_admit_retries_same_slot():
    """A burst of one-token requests must drain in a single scheduler
    step: each finishes at admit without occupying its slot, so the slot
    is retried with the next queued request."""
    cfg = _smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    sched = Scheduler(params, cfg, ServingConfig(max_batch=2,
                                                 prompt_bucket=4))
    rng = np.random.default_rng(6)
    one_shot = [sched.submit(rng.integers(1, cfg.vocab_size, 3), 1)
                for _ in range(4)]
    long_rid = sched.submit(rng.integers(1, cfg.vocab_size, 3), 5)
    emitted = sched.step()
    # all four one-token requests AND the long request admitted in step 1
    assert len(sched.queue) == 0
    assert {rid for rid, _ in emitted} == set(one_shot) | {long_rid}
    assert sched.n_active == 1  # only the long request holds a slot
    out = sched.run()
    for rid in one_shot:
        assert out[rid].shape == (1,)
    assert out[long_rid].shape == (5,)


def test_deferred_rid_resets_after_admit():
    """deferred -> admitted -> deferred-again must count two deferral
    events, even when the later request reuses the earlier rid (the
    pre-fix dedupe never reset ``_deferred_rid`` after the head got in,
    silently swallowing the second event)."""
    cfg = _smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(6))
    rng = np.random.default_rng(7)
    # each request: plen 4 + budget 8 = 12 tokens = 3 blocks of 4; pool
    # holds 3 usable blocks, so exactly one request fits at a time
    mk = lambda rid: make_request(rng.integers(1, cfg.vocab_size, 4), 8,
                                  rid=rid)
    sched = Scheduler(params, cfg,
                      ServingConfig(max_batch=2, prompt_bucket=4,
                                    paged=True, block_size=4, num_blocks=4),
                      clock=lambda: 0.0)
    sched.submit_request(mk(rid=9001))
    sched.step()
    assert sched.n_active == 1
    sched.submit_request(mk(rid=777))
    sched.step()
    assert sched.metrics.deferred_admits == 1     # 777 deferred behind 9001
    for _ in range(20):
        sched.step()
        if not len(sched.queue) and not sched.active_slots.any():
            break
    assert sched.metrics.deferred_admits == 1     # dedupe: one event per wait
    sched.submit_request(mk(rid=9002))
    sched.step()
    assert sched.n_active == 1                    # 9002 admitted
    sched.submit_request(mk(rid=777))             # rid reuse: worst case
    sched.step()
    assert sched.metrics.deferred_admits == 2, \
        "_deferred_rid not reset on successful admit"


# ---------------------------------------------------------------------------
# satellites: stats keys and validation gates
# ---------------------------------------------------------------------------

def test_stats_reserved_vs_in_use_and_key_alignment():
    """tokens_reserved (logical, per referencing slot) and tokens_in_use
    (physical, each block once) must diverge exactly by the shared
    blocks; both pools must emit the shared key set."""
    cfg = _smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(7))
    prefill = jax.jit(lambda p, t, li: M.prefill(p, {"tokens": t}, cfg,
                                                 last_index=li))
    rng = np.random.default_rng(8)
    prompt = rng.integers(1, cfg.vocab_size, 8)
    toks = np.zeros((1, 8), np.int32)
    toks[0, :] = prompt
    _, cache = prefill(params, jnp.asarray(toks), jnp.asarray([7], np.int32))

    pool = PagedCachePool(cfg, 2, cfg.max_seq_len, block_size=4,
                          prefix_cache=True)
    pool.admit(0, cache, 8, 12, prompt=prompt)     # 3 blocks
    st = pool.stats()
    assert st["tokens_reserved"] == 3 * 4
    assert st["tokens_in_use"] == 3 * 4            # nothing shared yet

    pool.fork(0, 1, 8, 12)                         # shares 2 content blocks
    st = pool.stats()
    assert st["tokens_reserved"] == 6 * 4          # both slots' reservations
    assert st["tokens_in_use"] == 4 * 4            # 3 + 1 fresh, shared once
    assert st["blocks_shared"] == 2.0
    assert st["prefix_blocks"] == 2.0              # plen 8 registered fully

    from repro.serving import CachePool

    flat = CachePool(cfg, 2, cfg.max_seq_len)
    core = {"kv_bytes_in_use", "kv_bytes_reserved", "blocks_in_use",
            "blocks_total", "tokens_reserved", "tokens_in_use"}
    assert core <= set(flat.stats())
    assert core <= set(st)
    assert flat.stats()["tokens_in_use"] == 0.0
    flat.admit(0, cache, 8, 12)
    assert flat.stats()["tokens_in_use"] == float(cfg.max_seq_len)
    assert flat.stats()["tokens_reserved"] == float(2 * cfg.max_seq_len)


def test_prefix_cache_rejects_non_separable_stacks():
    """Recurrent state and MoE capacity dropping make KV depend on more
    than the prefix — the scheduler must refuse rather than silently
    serve wrong bits."""
    moe = _smoke().scaled(pattern=("ae",), n_layers=2, n_experts=4)
    with pytest.raises(ValueError, match="MoE"):
        Scheduler(None, moe, ServingConfig(prefix_cache=True))
    rec = _smoke().scaled(pattern=("md",), n_layers=2)
    with pytest.raises(ValueError, match="prefix_cache"):
        Scheduler(None, rec, ServingConfig(prefix_cache=True,
                                           prompt_bucket=1))


def test_shared_prefix_synthetic_trace():
    """synthetic_requests(shared_prefix_len=N) prepends one identical
    N-token run to every prompt, reproducibly across calls with the same
    seed (warm-up and measured benchmark traces must share it)."""
    a = synthetic_requests(4, vocab_size=97, prompt_lens=[3, 5],
                          shared_prefix_len=8, seed=11)
    b = synthetic_requests(2, vocab_size=97, prompt_lens=[4],
                          shared_prefix_len=8, seed=11)
    head = a[0].prompt[:8]
    for r in a + b:
        assert (r.prompt[:8] == head).all()
    assert a[0].prompt.shape == (11,)
    assert a[1].prompt.shape == (13,)
    plain = synthetic_requests(2, vocab_size=97, prompt_lens=[4], seed=11)
    assert plain[0].prompt.shape == (4,)
