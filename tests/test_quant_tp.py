"""Tensor-parallel ``quant_tp`` execution mode.

The load-bearing claims, mirrored from the kernel docstring:

1. both shard_map splits (column- and row-parallel) and the non-divisible
   padding path reproduce the single-rank "quant" result bit-for-bit at
   the int8/int32 level (jit-vs-jit identical; eager references differ
   only by fusion-order ulps in the final float rescale);
2. the mode threads end to end — prefill, scalar decode, and the serving
   runtime's slot decode through contiguous *and* block-paged pools —
   without retracing, and greedy tokens match the single-rank path
   exactly;
3. the straight-through ``custom_vjp`` makes it train under shard_map;
4. dispatch goes through the one engine registry ("quant_tp" backend +
   MODES entry), and the mode degrades to "quant" outside a mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import context as dctx
from repro.dist import partitioning as dpart
from repro.kernels.quant_matmul import (quant_linear, tp_quant_linear,
                                        tp_split, tp_tile_shape)
from repro.launch.mesh import make_host_mesh, make_mesh
from repro.models import model_lib as M
from repro.models.layers import linear
from repro.pim import engine
from repro.serving import Scheduler, ServingConfig


@pytest.fixture(scope="module")
def tp8():
    return make_mesh((8,), ("model",))


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# --------------------------------------------------------------------------
# kernel: split selection + bit-exactness vs single-rank quant
# --------------------------------------------------------------------------

def test_tp_split_matches_param_placement():
    """The tile split must follow the dim param_pspecs shards, so weight
    shards are local to their rank's tile."""
    assert dpart.tp_shard_dim((64, 128), 8) == 1
    assert dpart.tp_shard_dim((128, 64), 8) == 0
    assert dpart.tp_shard_dim((64, 64), 8) == 1      # tie -> later (col)
    assert dpart.tp_shard_dim((60, 52), 8) == -1
    assert tp_split((64, 128), 8) == "col"
    assert tp_split((128, 64), 8) == "row"
    assert tp_split((60, 52), 8) == "col"            # pad fallback
    assert tp_tile_shape((64, 128), 8) == (64, 16)
    assert tp_tile_shape((128, 64), 8) == (16, 64)
    assert tp_tile_shape((60, 52), 8) == (60, 7)     # 52 -> 56 padded / 8


@pytest.mark.parametrize("m,k,n", [
    (4, 64, 128),    # column-parallel
    (4, 128, 64),    # row-parallel (psum over int32 partials)
    (5, 60, 52),     # neither dim divides: zero-pad N, slice back
    (3, 33, 56),     # K odd, N divides
    (2, 8, 8),       # single-block tiles
])
def test_kernel_bit_exact_vs_single_rank(tp8, m, k, n):
    rng = np.random.default_rng(m * 100 + n)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    ref = np.asarray(jax.jit(lambda a, b: quant_linear(a, b))(x, w))
    with dctx.use_mesh(tp8):
        got = np.asarray(jax.jit(lambda a, b: tp_quant_linear(a, b))(x, w))
    np.testing.assert_array_equal(got, ref)


def test_kernel_leading_batch_dims(tp8):
    rng = np.random.default_rng(0)
    x, w = _rand(rng, 2, 3, 64), _rand(rng, 64, 32)
    ref = np.asarray(jax.jit(lambda a, b: quant_linear(a, b))(x, w))
    with dctx.use_mesh(tp8):
        got = np.asarray(jax.jit(lambda a, b: tp_quant_linear(a, b))(x, w))
    assert got.shape == (2, 3, 32)
    np.testing.assert_array_equal(got, ref)


def test_without_mesh_degrades_to_quant_exactly():
    rng = np.random.default_rng(1)
    x, w = _rand(rng, 4, 16), _rand(rng, 16, 24)
    np.testing.assert_array_equal(np.asarray(tp_quant_linear(x, w)),
                                  np.asarray(quant_linear(x, w)))


def test_data_model_mesh(tp8):
    """On a (data, model) mesh the tile shards only over "model"."""
    mesh = make_host_mesh(model=2)
    rng = np.random.default_rng(2)
    x, w = _rand(rng, 8, 64), _rand(rng, 64, 32)
    ref = np.asarray(jax.jit(lambda a, b: quant_linear(a, b))(x, w))
    with dctx.use_mesh(mesh):
        got = np.asarray(jax.jit(lambda a, b: tp_quant_linear(a, b))(x, w))
    np.testing.assert_array_equal(got, ref)


# --------------------------------------------------------------------------
# engine registry dispatch
# --------------------------------------------------------------------------

def test_backend_kinds_guard_execute_state():
    """quant_tp is a linear-kind backend: the state-executor entry point
    must reject it loudly instead of feeding microcode to a GEMM."""
    assert engine.backend_kind("quant_tp") == "linear"
    assert engine.backend_kind("scan") == "state"
    with pytest.raises(ValueError, match="linear lowering"):
        engine.execute_state(np.zeros((1, 8, 1), np.uint32),
                             np.zeros((2, 4), np.int32), backend="quant_tp")
    with pytest.raises(ValueError, match="unknown backend"):
        engine.backend_kind("does-not-exist")


def test_engine_registry_dispatch(tp8):
    assert "quant_tp" in engine.MODES
    assert "quant_tp" in engine.backends()
    fn = engine.get_backend("quant_tp")
    rng = np.random.default_rng(3)
    x, w = _rand(rng, 4, 64), _rand(rng, 64, 32)
    ref = np.asarray(jax.jit(lambda a, b: quant_linear(a, b))(x, w))
    with dctx.use_mesh(tp8):
        # the registry entry, the layers.linear mode dispatch, and the
        # ambient-mode context all land on the same tile
        got_reg = np.asarray(jax.jit(fn)(x, w))
        got_lin = np.asarray(jax.jit(
            lambda a, b: linear(a, b, mode="quant_tp"))(x, w))
        with engine.mode("quant_tp"):
            got_amb = np.asarray(jax.jit(lambda a, b: linear(a, b))(x, w))
    np.testing.assert_array_equal(got_reg, ref)
    np.testing.assert_array_equal(got_lin, ref)
    np.testing.assert_array_equal(got_amb, ref)


# --------------------------------------------------------------------------
# grads: straight-through estimator under shard_map
# --------------------------------------------------------------------------

def test_grad_straight_through_under_shard_map(tp8):
    rng = np.random.default_rng(4)
    x, w = _rand(rng, 4, 16), _rand(rng, 16, 24)

    def loss(w_):
        return jnp.sum(tp_quant_linear(x, w_) ** 2)

    with dctx.use_mesh(tp8):
        val, grad = jax.jit(jax.value_and_grad(loss))(w)
        y = np.asarray(jax.jit(lambda a, b: tp_quant_linear(a, b))(x, w))
    # d/dw sum(y^2) with the quantized forward treated as x @ w
    ref = np.asarray(x).T @ (2 * y)
    assert np.isfinite(float(val))
    np.testing.assert_allclose(np.asarray(grad), ref, rtol=1e-5)


def test_trains_through_loss_fn(tp8, small_model_config):
    """cfg.pim_mode="quant_tp" reaches a jitted value_and_grad loss."""
    cfg = small_model_config.scaled(n_layers=1, pattern=("ad",),
                                    loss_chunk=8, pim_mode="quant_tp")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)),
                              jnp.int32),
    }
    with dctx.use_mesh(tp8):
        loss, grads = jax.jit(jax.value_and_grad(
            lambda p: M.loss_fn(p, batch, cfg)))(params)
    assert np.isfinite(float(loss))
    gnorm = float(jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                               for g in jax.tree.leaves(grads))))
    assert np.isfinite(gnorm) and gnorm > 0


# --------------------------------------------------------------------------
# model threading: prefill + decode vs single-rank quant
# --------------------------------------------------------------------------

def test_prefill_and_decode_match_quant(tp8, small_model_config):
    cfg_q = small_model_config.scaled(pim_mode="quant")
    cfg_tp = cfg_q.scaled(pim_mode="quant_tp")
    params = M.init_params(cfg_q, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg_q.vocab_size, (2, 9))
    batch = {"tokens": jnp.asarray(toks[:, :8], jnp.int32)}
    nxt = jnp.asarray(toks[:, 8:9], jnp.int32)

    lg_q, c_q = jax.jit(lambda p, b: M.prefill(p, b, cfg_q))(params, batch)
    _, d_q, _ = jax.jit(
        lambda p, t, c: M.decode_step(p, t, jnp.int32(8), c, cfg_q))(
        params, nxt, c_q)
    with dctx.use_mesh(tp8):
        lg_t, c_t = jax.jit(lambda p, b: M.prefill(p, b, cfg_tp))(params,
                                                                  batch)
        _, d_t, _ = jax.jit(
            lambda p, t, c: M.decode_step(p, t, jnp.int32(8), c, cfg_tp))(
            params, nxt, c_t)
    # per-token outputs within ulp-fusion noise of the single-rank quant
    # path (the int accumulation is identical; only the float rescale and
    # downstream norm/attention fusion orders can differ across programs)
    scale = np.abs(np.asarray(lg_q)).max()
    assert np.abs(np.asarray(lg_t) - np.asarray(lg_q)).max() < 1e-4 * scale
    dscale = np.abs(np.asarray(d_q)).max()
    assert np.abs(np.asarray(d_t) - np.asarray(d_q)).max() < 1e-4 * dscale


@pytest.mark.parametrize("paged", [False, True])
def test_serving_matches_quant_both_pools(small_model_config, paged):
    """Continuous-batching decode under the 8-device (data, model) mesh:
    greedy tokens identical to the meshless single-rank quant scheduler
    through the contiguous and block-paged pools, one decode trace."""
    cfg_q = small_model_config.scaled(pim_mode="quant")
    cfg_tp = cfg_q.scaled(pim_mode="quant_tp")
    params = M.init_params(cfg_q, jax.random.PRNGKey(0))
    prompts = [([1, 2, 3, 4, 5], 6), ([9, 8], 4), ([3, 1, 4, 1, 5, 9], 5)]

    s_q = Scheduler(params, cfg_q,
                    ServingConfig(max_batch=2, prompt_bucket=8,
                                  paged=paged, block_size=8))
    rids_q = [s_q.submit(p, n) for p, n in prompts]
    out_q = s_q.run()

    mesh = make_host_mesh(model=2)
    with dctx.use_mesh(mesh):
        s_t = Scheduler(params, cfg_tp,
                        ServingConfig(max_batch=2, prompt_bucket=8,
                                      paged=paged, block_size=8), mesh=mesh)
        rids_t = [s_t.submit(p, n) for p, n in prompts]
        out_t = s_t.run()
    assert s_t.decode_traces == 1
    for ra, rb in zip(rids_q, rids_t):
        np.testing.assert_array_equal(out_q[ra], out_t[rb])


# --------------------------------------------------------------------------
# pspec plumbing for the sharded leaves
# --------------------------------------------------------------------------

def test_cache_pspecs_scale_leaves_follow_their_kv_heads():
    """Quantized-KV scale leaves get "model" on their *last* (head) dim,
    staying aligned with the (…, heads, hd) values they rescale."""
    from jax.sharding import PartitionSpec as P

    mesh = make_host_mesh(model=2)
    caches = {
        "k": jax.ShapeDtypeStruct((6, 8, 128, 2, 16), jnp.int8),
        "k_scale": jax.ShapeDtypeStruct((6, 8, 128, 2), jnp.float32),
    }
    specs = dpart.cache_pspecs(caches, mesh)
    assert specs["k"] == P(None, "data", None, "model", None)
    assert specs["k_scale"] == P(None, "data", None, "model")
