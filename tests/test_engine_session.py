"""ExecutionSession: persistent crossbar state, operand streaming, counters.

The reuse contract (the ROADMAP's "batched/persistent engine execution"):
crossbar state is uploaded once per (artifact, geometry) and later calls
stream only operand columns — bit-exactly, because every program INITs each
working column before reading it.  ``cache_info`` exposes the session
counters so the persistent path is observable from tests and benchmarks.
"""
import numpy as np
import pytest

from repro.pim import engine


@pytest.fixture(autouse=True)
def _fresh_cache():
    engine.clear_cache()
    yield
    engine.clear_cache()


def _operands(rng, m, o, k, bits=8):
    hi = 1 << bits
    return (rng.integers(0, hi, size=(m, k), dtype=np.uint64),
            rng.integers(0, hi, size=(o, k), dtype=np.uint64))


def test_session_reuse_is_bit_exact_and_uploads_once():
    """State uploads once per (artifact, weight) — the crossbar array IS
    the weight matrix; later executes stream activations onto resident
    state and match a fresh-state execution bit for bit."""
    rng = np.random.default_rng(0)
    art = engine.compile_dot(3, 8, model="minimal")
    sess = engine.ExecutionSession(art, rows_per_crossbar=16)
    x1, w = _operands(rng, 2, 4, 3)
    x2, _ = _operands(rng, 2, 4, 3)
    y1 = sess.execute(x1, w)
    y2 = sess.execute(x2, w)                     # resident-state reuse
    assert np.array_equal(y1.astype(object), x1.astype(object) @ w.T)
    assert np.array_equal(y2.astype(object), x2.astype(object) @ w.T)
    # reuse matches a cold, fresh-state execution exactly
    assert np.array_equal(y2, engine.execute(art, x2, w,
                                             rows_per_crossbar=16))
    assert (sess.uploads, sess.hits) == (1, 1)
    # a different weight matrix is a different crossbar array: new upload,
    # and the first weight's state stays resident alongside it
    _, w2 = _operands(rng, 2, 4, 3)
    y3 = sess.execute(x1, w2)
    assert np.array_equal(y3.astype(object), x1.astype(object) @ w2.T)
    assert sess.uploads == 2
    sess.execute(x2, w)                          # still warm
    assert (sess.uploads, sess.hits) == (2, 2)


def test_session_weight_stationary_streams_activations_only():
    """Same weights (the decode steady state): one upload, then every call
    is a hit that streams only activation columns; result stays exact."""
    rng = np.random.default_rng(1)
    art = engine.compile_dot(4, 8, model="minimal")
    sess = engine.ExecutionSession(art, rows_per_crossbar=16)
    _, w = _operands(rng, 2, 3, 4)
    for i in range(3):
        x, _ = _operands(rng, 2, 3, 4)
        y = sess.execute(x, w)
        assert np.array_equal(y.astype(object), x.astype(object) @ w.T), i
    assert (sess.uploads, sess.hits) == (1, 2)
    # changing the weights is a new crossbar array (upload), still exact
    x, w2 = _operands(rng, 2, 3, 4)
    assert np.array_equal(sess.execute(x, w2).astype(object),
                          x.astype(object) @ w2.T)
    assert (sess.uploads, sess.hits) == (2, 2)


def test_session_lru_eviction_bounds_resident_states():
    """Cyclic access over more weights than max_resident stays exact (it
    just re-uploads); within the cap everything stays resident."""
    rng = np.random.default_rng(6)
    art = engine.compile_dot(2, 8, model="minimal")
    sess = engine.ExecutionSession(art, rows_per_crossbar=16,
                                   max_resident=2)
    ws = [_operands(rng, 2, 2, 2)[1] for _ in range(3)]
    x, _ = _operands(rng, 2, 2, 2)
    for rnd in range(2):
        for w in ws:                             # 3 weights, 2 slots
            y = sess.execute(x, w)
            assert np.array_equal(y.astype(object),
                                  x.astype(object) @ w.T), rnd
    assert len(sess._states) == 2
    assert sess.hits == 0 and sess.uploads == 6  # cyclic > cap: all cold


def test_session_new_geometry_pays_new_upload():
    rng = np.random.default_rng(2)
    art = engine.compile_dot(3, 8, model="minimal")
    sess = engine.ExecutionSession(art, rows_per_crossbar=16)
    x, w = _operands(rng, 2, 4, 3)
    sess.execute(x, w)
    xl, wl = _operands(rng, 8, 5, 3)             # more rows -> more crossbars
    y = sess.execute(xl, wl)
    assert np.array_equal(y.astype(object), xl.astype(object) @ wl.T)
    assert sess.uploads == 2
    sess.execute(x, w)                           # first geometry still warm
    assert (sess.uploads, sess.hits) == (2, 1)


@pytest.mark.parametrize("backend", ["scan", "numpy"])
def test_session_backends_agree(backend):
    rng = np.random.default_rng(3)
    art = engine.compile_dot(3, 8, model="minimal")
    sess = engine.ExecutionSession(art, backend=backend,
                                   rows_per_crossbar=16)
    x, w = _operands(rng, 3, 3, 3)
    y1 = sess.execute(x, w)
    x2, _ = _operands(rng, 3, 3, 3)
    y2 = sess.execute(x2, w)                     # weight-stationary hit
    assert np.array_equal(y1.astype(object), x.astype(object) @ w.T)
    assert np.array_equal(y2.astype(object), x2.astype(object) @ w.T)
    assert (sess.uploads, sess.hits) == (1, 1)


def test_matmul_int_pools_sessions_and_cache_info_reports():
    """The pim_sim host path (matmul_int) must reuse pooled sessions: one
    upload per artifact across repeated calls, observable via cache_info."""
    rng = np.random.default_rng(4)
    x, w = _operands(rng, 2, 3, 4)
    engine.matmul_int(x, w, 8, model="minimal", rows_per_crossbar=16)
    info1 = engine.cache_info()
    assert info1.exec_uploads == 1 and info1.exec_hits == 0
    engine.matmul_int(x, w, 8, model="minimal", rows_per_crossbar=16)
    info2 = engine.cache_info()
    assert info2.exec_uploads == 1, "second call must not re-upload state"
    assert info2.exec_hits == 1                  # weights stayed resident


def test_session_for_returns_same_session_until_cleared():
    art = engine.compile_dot(2, 8, model="minimal")
    s1 = engine.session_for(art, rows_per_crossbar=16)
    assert engine.session_for(art, rows_per_crossbar=16) is s1
    assert engine.session_for(art, rows_per_crossbar=32) is not s1
    engine.clear_cache()
    art2 = engine.compile_dot(2, 8, model="minimal")
    assert engine.session_for(art2, rows_per_crossbar=16) is not s1
    info = engine.cache_info()
    assert (info.exec_hits, info.exec_uploads) == (0, 0)


def test_sim_linear_decode_loop_uploads_once():
    """A pim_sim 'decode loop' — repeated jitted linears with the same
    weights — pays exactly one crossbar upload, then streams activations."""
    import jax
    import jax.numpy as jnp

    from repro.models.layers import linear

    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(2, 4)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))
    with engine.mode("pim_sim"):
        f = jax.jit(lambda a, b: linear(a, b))
        first = np.asarray(f(x, w))
    uploads_after_first = engine.cache_info().exec_uploads
    with engine.mode("pim_sim"):
        for _ in range(3):
            out = np.asarray(f(x, w))
    info = engine.cache_info()
    assert info.exec_uploads == uploads_after_first, \
        "steady-state pim_sim decode must not re-upload crossbar state"
    assert info.exec_hits >= 3
    assert np.array_equal(out, first)            # bit-identical steady state
