"""Crossbar executor: packing, IO helpers, gate execution semantics."""
import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import InitOp, Operation, PartitionConfig, Program
from repro.pim import executor as ex


@given(rows=st.integers(1, 130), seed=st.integers(0, 10**6))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(rows, seed):
    rng = np.random.default_rng(seed)
    bits = rng.random((3, rows)) < 0.5
    assert np.array_equal(ex.unpack_rows(ex.pack_rows(bits), rows), bits)


def test_write_read_numbers():
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 2**16, size=(2, 77), dtype=np.uint64)
    state = ex.blank_state(2, 64, 77)
    cols = tuple(range(3, 19))
    state = ex.write_numbers(state, cols, vals)
    assert np.array_equal(ex.read_numbers(state, cols, 77), vals)


def test_execute_matches_numpy_model():
    """Random microcode vs a pure-numpy bit-level interpreter."""
    rng = np.random.default_rng(1)
    n, rows, g = 32, 40, 200
    codes = rng.integers(0, 6, size=g)
    ia = rng.integers(0, n, size=g)
    ib = rng.integers(0, n, size=g)
    out = rng.integers(0, n, size=g)
    mc = np.stack([codes, ia, ib, out], axis=1).astype(np.int32)

    init_bits = rng.random((n, rows)) < 0.5
    ref = init_bits.copy()
    for c, a, b, o in mc:
        if c == 0:
            ref[o] = True
        elif c == 1:
            ref[o] = ~ref[a]
        elif c == 2:
            ref[o] = ~(ref[a] | ref[b])
        elif c == 3:
            ref[o] = ref[a] | ref[b]
        elif c == 4:
            ref[o] = ~(ref[a] & ref[b])
        else:
            ref[o] = ref[a] & ref[b]

    state = ex.blank_state(1, n, rows)
    for col in range(n):
        state = ex.write_bits(state, col, init_bits[None, col])
    got = ex.execute(state, jnp.asarray(mc))
    got_bits = np.stack([ex.read_bits(got, c, rows)[0] for c in range(n)])
    assert np.array_equal(got_bits, ref)


def test_unrolled_matches_scan():
    rng = np.random.default_rng(2)
    mc = np.stack([rng.integers(0, 6, 50), rng.integers(0, 16, 50),
                   rng.integers(0, 16, 50), rng.integers(0, 16, 50)],
                  axis=1).astype(np.int32)
    state = jnp.asarray(
        rng.integers(0, 2**32, size=(2, 16, 2), dtype=np.uint32))
    a = ex.execute(jnp.array(state), jnp.asarray(mc))
    b = ex.execute_unrolled(jnp.array(state), mc)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_program_microcode_init_expansion():
    cfg = PartitionConfig(64, 8)
    prog = Program(cfg=cfg, model="minimal")
    prog.append(Operation(init=InitOp("periodic", 1, 2, 0, 7, 1)))
    mc = prog.to_microcode()
    assert mc.shape == (16, 4)  # 8 partitions x 2 columns
    assert (mc[:, 0] == 0).all()
