"""Mode-sensitive smoke suite, parametrized by ``PIM_TEST_MODE``.

``conftest.pytest_generate_tests`` fans every test taking a
``pim_test_mode`` argument out over the engine modes selected by the
``PIM_TEST_MODE`` env var (CI's tier-1 matrix pins one mode per job so a
backend regression pinpoints its mode; locally all modes run).  The
invariants checked are *within-mode*: prefill+decode must agree with the
full forward pass under the same lowering, and the serving runtime must
generate without retracing — for every backend, not just the default
einsum path.
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.dist import context as dctx
from repro.launch.mesh import make_mesh
from repro.models import model_lib as M
from repro.serving import Scheduler, ServingConfig


def _tiny(mode):
    """Small enough that the bit-accurate pim_sim crossbar runs in
    seconds; big enough to cover GQA attention + gated MLP + unembed."""
    return C.get("qwen1.5-0.5b").smoke().scaled(
        n_layers=1, pattern=("ad",), d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=64, pad_vocab_multiple=8,
        loss_chunk=8, max_seq_len=16, pim_mode=mode)


def _mesh_ctx(mode):
    """quant_tp is only distinct from quant under a tensor axis."""
    import contextlib

    if mode != "quant_tp":
        return contextlib.nullcontext()
    return dctx.use_mesh(make_mesh((8,), ("model",)))


def test_decode_matches_forward_in_mode(pim_test_mode):
    """prefill + one decode step == full-forward last-token logits, with
    every linear lowered through the selected backend.  Both paths run the
    same quantized arithmetic, so the tolerance is numerical-noise-sized
    even for the fixed-point modes."""
    cfg = _tiny(pim_test_mode)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    L = 8
    toks = rng.integers(0, cfg.vocab_size, (2, L + 1))
    batch = {"tokens": jnp.asarray(toks[:, :L], jnp.int32)}
    with _mesh_ctx(pim_test_mode):
        _, caches = jax.jit(lambda p, b: M.prefill(p, b, cfg))(params, batch)
        nxt = jnp.asarray(toks[:, L:L + 1], jnp.int32)
        _, logits_dec, _ = jax.jit(
            lambda p, t, c: M.decode_step(p, t, jnp.int32(L), c, cfg))(
            params, nxt, caches)

        full = dict(batch, tokens=jnp.asarray(toks, jnp.int32))
        x = M._embed_in(params, full["tokens"], cfg)
        with M._pim_ctx(cfg):
            x, _ = M._decoder_stack(params, x, cfg,
                                    positions=jnp.arange(L + 1), mode="train")
        from repro.models.layers import rms_norm, unembed

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits_fwd = unembed(x[:, -1], M._unembed_table(params, cfg))
    got, want = np.asarray(logits_dec), np.asarray(logits_fwd)
    tol = 2e-3 if pim_test_mode == "xla" else 2e-2
    assert np.abs(got - want).max() <= tol * max(np.abs(want).max(), 1.0), \
        f"decode/forward divergence under mode {pim_test_mode}"


def test_loss_is_finite_in_mode(pim_test_mode):
    cfg = _tiny(pim_test_mode)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)),
                              jnp.int32),
    }
    with _mesh_ctx(pim_test_mode):
        loss = float(jax.jit(lambda p, b: M.loss_fn(p, b, cfg))(params,
                                                                batch))
    assert np.isfinite(loss)


def test_serving_generates_in_mode(pim_test_mode):
    """The continuous-batching runtime serves under every backend with one
    decode trace (the jitted slot step must not retrace per mode-internal
    machinery like pure_callback or shard_map)."""
    cfg = _tiny(pim_test_mode)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    with _mesh_ctx(pim_test_mode):
        sched = Scheduler(params, cfg,
                          ServingConfig(max_batch=2, prompt_bucket=4))
        rids = [sched.submit([1, 2, 3], 3), sched.submit([5, 4], 3),
                sched.submit([7], 2)]
        out = sched.run()
    assert sched.decode_traces == 1
    for rid, n in zip(rids, (3, 3, 2)):
        assert out[rid].shape == (n,)
        assert ((0 <= out[rid]) & (out[rid] < cfg.padded_vocab)).all()
