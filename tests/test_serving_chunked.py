"""Chunked + packed prefill with decode interleaving, plus the scheduler
bugfix sweep that rode along.

Tentpole coverage: chunked (and packed) scheduling must be a *latency*
optimization only — generations bit-identical to whole-prompt prefill
per PIM engine mode, with steady-state decode still exactly one jit
trace under chunk churn; a packed prefill's segments must be fully
isolated (each segment's logits equal its own unpacked prefill, and
perturbing one segment's tokens must not move another's logits); a
replica killed while a slot is mid-prefill must drain that request like
any other — requeued, re-served, bit-exact.

Satellite regressions: ``validate_request`` must accept a windowed
request whose ``prompt + budget`` exceeds ``num_blocks * block_size``
(the ring clamps its block need to the window — the raw token count
over-rejected); ``deferred_admits`` must count one event per request per
wait even when SJF churns the queue head mid-wait; an idle ``run()``
must sleep toward a far-future arrival instead of busy-polling 1 ms
slices (while still detecting a non-advancing injected clock).
"""
import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.dist import context as dctx
from repro.launch.mesh import make_mesh
from repro.models import model_lib as M
from repro.serving import (FailurePlan, Router, RouterConfig, Scheduler,
                           ServingConfig, make_request)
from repro.serving.scheduler import _idle_sleep


def _smoke():
    return C.get("qwen1.5-0.5b").smoke()


def _tiny(mode, **kw):
    return C.get("qwen1.5-0.5b").smoke().scaled(
        n_layers=1, pattern=("ad",), d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=64, pad_vocab_multiple=8,
        loss_chunk=8, max_seq_len=48, pim_mode=mode, **kw)


def _mesh_ctx(mode):
    if mode != "quant_tp":
        return contextlib.nullcontext()
    return dctx.use_mesh(make_mesh((8,), ("model",)))


def _bursty_trace(cfg, *, long_plen, seed=0):
    """Short prompts with staggered budgets plus one long prompt wedged
    mid-queue — the chunking workload."""
    rng = np.random.default_rng(seed)
    reqs = [make_request(rng.integers(1, cfg.vocab_size, (3, 5, 4, 6)[i]),
                         (4, 6, 5, 4)[i]) for i in range(4)]
    reqs.insert(2, make_request(rng.integers(1, cfg.vocab_size, long_plen),
                                4))
    return reqs


def _run(params, cfg, scfg, reqs):
    sched = Scheduler(params, cfg, scfg)
    rids = [sched.submit_request(make_request(r.prompt, r.max_new_tokens))
            for r in reqs]
    out = sched.run()
    return sched, [out[rid] for rid in rids]


# ---------------------------------------------------------------------------
# tentpole: bit-exactness of chunked + packed scheduling
# ---------------------------------------------------------------------------

def test_chunked_bit_exact_per_pim_mode(pim_test_mode):
    """Chunked + packed generations must match whole-prompt prefill token
    for token under every engine lowering (CI's PIM_TEST_MODE matrix)."""
    cfg = _tiny(pim_test_mode)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _bursty_trace(cfg, long_plen=32, seed=1)
    base = dict(max_batch=3, prompt_bucket=4, block_size=4)
    with _mesh_ctx(pim_test_mode):
        _, whole = _run(params, cfg, ServingConfig(paged=True, **base), reqs)
        sched, chunked = _run(
            params, cfg,
            ServingConfig(paged=True, prefill_chunk=8, step_token_budget=8,
                          packed_prefill=True, **base), reqs)
    for i, (a, b) in enumerate(zip(whole, chunked)):
        assert (a == b).all(), \
            f"request {i} diverged under {pim_test_mode}: {a} vs {b}"
    s = sched.metrics.summary()
    # the 32-token prompt must actually have chunked (4 chunks of 8)
    assert s["prefill_chunks"] == 4
    assert sched.decode_traces == 1


def test_decode_trace_stays_single_under_chunk_churn():
    """Mid-prefill slots joining and leaving the decode batch must never
    change the decode step's shapes: exactly one trace, start to end."""
    cfg = _smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(3)
    reqs = [make_request(rng.integers(1, cfg.vocab_size, 40), 6)
            for _ in range(3)]
    reqs += [make_request(rng.integers(1, cfg.vocab_size, p), g)
             for p, g in ((5, 7), (9, 4), (3, 9), (7, 5))]
    scfg = ServingConfig(max_batch=4, prompt_bucket=8, paged=True,
                         block_size=8, prefill_chunk=16,
                         step_token_budget=16, packed_prefill=True)
    sched, outs = _run(params, cfg, scfg, reqs)
    assert sched.decode_traces == 1
    assert sched.metrics.summary()["prefill_chunks"] >= 9  # 3 prompts x 3
    _, whole = _run(params, cfg,
                    ServingConfig(max_batch=4, prompt_bucket=8, paged=True,
                                  block_size=8), reqs)
    for a, b in zip(whole, outs):
        assert (a == b).all()


def test_packed_segments_are_isolated():
    """Each packed segment's logits must equal its own unpacked prefill,
    and perturbing one segment's tokens must not move any other
    segment's logits (the block-diagonal mask actually isolates)."""
    cfg = _tiny("xla")
    params = M.init_params(cfg, jax.random.PRNGKey(4))
    rng = np.random.default_rng(5)
    plens = [5, 3, 7]
    widths = [8, 4, 8]          # bucket-aligned segment widths
    prompts = [rng.integers(1, cfg.vocab_size, p) for p in plens]

    def pack(prompts):
        L = sum(widths)
        toks = np.zeros((1, L), np.int32)
        pos = np.zeros(L, np.int32)
        seg = np.full(L, -1, np.int32)
        last = np.zeros(len(prompts), np.int32)
        s0 = 0
        for i, (p, w) in enumerate(zip(prompts, widths)):
            toks[0, s0:s0 + len(p)] = p
            pos[s0:s0 + w] = np.arange(w)
            seg[s0:s0 + len(p)] = i
            last[i] = s0 + len(p) - 1
            s0 += w
        return M.prefill_packed(params, jnp.asarray(toks), jnp.asarray(pos),
                                jnp.asarray(seg), jnp.asarray(last), cfg)

    packed_logits, _ = pack(prompts)
    for i, (p, w) in enumerate(zip(prompts, widths)):
        toks = np.zeros((1, w), np.int32)
        toks[0, :len(p)] = p
        solo, _ = M.prefill(params, {"tokens": jnp.asarray(toks)}, cfg,
                            last_index=jnp.asarray([len(p) - 1], jnp.int32))
        np.testing.assert_array_equal(np.asarray(packed_logits[i]),
                                      np.asarray(solo[0]),
                                      err_msg=f"segment {i} != solo prefill")
    # adversarial: rewrite segment 1's tokens entirely; 0 and 2 must not move
    mutated = list(prompts)
    mutated[1] = rng.integers(1, cfg.vocab_size, plens[1])
    perturbed, _ = pack(mutated)
    for i in (0, 2):
        np.testing.assert_array_equal(
            np.asarray(packed_logits[i]), np.asarray(perturbed[i]),
            err_msg=f"segment {i} leaked across the segment mask")
    assert not np.array_equal(np.asarray(packed_logits[1]),
                              np.asarray(perturbed[1]))


def test_midprefill_slot_drains_through_router_kill():
    """A replica killed while a slot is mid-prefill must requeue that
    request (partial blocks evicted) and the rerun must stay
    bit-identical to an undisturbed single scheduler."""
    cfg = _smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(6))
    rng = np.random.default_rng(7)
    reqs = [make_request(rng.integers(1, cfg.vocab_size, p), g)
            for p, g in ((48, 6), (5, 8), (7, 6), (48, 4), (6, 8))]
    scfg = ServingConfig(max_batch=2, prompt_bucket=8, paged=True,
                         block_size=8, prefill_chunk=16,
                         step_token_budget=16)
    oracle_sched, oracle = _run(params, cfg, scfg, reqs)
    assert oracle_sched.metrics.summary()["prefill_chunks"] >= 6

    class FakeClock:
        def __init__(self, t=0.0):
            self.t = t

        def __call__(self):
            return self.t

    # round 0 admits the first 48-token prompt's first chunk (one of
    # three); the kill fires at the start of round 1, draining the slot
    # while _prefilling is still set
    router = Router(params, cfg, scfg,
                    RouterConfig(n_replicas=2, policy="round_robin"),
                    devices=jax.devices()[:2], clock=FakeClock(1.0),
                    failure_plan=FailurePlan(kill_replica=0, at_step=1))
    fresh = [make_request(r.prompt, r.max_new_tokens) for r in reqs]
    for r in fresh:
        router.submit_request(r)
    results = router.run()
    assert router.rebalanced_requests > 0
    for i, r in enumerate(fresh):
        assert np.array_equal(results[r.rid], oracle[i]), i
    # the drained scheduler's mid-prefill bookkeeping must be clean
    for rep in router.replicas:
        if rep.alive:
            assert not rep.sched._prefilling.any()
            assert not rep.sched._deferred_rids


def test_chunked_config_validation():
    cfg = _smoke()
    with pytest.raises(ValueError, match="multiple of block_size"):
        Scheduler(None, cfg, ServingConfig(paged=True, block_size=8,
                                           prefill_chunk=12))
    with pytest.raises(ValueError, match="below"):
        Scheduler(None, cfg, ServingConfig(paged=True, block_size=8,
                                           prefill_chunk=16,
                                           step_token_budget=8))
    with pytest.raises(ValueError, match="step_token_budget"):
        Scheduler(None, cfg, ServingConfig(step_token_budget=0))


# ---------------------------------------------------------------------------
# satellite: validate_request vs the windowed ring clamp
# ---------------------------------------------------------------------------

def test_validate_request_windowed_long_budget_admits_and_serves():
    """A windowed request with ``prompt + budget > num_blocks *
    block_size`` must pass validation *and serve*: the slot is a ring
    capped at ceil(window / block_size) blocks, so the raw token count
    never reaches the pool-size check."""
    cfg = _smoke().scaled(sliding_window=16)
    params = M.init_params(cfg, jax.random.PRNGKey(8))
    rng = np.random.default_rng(9)
    scfg = ServingConfig(max_batch=1, prompt_bucket=8, block_size=8,
                         num_blocks=5)      # 4 usable blocks = 32 tokens
    sched = Scheduler(params, cfg, scfg)
    prompt = rng.integers(1, cfg.vocab_size, 32)
    budget = 16                              # 32 + 16 = 48 > 32 pool tokens
    req = make_request(prompt, budget)
    sched.validate_request(req)              # pre-fix: over-rejected here
    sched.submit_request(req)
    out = sched.run()
    assert out[req.rid].shape == (budget,)
    assert sched.metrics.summary()["deferred_admits"] == 0


# ---------------------------------------------------------------------------
# satellite: deferred_admits dedupe under SJF head churn
# ---------------------------------------------------------------------------

def test_deferred_admits_dedupes_across_sjf_head_churn():
    """Under SJF the queue head changes identity while a request waits:
    long request A defers, shorter B arrives and becomes head (second
    event), B later admits while A keeps waiting.  A's continued wait is
    the *same* event — a last-deferred-rid scalar recounts it once B is
    out of the way; the set dedupe must not."""
    cfg = _smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(10))
    rng = np.random.default_rng(11)
    # each request needs 3 blocks of 4; pool holds 3 usable blocks, so
    # exactly one request fits at a time
    sched = Scheduler(params, cfg,
                      ServingConfig(max_batch=2, prompt_bucket=4,
                                    paged=True, block_size=4, num_blocks=4,
                                    queue_policy="sjf"),
                      clock=lambda: 0.0)
    hold = make_request(rng.integers(1, cfg.vocab_size, 4), 8, rid=1)
    sched.submit_request(hold)
    sched.step()
    assert sched.n_active == 1               # pool now full
    req_a = make_request(rng.integers(1, cfg.vocab_size, 8), 4, rid=2)
    sched.submit_request(req_a)
    sched.step()
    assert sched.metrics.deferred_admits == 1     # A deferred behind hold
    req_b = make_request(rng.integers(1, cfg.vocab_size, 4), 8, rid=3)
    sched.submit_request(req_b)
    sched.step()
    # SJF: B (plen 4) is now the head and defers — a distinct second event
    assert sched.metrics.deferred_admits == 2
    for _ in range(40):
        sched.step()
        if not len(sched.queue) and not sched.active_slots.any():
            break
    assert not len(sched.queue)
    # B admitted while A kept waiting, then A admitted: neither continued
    # wait is a new event (the scalar-rid version recounted A here)
    assert sched.metrics.deferred_admits == 2, \
        "deferred_admits overcounted across SJF head churn"


# ---------------------------------------------------------------------------
# satellite: idle run() sleeps toward the arrival instead of busy-polling
# ---------------------------------------------------------------------------

def test_idle_sleep_jumps_to_arrival_on_a_real_clock():
    calls = []

    def clock():
        calls.append(None)
        return time.monotonic()

    target = time.monotonic() + 0.2
    t0 = time.monotonic()
    stalls = _idle_sleep(clock, target, stalls=0)
    waited = time.monotonic() - t0
    assert stalls == 0
    # one probe + one capped slice — not two hundred 1 ms spins
    assert len(calls) <= 3
    assert waited >= 0.15

    # cap bounds a single sleep so run() re-checks the queue periodically
    t0 = time.monotonic()
    _idle_sleep(clock, time.monotonic() + 60.0, stalls=0, cap=0.05)
    assert time.monotonic() - t0 < 1.0


def test_idle_sleep_detects_injected_clock():
    stalls = 0
    for _ in range(3):
        stalls = _idle_sleep(lambda: 5.0, 99.0, stalls)
    assert stalls == 3                       # never advances, never sleeps long


def test_run_does_not_busy_poll_far_arrivals():
    """An idle scheduler waiting 0.3 s for its only request must make a
    handful of loop iterations, not ~300 one-millisecond polls."""
    cfg = _smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(12))
    rng = np.random.default_rng(13)
    calls = [0]

    def clock():
        calls[0] += 1
        return time.monotonic()

    sched = Scheduler(params, cfg,
                      ServingConfig(max_batch=1, prompt_bucket=4),
                      clock=clock)
    sched.submit(rng.integers(1, cfg.vocab_size, 4), 2,
                 arrival_time=time.monotonic() + 0.3)
    calls[0] = 0
    out = sched.run()
    assert len(out) == 1
    # pre-fix this sat at ~300 polls x several clock reads each; the
    # capped-slice sleeper needs only a few iterations (plus serving)
    assert calls[0] < 120, f"{calls[0]} clock reads for a 0.3s idle wait"
