"""Deterministic draw-based strategies for the hypothesis shim."""
from __future__ import annotations

import math
from typing import Any, Callable, Sequence

__all__ = ["SearchStrategy", "Unsatisfiable", "integers", "booleans",
           "floats", "sampled_from", "just", "tuples", "lists", "one_of",
           "composite"]

_MAX_FILTER_TRIES = 200


class Unsatisfiable(Exception):
    """A ``.filter`` predicate rejected every candidate."""


class SearchStrategy:
    def __init__(self, draw: Callable[[Any], Any]):
        self._draw = draw

    def do_draw(self, rnd) -> Any:
        return self._draw(rnd)

    def map(self, fn) -> "SearchStrategy":
        return SearchStrategy(lambda rnd: fn(self._draw(rnd)))

    def filter(self, pred) -> "SearchStrategy":
        def draw(rnd):
            for _ in range(_MAX_FILTER_TRIES):
                v = self._draw(rnd)
                if pred(v):
                    return v
            raise Unsatisfiable("filter predicate rejected "
                                f"{_MAX_FILTER_TRIES} candidates")

        return SearchStrategy(draw)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    if min_value > max_value:
        raise ValueError(f"empty integer range [{min_value}, {max_value}]")

    def draw(rnd):
        # Weight the endpoints: boundary bugs dominate this codebase
        # (partition 0 / k-1, value 0 / 2^32-1).
        r = rnd.random()
        if r < 0.08:
            return min_value
        if r < 0.16:
            return max_value
        return rnd.randint(min_value, max_value)

    return SearchStrategy(draw)


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.random() < 0.5)


def floats(min_value: float = -1e9, max_value: float = 1e9,
           allow_nan: bool = False, allow_infinity: bool = False,
           width: int = 64) -> SearchStrategy:
    def draw(rnd):
        r = rnd.random()
        if allow_nan and r < 0.02:
            return math.nan
        if allow_infinity and r < 0.04:
            return math.inf if rnd.random() < 0.5 else -math.inf
        return rnd.uniform(min_value, max_value)

    return SearchStrategy(draw)


def sampled_from(elements: Sequence) -> SearchStrategy:
    elements = list(elements)
    if not elements:
        raise ValueError("sampled_from requires a non-empty sequence")
    return SearchStrategy(lambda rnd: elements[rnd.randrange(len(elements))])


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rnd: value)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rnd: tuple(s.do_draw(rnd) for s in strategies))


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rnd):
        n = rnd.randint(min_size, max_size)
        return [elements.do_draw(rnd) for _ in range(n)]

    return SearchStrategy(draw)


def one_of(*strategies: SearchStrategy) -> SearchStrategy:
    if not strategies:
        raise ValueError("one_of requires at least one strategy")
    return SearchStrategy(
        lambda rnd: strategies[rnd.randrange(len(strategies))].do_draw(rnd))


def composite(fn) -> Callable[..., SearchStrategy]:
    """``@composite`` decorator: ``fn(draw, *args)`` builds one example by
    drawing from other strategies — the way hypothesis expresses dependent
    draws (e.g. ``hi`` at least ``lo``).  Calling the decorated function
    returns the strategy."""

    def builder(*args, **kwargs) -> SearchStrategy:
        def draw_impl(rnd):
            def draw(strategy):
                if not isinstance(strategy, SearchStrategy):
                    raise TypeError("draw() takes a SearchStrategy")
                return strategy.do_draw(rnd)

            return fn(draw, *args, **kwargs)

        return SearchStrategy(draw_impl)

    return builder
