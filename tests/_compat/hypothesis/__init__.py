"""Minimal stand-in for the ``hypothesis`` API used by this test suite.

The real hypothesis package is preferred and used whenever it is
importable; ``conftest.py`` only puts this shim on ``sys.path`` when it is
missing (the pinned CI image installs the real one).  The shim replays a
deterministic stream of pseudo-random examples per test — no shrinking, no
database, no health checks — which keeps the property tests meaningful as
regression tests in a dependency-free environment.

Supported surface: ``given`` (keyword strategies), ``settings(max_examples,
deadline)``, ``assume``, and the strategies in ``hypothesis.strategies``
(``integers``, ``booleans``, ``floats``, ``sampled_from``, ``just``,
``tuples``, ``lists``, ``one_of``, ``@composite``, plus
``.map``/``.filter``).  Grow this surface in lockstep with the property
tests: anything ``tests/test_codec.py`` draws must collect and pass both
with real hypothesis and with this shim.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

from . import strategies
from .strategies import SearchStrategy, Unsatisfiable

__all__ = ["given", "settings", "assume", "strategies", "HealthCheck"]

_DEFAULT_MAX_EXAMPLES = 25


class _Assumption(Exception):
    """Raised by ``assume(False)``: the example is discarded, not failed."""


def assume(condition) -> bool:
    if not condition:
        raise _Assumption()
    return True


class HealthCheck:
    """Accepted and ignored (``suppress_health_check=`` compatibility)."""

    all = classmethod(lambda cls: [])
    too_slow = data_too_large = filter_too_much = None


def settings(*args, max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
    """Decorator recording ``max_examples``; every other knob is a no-op.

    Mirrors hypothesis in accepting either order relative to ``@given``.
    """

    def apply(fn):
        fn._hyp_settings = {"max_examples": max_examples}
        return fn

    if args and callable(args[0]):  # bare @settings
        return apply(args[0])
    return apply


def given(*arg_strategies, **kw_strategies):
    if arg_strategies:
        raise TypeError("the hypothesis shim supports keyword strategies "
                        "only, e.g. @given(x=st.integers(0, 9))")
    for name, s in kw_strategies.items():
        if not isinstance(s, SearchStrategy):
            raise TypeError(f"strategy for {name!r} is not a SearchStrategy")

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = (getattr(wrapper, "_hyp_settings", None)
                   or getattr(fn, "_hyp_settings", None)
                   or {"max_examples": _DEFAULT_MAX_EXAMPLES})
            # Stable per-test stream: same examples on every run / machine.
            seed0 = zlib.crc32(fn.__qualname__.encode())
            ran = 0
            attempts = 0
            limit = cfg["max_examples"]
            while ran < limit and attempts < limit * 20:
                rnd = random.Random(seed0 * 1_000_003 + attempts)
                attempts += 1
                try:
                    drawn = {k: s.do_draw(rnd)
                             for k, s in kw_strategies.items()}
                except Unsatisfiable:
                    continue
                try:
                    fn(*args, **drawn, **kwargs)
                except _Assumption:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({fn.__name__}): {drawn!r}"
                    ) from e
                ran += 1
            if ran == 0:
                raise Unsatisfiable(
                    f"{fn.__name__}: could not generate any valid example")
            return None

        # Hide the strategy-filled parameters from pytest's fixture
        # resolution (functools.wraps copies the original signature).
        del wrapper.__wrapped__
        orig = inspect.signature(fn)
        wrapper.__signature__ = orig.replace(parameters=[
            p for name, p in orig.parameters.items()
            if name not in kw_strategies])
        return wrapper

    return decorate
