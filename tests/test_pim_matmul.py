"""PIM GEMM: bit-exact dot products / matmuls and the analytical cost model."""
import numpy as np
import pytest

from repro.pim.cost_model import gemm_cost, mult_cost
from repro.pim.matmul import build_dot, pim_matmul_int


@pytest.mark.parametrize("model", ["unlimited", "minimal"])
def test_dot_program_exact(model):
    d = build_dot(3, 8, model=model)
    d.program.validate()
    rng = np.random.default_rng(0)
    from repro.pim import executor as ex

    rows = 33
    xs = rng.integers(0, 256, size=(3, 1, rows), dtype=np.uint64)
    ws = rng.integers(0, 256, size=(3, 1, rows), dtype=np.uint64)
    state = ex.blank_state(1, d.program.cfg.n, rows)
    for i in range(3):
        state = ex.write_numbers(state, d.x_cols[i], xs[i])
        state = ex.write_numbers(state, d.w_cols[i], ws[i])
    state = ex.execute(state, d.program.to_microcode())
    acc = ex.read_numbers(state, d.acc_cols, rows)
    want = (xs.astype(object) * ws.astype(object)).sum(axis=0)
    assert np.array_equal(acc.astype(object), want)


def test_pim_matmul_int_exact():
    rng = np.random.default_rng(1)
    x = rng.integers(0, 256, size=(4, 5), dtype=np.uint64)
    w = rng.integers(0, 256, size=(3, 5), dtype=np.uint64)
    y = pim_matmul_int(x, w, n_bits=8, model="minimal", rows_per_crossbar=16)
    assert np.array_equal(y.astype(object), x.astype(object) @ w.T.astype(object))


def test_dot_cycles_model_ordering():
    c = {m: build_dot(2, 8, model=m).program.stats().cycles
         for m in ("unlimited", "standard", "minimal")}
    assert c["unlimited"] <= c["standard"] <= c["minimal"]


def test_cost_model_consistency():
    g = gemm_cost(1024, 512, 1024, n_bits=8, model="minimal")
    assert g.crossbars > 0 and g.time_s > 0 and g.energy_j > 0
    # throughput mapping: cycles scale with K, not with M*N
    g2 = gemm_cost(2048, 512, 1024, n_bits=8, model="minimal")
    assert g2.cycles_per_wave == g.cycles_per_wave
    g3 = gemm_cost(1024, 1024, 1024, n_bits=8, model="minimal")
    assert g3.cycles_per_wave > g.cycles_per_wave
    # end-to-end speedup vs the serial baseline (Amdahl-limited at 8 bits;
    # grows with bit width as the multiply dominates — see benchmarks)
    base = gemm_cost(1024, 512, 1024, n_bits=8, model="baseline")
    assert base.time_s / g.time_s > 2.0
    base32 = gemm_cost(64, 64, 64, n_bits=32, model="baseline")
    g32 = gemm_cost(64, 64, 64, n_bits=32, model="minimal")
    assert base32.time_s / g32.time_s > 4.0


def test_mult_cost_measured_values():
    assert mult_cost(32, "baseline")["cycles"] > 10_000
    assert mult_cost(32, "minimal")["cycles"] < 1_500
    assert mult_cost(32, "minimal")["msg_bits"] == 36
